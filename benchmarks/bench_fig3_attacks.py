"""Paper Figure 3: attack x defense grid (controlled classification task,
16 peers / 7 Byzantine). Reports final accuracy per cell — BTARD should
recover for every attack; plain mean and the coordinate median should fail
where the paper says they do.

BTARD cells run through the scanned ProtocolState engine (core.engine):
every cell is ONE jitted lax.scan over all its steps. A loop-engine
cross-check cell confirms the scan reproduces the host loop's bans."""
from benchmarks.common import emit, run_cell

ATTACKS = ["none", "sign_flip", "random_direction", "label_flip", "ipm_06", "alie"]
DEFENSES = ["btard", "mean", "coordinate_median", "centered_clip"]


def main(fast=True):
    attacks = ATTACKS if not fast else ["none", "sign_flip", "ipm_06", "alie"]
    defenses = DEFENSES if not fast else ["btard", "mean", "centered_clip"]
    for attack in attacks:
        for defense in defenses:
            acc, banned, us = run_cell(defense, attack, steps=35, scan=True)
            emit(
                f"fig3/{attack}/{defense}",
                us,
                f"acc={acc:.3f};banned={banned}",
            )
    # engine cross-check: the scanned run and the legacy per-step loop are
    # the same state machine — bans and accuracy must agree
    acc_l, ban_l, us_l = run_cell("btard", "sign_flip", steps=35, scan=False)
    acc_s, ban_s, us_s = run_cell("btard", "sign_flip", steps=35, scan=True)
    emit(
        "fig3/engine_check/sign_flip",
        us_l,
        f"loop_acc={acc_l:.3f};scan_acc={acc_s:.3f};"
        f"loop_banned={ban_l};scan_banned={ban_s};"
        f"scan_speedup={us_l / max(us_s, 1e-9):.1f}x",
    )


if __name__ == "__main__":
    main(fast=False)
