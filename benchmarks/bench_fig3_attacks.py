"""Paper Figure 3: attack x defense grid (controlled classification task,
16 peers / 7 Byzantine). Reports final accuracy per cell — BTARD should
recover for every attack; plain mean and the coordinate median should fail
where the paper says they do."""
from benchmarks.common import emit, run_cell

ATTACKS = ["none", "sign_flip", "random_direction", "label_flip", "ipm_06", "alie"]
DEFENSES = ["btard", "mean", "coordinate_median", "centered_clip"]


def main(fast=True):
    attacks = ATTACKS if not fast else ["none", "sign_flip", "ipm_06", "alie"]
    defenses = DEFENSES if not fast else ["btard", "mean", "centered_clip"]
    for attack in attacks:
        for defense in defenses:
            acc, banned, us = run_cell(defense, attack, steps=35)
            emit(
                f"fig3/{attack}/{defense}",
                us,
                f"acc={acc:.3f};banned={banned}",
            )


if __name__ == "__main__":
    main(fast=False)
