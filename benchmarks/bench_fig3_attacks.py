"""Paper Figure 3: attack x aggregator grid (controlled classification task,
16 peers / 7 Byzantine). Reports final accuracy per cell — BTARD's
ButterflyClip should recover for every attack; the robust baselines fail
exactly where the paper (and He et al. / Lu et al.) say they do.

Every cell runs through the scanned ProtocolState engine (core.engine) via
the AggregatorSpec registry: ONE jitted lax.scan per cell, with the
aggregator selected declaratively (``EngineConfig.aggregator``). The
"btard" column is the verifiable ButterflyClip flagship (bans flow from the
verification tables); every other column is a registered baseline spec
running with verification degraded to a no-op — the attack lands, only the
detection arm differs. A loop-engine cross-check cell confirms the scan
reproduces the host loop's bans."""
import argparse

from benchmarks.common import emit, run_cell

ATTACKS = ["none", "sign_flip", "random_direction", "label_flip", "ipm_06",
           "alie"]
# "btard" = the verifiable butterfly_clip spec; the rest are the registered
# baseline aggregators (core.aggregators.registered_aggregators()), incl.
# the verified:* wrapped coordinatewise baselines — same numerics as their
# base column, but with the generalized-digest detection arm LIVE (bans).
AGGREGATORS = ["btard", "mean", "coordinate_median", "trimmed_mean",
               "geometric_median", "krum", "centered_clip",
               "verified:mean", "verified:trimmed_mean",
               "verified:coordinate_median"]


def main(fast=True):
    attacks = ATTACKS if not fast else ["none", "sign_flip", "ipm_06", "alie"]
    aggregators = AGGREGATORS if not fast else [
        "btard", "mean", "krum", "centered_clip", "trimmed_mean",
        "verified:trimmed_mean",
    ]
    steps = 25 if fast else 35
    for attack in attacks:
        for agg in aggregators:
            acc, banned, us = run_cell(agg, attack, steps=steps, scan=True)
            emit(
                f"fig3/{attack}/{agg}",
                us,
                f"acc={acc:.3f};banned={banned}",
            )
    # engine cross-check: the scanned run and the legacy per-step loop are
    # the same state machine — bans and accuracy must agree
    acc_l, ban_l, us_l = run_cell("btard", "sign_flip", steps=steps,
                                  scan=False)
    acc_s, ban_s, us_s = run_cell("btard", "sign_flip", steps=steps,
                                  scan=True)
    emit(
        "fig3/engine_check/sign_flip",
        us_l,
        f"loop_acc={acc_l:.3f};scan_acc={acc_s:.3f};"
        f"loop_banned={ban_l};scan_banned={ban_s};"
        f"scan_speedup={us_l / max(us_s, 1e-9):.1f}x",
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of attacks x aggregators, shorter runs")
    args = ap.parse_args()
    main(fast=args.quick)
