"""Paper Table 1 (empirical view): iterations-to-epsilon on a convex
least-squares problem vs the Byzantine fraction delta and validator count m.

Expected qualitative behaviour from the bounds:
  * delta = 0 recovers parallel-SGD convergence;
  * delta > 0 costs a bounded number of extra iterations (the attackers can
    deviate only ~n/m times in expectation before being banned), so the
    asymptotic rate matches delta = 0 — the paper's headline claim.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
from repro.optim import sgd

D = 32


def _setup():
    w_true = jax.random.normal(jax.random.key(5), (D,))

    def batch_fn(peer, step, flipped):
        k = jax.random.key((peer * 7919 + step * 31 + 1) % 2**31)
        X = jax.random.normal(k, (8, D))
        y = X @ w_true + 0.05 * jax.random.normal(jax.random.fold_in(k, 1), (8,))
        if flipped:
            y = -y
        return {"X": X, "y": y}

    def loss_fn(params, batch):
        return jnp.mean((batch["X"] @ params["w"] - batch["y"]) ** 2)

    def sub_opt(params):
        return float(jnp.sum((params["w"] - w_true) ** 2))

    return loss_fn, {"w": jnp.zeros((D,))}, batch_fn, sub_opt


def iters_to_eps(n_byz, m, eps=0.05, max_steps=120):
    loss_fn, params0, batch_fn, sub_opt = _setup()
    cfg = TrainerConfig(
        n_peers=16,
        byzantine=tuple(range(16 - n_byz, 16)),
        attack=AttackConfig(kind="sign_flip", start_step=0),
        defense="btard",
        tau=1.0,
        m_validators=m,
        seed=0,
    )
    tr = BTARDTrainer(loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.05, momentum=0.9))
    t0 = time.perf_counter()
    for t in range(max_steps):
        tr.train_step()
        if sub_opt(tr.unraveled_params()) < eps:
            return t + 1, (time.perf_counter() - t0) / (t + 1) * 1e6
    return max_steps, (time.perf_counter() - t0) / max_steps * 1e6


def main(fast=True):
    grid = [(0, 1), (2, 1), (5, 1), (5, 2)] if fast else [
        (0, 1), (1, 1), (2, 1), (4, 1), (5, 1), (7, 1), (5, 2), (7, 2)
    ]
    base = None
    for n_byz, m in grid:
        iters, us = iters_to_eps(n_byz, m)
        if n_byz == 0:
            base = iters
        emit(
            f"table1/delta={n_byz}of16/m={m}",
            us,
            f"iters_to_eps={iters};overhead_vs_delta0={iters - (base or iters)}",
        )


if __name__ == "__main__":
    main(fast=False)
