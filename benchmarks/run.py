"""Benchmark harness — one module per paper table/figure.

Each bench prints ``name,us_per_call,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run            # fast mode (CI-sized)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-sized grids
  PYTHONPATH=src python -m benchmarks.run --only fig3
"""
import argparse
import time

BENCHES = {
    "fig3": ("benchmarks.bench_fig3_attacks", "Fig. 3 attack x defense grid"),
    "table1": ("benchmarks.bench_table1_convergence", "Table 1 iterations-to-eps"),
    "fig9": ("benchmarks.bench_fig9_clip_iters", "Fig. 9 CenteredClip budget"),
    "overhead": ("benchmarks.bench_overhead", "App. I.2 BTARD overhead"),
    "roofline": ("benchmarks.bench_roofline", "Dry-run roofline terms"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    args = ap.parse_args()

    import importlib

    names = [args.only] if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        mod_name, desc = BENCHES[name]
        print(f"# === {name}: {desc} ===", flush=True)
        t0 = time.time()
        mod = importlib.import_module(mod_name)
        mod.main(fast=not args.full)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
