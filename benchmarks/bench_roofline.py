"""Roofline report from the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh x step):
  compute term    = FLOPs / (chips * 197e12)
  memory term     = bytes / (chips * 819e9)
  collective term = collective_bytes / (chips * 50e9)   [per-device program:
                    collective bytes already per device => / link_bw]
plus MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (inference)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

FLOPs/bytes use the scan-corrected values when the probe succeeded.

Also emits the WIRE-CODEC roofline for the compressed butterfly all-reduce
(:func:`codec_roofline`, analytic — no artifacts needed): per codec and per
gradient dim, the comm / compute / HBM time terms of one robust aggregation
round, the dim above which the payload (not the O(n^2) tables + scale
sidecars) dominates the wire, and the clip budget at which the round turns
compute-bound (where a faster codec stops paying).
"""
import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

from benchmarks.common import emit

# flops per coordinate per clip iteration (fused kernel: diff, norm-sq
# accumulate, clip-weighted update, incremental-norm recurrence — DESIGN.md)
CLIP_FLOPS_PER_COORD = 8.0


def codec_roofline(n=16, n_iters=20, dims=None, bytes_per=4,
                   m_validators=1, audit_k=None, groups=None, tag=""):
    """Bandwidth roofline of ONE compressed robust all-reduce per codec.

    Per (codec, d) the three per-peer time terms:

      comm    = bytes_on_wire / ICI_BW  — the all_to_all payload leg
                (d * codec_bytes + 2n f32 sidecar scales + the broadcast
                tables; the aggregate all_gather rides the transport dtype
                and cancels across codecs)
      compute = n_iters * d * CLIP_FLOPS_PER_COORD / PEAK_FLOPS — the
                owner-side CenteredClip work across all partitions
      hbm     = (n_iters + 2) * d * codec_bytes / HBM_BW — the fused
                dequant kernel streams WIRE bytes (kernels/DESIGN.md), so
                the codec compresses memory traffic too

    and two crossovers:

      payload_dominant_d — the dim above which d * codec_bytes exceeds the
          size-independent wire terms (tables + sidecars); below it the
          codec cannot help because the wire is table-bound;
      compute_bound_iters — the clip budget at which compute time reaches
          this codec's comm time at dim d (above it the round is
          compute-bound and further wire compression stops paying).

    Table bytes are priced through core.hierarchy.table_bytes — the SAME
    analytic model bench_overhead and check_regression use — so the
    sampled-digest (``audit_k``) and hierarchical (``groups``) axes lower
    the table-bound floor here exactly as they shrink the wire: under
    sampling the full-table 2n^2 term would overstate payload_dominant_d
    by the sampling factor.

    Returns {codec: [per-dim records]}; every record is emitted for the
    perf trajectory. Pure model — mirror of bench_overhead.comm_model — so
    it runs identically on any host.
    """
    from repro.core.compression import CODEC_BYTES
    from repro.core.hierarchy import table_bytes as hier_table_bytes

    if dims is None:
        dims = [1 << 14, 1 << 17, 1 << 20, 1 << 23, 1 << 26]
    table_b = hier_table_bytes(
        n, m_validators=m_validators, audit_k=audit_k, groups=groups,
        bytes_per=bytes_per,
    )
    out = {}
    for codec, cb in dict(CODEC_BYTES, f32=bytes_per).items():
        sidecar_b = 0 if codec == "f32" else 2 * n * bytes_per
        fixed_b = table_b + sidecar_b
        rows = []
        for d in dims:
            wire_b = d * cb + fixed_b
            t_comm = wire_b / ICI_BW
            t_compute = n_iters * d * CLIP_FLOPS_PER_COORD / PEAK_FLOPS
            t_hbm = (n_iters + 2) * d * cb / HBM_BW
            terms = {"comm": t_comm, "compute": t_compute, "hbm": t_hbm}
            rows.append({
                "d": d,
                "bytes_on_wire": wire_b,
                "t_comm_s": t_comm,
                "t_compute_s": t_compute,
                "t_hbm_s": t_hbm,
                "dominant": max(terms, key=terms.get),
                "wire_reduction_x": (d * bytes_per + table_b) / wire_b,
                "compute_bound_iters": (wire_b / ICI_BW) * PEAK_FLOPS
                / (d * CLIP_FLOPS_PER_COORD),
            })
        out[codec] = {
            # d * cb = fixed_b — payload overtakes the size-independent wire
            "payload_dominant_d": fixed_b / cb,
            "dims": rows,
        }
        for r in rows:
            emit(
                f"roofline/codec{tag}/{codec}/d={r['d']}",
                1e6 * r["t_comm_s"],
                f"compute_us={1e6 * r['t_compute_s']:.2f};"
                f"hbm_us={1e6 * r['t_hbm_s']:.2f};"
                f"dominant={r['dominant']};"
                f"wire_reduction={r['wire_reduction_x']:.2f}x;"
                f"compute_bound_iters={r['compute_bound_iters']:.0f}",
            )
    return out


def analyze_record(rec):
    chips = rec["n_devices"]
    flops = rec.get("flops_corrected", rec["flops"])
    byts = rec.get("bytes_corrected", rec["bytes"])
    coll = rec.get(
        "collective_bytes_corrected", rec["collective_bytes"].get("total", 0)
    )
    # cost_analysis is for the per-device partitioned program
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    n_active = rec.get("active_param_count", rec.get("param_count", 0))
    shape = rec["shape"]
    tokens = {
        "train_4k": 4096 * 256,
        "prefill_32k": 32768 * 32,
        "decode_32k": 128,
        "long_500k": 1,
    }.get(shape, 0)
    if rec["step"] in ("baseline", "btard"):
        model_flops = 6 * n_active * tokens
    else:
        model_flops = 2 * n_active * tokens
    ratio = model_flops / max(flops * chips, 1e-9)
    return terms, dominant, model_flops, ratio


def main(fast=True, out_dir="results/dryrun"):
    # full Alg. 6 tables vs the flat-cost axes (sampled digests at
    # m_validators=2 x audit_k=2; 4 groups of 4 at n=16): the table-bound
    # wire floor drops with the tables, so payload_dominant_d falls by the
    # sampling factor — the full-table figure would overstate it.
    variants = {
        "": dict(),
        "/sampled": dict(m_validators=2, audit_k=2),
        "/hier_sampled": dict(m_validators=2, audit_k=2, groups=4),
    }
    print(
        "# variant,codec,payload_dominant_d,largest_dim_dominant,"
        "wire_reduction_x"
    )
    for tag, kw in variants.items():
        codecs = codec_roofline(tag=tag, **kw)
        for codec, block in codecs.items():
            last = block["dims"][-1]
            print(
                f"{tag or '/full'},{codec},"
                f"{block['payload_dominant_d']:.0f},{last['dominant']},"
                f"{last['wire_reduction_x']:.2f}",
                flush=True,
            )
    files = sorted(glob.glob(os.path.join(out_dir, "*.json")))
    if not files:
        emit("roofline/no_dryrun_artifacts", 0.0, "run launch.dryrun first")
        return
    print(
        "# arch,shape,mesh,step,compute_s,memory_s,collective_s,dominant,"
        "model_flops,useful_ratio,temp_GB"
    )
    for f in files:
        rec = json.load(open(f))
        if rec["mesh"] != "16x16":
            continue  # roofline table is single-pod (multi-pod = dry-run proof only)
        terms, dom, mf, ratio = analyze_record(rec)
        print(
            f"{rec['arch']},{rec['shape']},{rec['mesh']},{rec['step']},"
            f"{terms['compute']:.4e},{terms['memory']:.4e},"
            f"{terms['collective']:.4e},{dom},{mf:.3e},{ratio:.3f},"
            f"{rec.get('temp_size_in_bytes', 0)/1e9:.1f}",
            flush=True,
        )


if __name__ == "__main__":
    main(fast=False)
