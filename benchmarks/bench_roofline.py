"""Roofline report from the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh x step):
  compute term    = FLOPs / (chips * 197e12)
  memory term     = bytes / (chips * 819e9)
  collective term = collective_bytes / (chips * 50e9)   [per-device program:
                    collective bytes already per device => / link_bw]
plus MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (inference)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

FLOPs/bytes use the scan-corrected values when the probe succeeded.
"""
import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

from benchmarks.common import emit


def analyze_record(rec):
    chips = rec["n_devices"]
    flops = rec.get("flops_corrected", rec["flops"])
    byts = rec.get("bytes_corrected", rec["bytes"])
    coll = rec.get(
        "collective_bytes_corrected", rec["collective_bytes"].get("total", 0)
    )
    # cost_analysis is for the per-device partitioned program
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    n_active = rec.get("active_param_count", rec.get("param_count", 0))
    shape = rec["shape"]
    tokens = {
        "train_4k": 4096 * 256,
        "prefill_32k": 32768 * 32,
        "decode_32k": 128,
        "long_500k": 1,
    }.get(shape, 0)
    if rec["step"] in ("baseline", "btard"):
        model_flops = 6 * n_active * tokens
    else:
        model_flops = 2 * n_active * tokens
    ratio = model_flops / max(flops * chips, 1e-9)
    return terms, dominant, model_flops, ratio


def main(fast=True, out_dir="results/dryrun"):
    files = sorted(glob.glob(os.path.join(out_dir, "*.json")))
    if not files:
        emit("roofline/no_dryrun_artifacts", 0.0, "run launch.dryrun first")
        return
    print(
        "# arch,shape,mesh,step,compute_s,memory_s,collective_s,dominant,"
        "model_flops,useful_ratio,temp_GB"
    )
    for f in files:
        rec = json.load(open(f))
        if rec["mesh"] != "16x16":
            continue  # roofline table is single-pod (multi-pod = dry-run proof only)
        terms, dom, mf, ratio = analyze_record(rec)
        print(
            f"{rec['arch']},{rec['shape']},{rec['mesh']},{rec['step']},"
            f"{terms['compute']:.4e},{terms['memory']:.4e},"
            f"{terms['collective']:.4e},{dom},{mf:.3e},{ratio:.3f},"
            f"{rec.get('temp_size_in_bytes', 0)/1e9:.1f}",
            flush=True,
        )


if __name__ == "__main__":
    main(fast=False)
