"""Paper App. I.2: BTARD overhead vs plain All-Reduce.

Three views:
  * measured step time of the butterfly robust aggregation + verification
    tables vs a plain mean over stacked peer gradients, as d grows, for both
    the pure-jnp pipeline and the fused Pallas kernel (interpret mode on
    CPU — the interpreter is slow, so the *pass model* is the bandwidth
    signal there; on a TPU set REPRO_PALLAS_COMPILE=1);
  * the HBM-pass model: the seed kernel family streamed the (n, d) peer
    stack 2*n_iters + 1 times per aggregation (norm phase + update phase per
    clip iteration, then a standalone table pass); the fused kernel's
    incremental-norm recurrence + verification epilogue does it in
    n_iters + 2 (see src/repro/kernels/DESIGN.md);
  * the communication model: per-peer bytes for AR vs BTARD
    (2d for ring/butterfly AR; BTARD adds O(n^2) scalars — independent of d,
    exactly the paper's §3.1 cost accounting).

Emits BENCH_overhead.json next to this file so the perf trajectory is
machine-trackable across PRs.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timer
from repro.core.butterfly import (
    butterfly_clip,
    butterfly_clip_verified,
    get_random_directions,
    verification_tables,
)

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "BENCH_overhead.json")


def comm_model(n, d, bytes_per=4):
    ar = 2 * d * bytes_per  # reduce-scatter + all-gather per peer
    btard_extra = (2 * n * n + 3 * n) * bytes_per  # s-table, norms, hashes, mprng
    return ar, btard_extra


def hbm_pass_model(n_iters, n, d, bytes_per=4):
    """HBM traffic of the full aggregation workload per robust all-reduce:
    across all n partitions the streamed stack totals n * d values (each
    partition is an (n, d/n) peer stack).

    seed two-phase kernel + standalone table kernel: 2*n_iters + 1 passes;
    fused incremental-norm kernel with verification epilogue: n_iters + 2.
    """
    stack = n * d * bytes_per
    return {
        "seed_passes": 2 * n_iters + 1,
        "fused_passes": n_iters + 2,
        "seed_bytes": (2 * n_iters + 1) * stack,
        "fused_bytes": (n_iters + 2) * stack,
        "pass_speedup": (2 * n_iters + 1) / (n_iters + 2),
    }


def main(fast=True):
    n, n_iters = 16, 20
    dims = [1 << 14, 1 << 17] if fast else [1 << 14, 1 << 17, 1 << 20, 1 << 23]
    # interpret-mode pallas is CPU-interpreter-bound; keep its sizes sane
    fused_dims = [d for d in dims if d <= 1 << 17]
    records = []
    for d in dims:
        g = jax.random.normal(jax.random.key(0), (n, d))
        z = get_random_directions(7, n, -(-d // n))

        mean_fn = jax.jit(lambda x: x.mean(0))
        us_mean = timer(mean_fn, g, reps=10)

        def full_btard(x):
            agg, parts = butterfly_clip(x, tau=1.0, n_iters=n_iters)
            s, norms = verification_tables(parts, agg, z, 1.0)
            return agg, s, norms

        us_btard = timer(jax.jit(full_btard), g, reps=5)

        us_fused = None
        if d in fused_dims:
            def fused_btard(x):
                agg, _parts, s, norms = butterfly_clip_verified(
                    x, 1.0, z, n_iters=n_iters, use_pallas=True
                )
                return agg, s, norms

            us_fused = timer(jax.jit(fused_btard), g, reps=3)

        ar, extra = comm_model(n, d)
        passes = hbm_pass_model(n_iters, n, d)
        emit(
            f"overhead/d={d}",
            us_btard,
            f"mean_us={us_mean:.1f};overhead_x={us_btard/max(us_mean,1e-9):.2f};"
            f"fused_us={-1.0 if us_fused is None else us_fused:.1f};"
            f"passes_seed={passes['seed_passes']};passes_fused={passes['fused_passes']};"
            f"pass_speedup={passes['pass_speedup']:.2f};"
            f"comm_ar_bytes={ar};comm_btard_extra_bytes={extra};"
            f"extra_frac={extra/ar:.4f}",
        )
        records.append(
            {
                "d": d,
                "n_peers": n,
                "n_iters": n_iters,
                "mean_us": us_mean,
                "btard_jnp_us": us_btard,
                "btard_fused_interpret_us": us_fused,
                "overhead_x": us_btard / max(us_mean, 1e-9),
                "hbm_pass_model": passes,
                "comm_ar_bytes": ar,
                "comm_btard_extra_bytes": extra,
            }
        )
    payload = {
        "bench": "overhead",
        "backend": jax.default_backend(),
        "pallas_mode": "interpret"
        if os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"
        else "compiled",
        "records": records,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    main(fast=False)
