"""Paper App. I.2: BTARD overhead vs plain All-Reduce.

Four views:
  * measured step time of the butterfly robust aggregation + verification
    tables vs a plain mean over stacked peer gradients, as d grows, for both
    the pure-jnp pipeline and the fused Pallas kernel (interpret mode on
    CPU — the interpreter is slow, so the *pass model* is the bandwidth
    signal there; on a TPU set REPRO_PALLAS_COMPILE=1);
  * the HBM-pass model: the seed kernel family streamed the (n, d) peer
    stack 2*n_iters + 1 times per aggregation (norm phase + update phase per
    clip iteration, then a standalone table pass); the fused kernel's
    incremental-norm recurrence + verification epilogue does it in
    n_iters + 2 (see src/repro/kernels/DESIGN.md);
  * the communication model: per-peer bytes for AR vs BTARD
    (2d for ring/butterfly AR; BTARD adds O(n^2) scalars — independent of d,
    exactly the paper's §3.1 cost accounting), now PER AGGREGATOR SPEC:
    verifiable specs (the flagship and every verified:* wrapper) ride the
    butterfly at O(d) per peer plus size-independent table bytes, while the
    unwrapped baselines pay the trusted-PS O(n*d) all_gather; compressed:*
    specs carry per-codec ``bytes_on_wire`` / ``wire_reduction_x`` columns
    (int8 ~4x fewer all_to_all bytes; regression-gated);
  * the scan-engine view: steps/s of the legacy host protocol loop vs the
    jitted lax.scan ProtocolState engine (core.engine), at the default
    clip_iters=60 and at warm-start clip_iters=15 -> BENCH_scan.json;
  * the flat-cost scaling curve (n in {16, 64, 256, 1024}): per-peer table
    bytes + measured engine throughput/bans under sampled-digest audits and
    the hierarchical butterfly-of-butterflies (core.hierarchy), plus the
    per-phase SYMBOLIC comm model (sympy) cross-checked against the
    implementation — both gated in check_regression.py.

Emits BENCH_overhead.json + BENCH_scan.json next to this file (or --out-dir)
so the perf trajectory is machine-trackable across PRs; CI regenerates both
with --quick and gates merges on benchmarks/check_regression.py.
"""
import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timer
from repro.core.butterfly import (
    butterfly_clip,
    butterfly_clip_verified,
    get_random_directions,
    verification_tables,
)

_DIR = os.path.dirname(os.path.abspath(__file__))
JSON_PATH = os.path.join(_DIR, "BENCH_overhead.json")
SCAN_JSON_PATH = os.path.join(_DIR, "BENCH_scan.json")


def comm_model(n, d, bytes_per=4, payload_bytes=None, sidecar_bytes=0):
    """AR vs BTARD per-peer bytes, parameterized by the gradient payload
    dtype: ``payload_bytes`` is the bytes/coordinate on the butterfly
    all_to_all leg (defaults to ``bytes_per``, the f32 baseline; compressed
    specs ship 1-2), ``sidecar_bytes`` the codec sidecar traffic (one f32
    scale per payload each way). Returns (ar, btard_extra, bytes_on_wire)
    where bytes_on_wire is the all_to_all payload leg — the bytes a wire
    codec actually compresses."""
    pb = bytes_per if payload_bytes is None else payload_bytes
    ar = 2 * d * bytes_per  # reduce-scatter + all-gather per peer
    btard_extra = (2 * n * n + 3 * n) * bytes_per  # s-table, norms, hashes, mprng
    bytes_on_wire = d * pb + sidecar_bytes
    return ar, btard_extra, bytes_on_wire


def comm_model_per_spec(n, d, bytes_per=4):
    """Per-peer communication bytes per robust all-reduce, by registered
    AggregatorSpec (launch/steps.aggregation_stage topologies):

    * verifiable specs (butterfly_clip + every verified:* wrapper) run the
      butterfly — all_to_all its d/n-sized partition to every peer (~d
      sent) + the aggregated-partition all_gather (~d received) + the
      O(n^2)-scalar broadcast tables, independent of d;
    * compressed:* specs additionally quantize the all_to_all payload to
      their wire codec (int8: 1 byte/coordinate + one f32 scale sidecar
      per payload each way; bf16: 2 bytes) — ``bytes_on_wire`` is that
      compressed leg and ``wire_reduction_x`` its reduction vs the f32
      butterfly payload (the regression-gated codec claim); the aggregate
      all_gather rides the transport dtype, codec-independent;
    * non-verifiable specs all_gather the FULL peer stack (the trusted-PS
      model): n*d received per peer, zero tables.

    This is the paper's §3.1 cost accounting extended across the spec
    registry: wrapping a baseline into its verified: form REPLACES the
    O(n*d) PS gather with the O(d)-per-peer butterfly plus size-independent
    table traffic — verification makes the communication model BETTER, not
    worse, for n > 2 — and the compressed: wrapper then shrinks the
    dominant butterfly leg by ~4x (int8) on top.
    """
    from repro.core import compression as comp
    from repro.core.aggregators import REGISTRY, AggregatorSpec

    out = {}

    def cell(defn, payload_bytes, sidecar):
        if defn.verifiable:
            table = (2 * n * n + 3 * n) * bytes_per
            _, _, wire = comm_model(
                n, d, bytes_per, payload_bytes, sidecar
            )
            # + the aggregated-partition all_gather (transport dtype)
            per_peer = wire + d * bytes_per + table
            topology = "butterfly"
        else:
            table = 0
            wire = (n + 1) * d * bytes_per  # send d, gather the n*d stack
            per_peer = wire
            topology = "ps_all_gather"
        return {
            "topology": topology,
            "payload_bytes_per_coord": payload_bytes,
            "sidecar_bytes": sidecar,
            "bytes_on_wire": wire,
            "per_peer_bytes": per_peer,
            "table_bytes": table,
            "per_peer_over_ar": per_peer / (2 * d * bytes_per),
            # the codec claim: f32 all_to_all leg / this spec's leg
            "wire_reduction_x": (d * bytes_per) / wire
            if topology == "butterfly" else 1.0,
        }

    for name, defn in sorted(REGISTRY.items()):
        if name.startswith(comp.PREFIX):
            codec = comp.codec_of(AggregatorSpec(name))  # declared default
            out[name] = cell(
                defn, comp.CODEC_BYTES[codec], 2 * n * bytes_per
            )
            # the non-default codec variant, same spec machinery
            for alt in comp.CODECS:
                if alt != codec:
                    out[f"{name}:codec={alt}"] = cell(
                        defn, comp.CODEC_BYTES[alt], 2 * n * bytes_per
                    )
        else:
            out[name] = cell(defn, bytes_per, 0)
    return out


def symbolic_comm_model(bytes_per=4):
    """Per-phase SYMBOLIC communication-complexity model (sympy) of one
    robust all-reduce round, per verification mode — the closed forms the
    numeric models above instantiate, kept as expressions so the asymptotic
    claims (table bytes O(n^2) -> O(n*k) -> O(n^2/g + g^2)) are
    machine-checkable rather than prose.

    Symbols: n peers, d gradient dim, g groups, k sampled digest columns
    per step (k = m_validators * audit_k), b bytes/scalar. Phases follow
    launch/steps.aggregation_stage: the gradient all_to_all (~d sent per
    peer), the aggregate all_gather (~d received), and the verification
    table broadcast (digest + norm columns + the 3n checksum/vote/hash
    sidecars; hierarchical mode adds the g x g level-2 digest exchange).

    Every expression is cross-checked numerically against
    repro.core.hierarchy.table_scalars at the evaluation points — the gate
    in check_regression.py fails if the symbolic and implemented models
    ever drift apart. Returns a JSON-ready dict (expressions as strings).
    """
    import sympy as sp

    from repro.core import hierarchy as hier

    n, d, g, k, b = sp.symbols("n d g k b", positive=True)
    gs = n / g

    class Communication:
        """Accumulates per-phase symbolic costs (pia-mpc complexity idiom):
        one expression per protocol phase, summed into the per-peer round
        total."""

        def __init__(self):
            self.phases = {}

        def add(self, phase, expr):
            self.phases[phase] = sp.expand(self.phases.get(phase, 0) + expr)

        def total(self):
            return sp.expand(sum(self.phases.values(), sp.Integer(0)))

        def table_total(self):
            return sp.expand(sum(
                (e for p, e in self.phases.items() if "table" in p
                 or "digest" in p), sp.Integer(0)))

        def as_dict(self):
            return {p: str(e) for p, e in self.phases.items()}

    def build(mode):
        c = Communication()
        c.add("gradient_all_to_all", d * b)  # each peer ships d coords total
        c.add("aggregate_all_gather", d * b)
        if mode == "full":
            c.add("table_broadcast", (2 * n**2 + 3 * n) * b)
        elif mode == "sampled":
            # only the k sampled digest columns broadcast; checksum/vote/
            # hash sidecars stay per-column-owner (3n)
            c.add("table_broadcast", (2 * n * k + 3 * n) * b)
        elif mode == "hierarchical":
            c.add("table_broadcast", (2 * gs**2 + 3 * gs) * b)
            c.add("level2_digest_exchange", (2 * g**2 + 3 * g) * b)
        elif mode == "hierarchical_sampled":
            # k <= gs columns sampled within each group
            c.add("table_broadcast", (2 * gs * k + 3 * gs) * b)
            c.add("level2_digest_exchange", (2 * g**2 + 3 * g) * b)
        return c

    modes = {m: build(m) for m in (
        "full", "sampled", "hierarchical", "hierarchical_sampled")}
    full_tables = modes["full"].table_total()

    # numeric cross-check vs the implemented model (core.hierarchy):
    # sympy expression == table_scalars() at every evaluation point, exactly
    points = [
        {"n": 64, "g": 8, "k": 2},
        {"n": 256, "g": 16, "k": 4},
        {"n": 1024, "g": 32, "k": 4},
    ]
    checks = []
    for pt in points:
        subs = {n: pt["n"], g: pt["g"], k: pt["k"], b: 1}
        impl = {
            "full": hier.table_scalars(pt["n"]),
            "sampled": hier.table_scalars(
                pt["n"], m_validators=1, audit_k=pt["k"]),
            "hierarchical": hier.table_scalars(pt["n"], groups=pt["g"]),
            "hierarchical_sampled": hier.table_scalars(
                pt["n"], m_validators=1, audit_k=pt["k"], groups=pt["g"]),
        }
        sym = {m: int(c.table_total().subs(subs)) for m, c in modes.items()}
        checks.append({
            "point": pt,
            "symbolic": sym,
            "implemented": impl,
            "match": sym == impl,
        })

    return {
        "symbols": {"n": "peers", "d": "gradient dim", "g": "groups",
                    "k": "sampled digest columns/step (m_validators*audit_k)",
                    "b": "bytes/scalar"},
        "phases": {m: c.as_dict() for m, c in modes.items()},
        "per_peer_total": {m: str(c.total()) for m, c in modes.items()},
        "table_bytes": {m: str(c.table_total()) for m, c in modes.items()},
        "table_ratio_vs_full": {
            m: str(sp.simplify(c.table_total() / full_tables))
            for m, c in modes.items()
        },
        "cross_check": checks,
        "bytes_per": bytes_per,
    }


def _detect_bound(n, m_val, groups, audit_k=None):
    """Steps until the sign_flip workload's Byzantine peers are provably
    banned. Hierarchical full-table mode trips the GROUP-majority
    Delta_max vote within a step or two — a lone sign-flipper shifts its
    gs-peer group mean far past delta_max for every member, and the vote
    + exoneration recompute bans exactly the cheater. Under sampled
    digests the vote only sees SAMPLED columns (the zero-scatter
    invariant zeroes unsampled norms on both sides), so the composed
    mode's time-to-ban is the age-priority column draw reaching the
    cheater's own column — the staleness window ceil(n/(m*k)) + 2 — or
    the validator peer-audit backstop, whichever is sooner. Flat modes at
    larger n dilute the corruption across the global mean (V3 stays
    silent), so time-to-ban is that audit backstop alone: age-priority
    CHOOSETARGET covers every peer within ~ceil(n/m) steps. The +slack
    absorbs validator rotation (a peer serving as validator is not
    auditable that step)."""
    audit_cover = math.ceil(n / m_val)
    if groups:
        if audit_k is None:
            return 12
        staleness = math.ceil(n / (m_val * audit_k)) + 2
        return min(staleness, audit_cover) + 10
    return audit_cover + 10


def flat_cost_scaling(fast=True):
    """The tentpole scaling curve: per-peer verification-table bytes
    (analytic — core.hierarchy.table_scalars) and measured scan-engine
    throughput + ban behaviour as n grows, for the four mode combinations
    {full, sampled, hierarchical, hierarchical+sampled}.

    The analytic rows cover every n; the measured rows run the full
    ProtocolState engine (sign_flip Byzantine workload, Delta_max votes +
    validator audits live) on the n's a CI runner can afford — quick mode
    stops at 64, full mode at 1024. Each cell runs for its mode's
    :func:`_detect_bound` steps (capped), so the ban outcome is a
    guarantee check, not a race: cells whose bound fits under the cap
    carry ``bans_gated=True`` and check_regression.py requires
    ``bans_exact`` there; over-cap cells (flat modes at n=1024 — the
    audit backstop needs ~n/m steps — and the composed mode at n=1024,
    whose column-staleness window is ~n/(m*k)) are throughput-only,
    gated on zero honest bans. Also gated: at n=1024 the hierarchical+sampled per-peer
    table bytes must be <= 10% of full.
    """
    from repro.core import hierarchy as hier
    from repro.core.engine import EngineConfig, init_state, make_scan_runner

    M_VAL, AUDIT_K = 2, 2
    step_cap = 64 if fast else 160
    ns = [16, 64, 256, 1024]
    measured_ns = [16, 64] if fast else [16, 64, 256, 1024]
    rows = []
    for n in ns:
        g = int(np.sqrt(n))
        modes = {
            "full": {},
            "sampled": {"audit_k": AUDIT_K},
            "hierarchical": {"groups": g},
            "hierarchical_sampled": {"audit_k": AUDIT_K, "groups": g},
        }
        table_bytes = {
            m: hier.table_bytes(
                n, m_validators=M_VAL, audit_k=kw.get("audit_k"),
                groups=kw.get("groups"),
            )
            for m, kw in modes.items()
        }
        row = {
            "n": n,
            "groups": g,
            "audit_k": AUDIT_K,
            "m_validators": M_VAL,
            "table_bytes": table_bytes,
            "table_frac_vs_full": {
                m: tb / table_bytes["full"] for m, tb in table_bytes.items()
            },
        }
        if n in measured_ns:
            d = 4 * n
            # one Byzantine per far-apart group so no group is majority-Byz
            byz_ids = (0, n // 2)
            byz = jnp.zeros((n,)).at[jnp.asarray(byz_ids)].set(1.0)
            measured = {}
            for m, kw in modes.items():
                bound = _detect_bound(
                    n, M_VAL, kw.get("groups"), kw.get("audit_k")
                )
                gated = bound <= step_cap
                # over-cap cells (flat audit coverage ~n/m steps at
                # n=1024) are throughput-only: short program, bans
                # reported but not gated
                steps = bound if gated else 12
                cfg = EngineConfig(
                    n=n, d=d, attack="sign_flip", lam=100.0, start_step=0,
                    clip_iters=5, m_validators=M_VAL, delta_max=25.0,
                    aggregator="verified:mean", **kw,
                )
                runner = make_scan_runner(
                    cfg, _scaling_grads_fn(n, d), steps
                )
                st0 = init_state(cfg, seed=0)
                params = jnp.zeros(())
                state, _, outs = runner(st0, byz, params)  # warmup+trace
                jax.block_until_ready(state)
                reps = 1 if steps >= 48 else 2
                best = float("inf")
                for _ in range(reps):
                    t0 = time.perf_counter()
                    state, _, outs = runner(st0, byz, params)
                    jax.block_until_ready(state)
                    best = min(best, time.perf_counter() - t0)
                banned = sorted(
                    int(i)
                    for i in np.nonzero(np.asarray(state.ban_step) >= 0)[0]
                )
                measured[m] = {
                    "steps": steps,
                    "detect_bound": bound,
                    "bans_gated": gated,
                    "steps_per_s": steps / best,
                    "banned": banned,
                    "byzantine": list(byz_ids),
                    "bans_exact": banned == sorted(byz_ids),
                    "honest_banned": sorted(
                        set(banned) - set(int(i) for i in byz_ids)
                    ),
                }
                emit(
                    f"overhead/scaling/n={n}/{m}",
                    1e6 * best / steps,
                    f"sps={steps / best:.1f};"
                    f"table_bytes={table_bytes[m]};"
                    f"frac={row['table_frac_vs_full'][m]:.4f};"
                    f"steps={steps};gated={gated};"
                    f"bans_exact={measured[m]['bans_exact']}",
                )
            row["measured"] = measured
        rows.append(row)
    return {"step_cap": step_cap, "rows": rows}


def _scaling_grads_fn(n, d):
    """Honest per-step gradients for the scaling bench: unit-variance
    noise around a fixed descent direction; the engine's phase_attack
    applies the configured Byzantine corruption itself."""
    mu = jax.random.normal(jax.random.key(7), (d,)) * 0.1

    def grads_fn(params, t, flips):
        key = jax.random.fold_in(jax.random.key(1), t)
        G = mu[None] + jax.random.normal(key, (n, d), jnp.float32)
        return G, G

    return grads_fn


def hbm_pass_model(n_iters, n, d, bytes_per=4, adaptive_iters=2):
    """HBM traffic of the full aggregation workload per robust all-reduce:
    across all n partitions the streamed stack totals n * d values (each
    partition is an (n, d/n) peer stack).

    seed two-phase kernel + standalone table kernel: 2*n_iters + 1 passes;
    fused incremental-norm kernel with verification epilogue: n_iters + 2;
    adaptive early-exit driver: iters_run + 2 (jnp prologue + one pass per
    iteration actually run + the single verification epilogue) —
    ``adaptive_iters`` is the warm-start steady-state iteration count
    (measured 1-2 on the convergence workloads, vs the fixed 60 budget).
    """
    stack = n * d * bytes_per
    return {
        "seed_passes": 2 * n_iters + 1,
        "fused_passes": n_iters + 2,
        "adaptive_passes": adaptive_iters + 2,
        "seed_bytes": (2 * n_iters + 1) * stack,
        "fused_bytes": (n_iters + 2) * stack,
        "adaptive_bytes": (adaptive_iters + 2) * stack,
        "pass_speedup": (2 * n_iters + 1) / (n_iters + 2),
        "adaptive_pass_speedup": (n_iters + 2) / (adaptive_iters + 2),
    }


# (d_model, vocab_size) ladder for the real-model scaling curve: reduced
# ALBERT scaled along width AND vocab so params grow ~geometrically. Quick
# mode runs the first three (CI-affordable on CPU); full mode appends the
# d512/30k-vocab point (~39M params, the committed-baseline ceiling).
MODEL_SCALING_SIZES = ((128, 2048), (192, 4096), (256, 8192))
MODEL_SCALING_SIZES_FULL = MODEL_SCALING_SIZES + ((512, 30000),)
MODEL_SCALING_AGG = "compressed:verified:mean:codec=bf16"


def model_scaling_bench(fast=True, steps=4, n_peers=4, seq_len=16, batch=2):
    """Real-model gauntlet scaling curve: model size (flat gradient dim d)
    vs measured scanned-BTARD steps/s, per-peer wire bytes, and table
    overhead fraction, under the bf16 wire codec with full verification and
    one sign-flip Byzantine peer. The byte columns are analytic
    (:func:`comm_model` — same accounting as comm_per_spec); the ban
    columns are protocol guarantees (the attacker must be banned, no honest
    peer ever accused); steps/s is the one wall-clock column.

    The paper's flat-cost claim, restated on real models: table bytes are
    size-INDEPENDENT, so table overhead fraction must fall as the model
    grows while the wire bytes track d exactly.
    """
    import dataclasses

    from repro.configs import get_config, reduce_config
    from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
    from repro.core.compression import CODEC_BYTES
    from repro.data import TokenPipeline
    from repro.models.model import Model
    from repro.optim import sgd

    cfg0 = reduce_config(get_config("albert-large"))
    sizes = MODEL_SCALING_SIZES if fast else MODEL_SCALING_SIZES_FULL
    byz = (n_peers - 1,)
    rows = []
    for dm, vocab in sizes:
        cfg = dataclasses.replace(
            cfg0, name=f"albert-d{dm}-v{vocab}", d_model=dm, d_ff=4 * dm,
            n_heads=max(2, dm // 64), n_kv_heads=max(2, dm // 64),
            head_dim=64, vocab_size=vocab,
        )
        m = Model(cfg)
        pipe = TokenPipeline(vocab, seq_len, batch)
        tr = BTARDTrainer(
            lambda p, b, m=m: m.loss_fn(p, b)[0],
            m.init_params(jax.random.key(0)),
            lambda peer, step, flipped, pipe=pipe: pipe.device_batch(step, peer),
            TrainerConfig(
                n_peers=n_peers, byzantine=byz,
                attack=AttackConfig(kind="sign_flip", start_step=0),
                defense="btard", aggregator=MODEL_SCALING_AGG,
                tau=2.0, clip_iters=5, m_validators=1,
            ),
            optimizer=sgd(0.05),
        )
        d = tr.d
        tr.run_scan(steps)  # warmup: trace + compile (bans land here)
        best = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            tr.run_scan(steps)
            best = min(best, time.perf_counter() - t0)
        pb = CODEC_BYTES["bf16"]
        _, table, wire = comm_model(
            n_peers, d, 4, payload_bytes=pb, sidecar_bytes=2 * n_peers * 4
        )
        per_peer = wire + d * 4 + table  # + aggregate all_gather (transport)
        row = {
            "name": cfg.name,
            "params": d,
            "d_model": dm,
            "vocab": vocab,
            "steps_per_s": steps / best,
            "payload_bytes_per_coord": pb,
            "wire_bytes_per_peer": wire,
            "per_peer_bytes": per_peer,
            "table_bytes": table,
            "table_overhead_frac": table / per_peer,
            "byzantine": sorted(byz),
            "banned": sorted(tr.banned),
            "honest_banned": sorted(set(tr.banned) - set(byz)),
        }
        rows.append(row)
        emit(
            f"overhead/model_scaling/{cfg.name}",
            1e6 * best / steps,
            f"params={d};sps={row['steps_per_s']:.2f};"
            f"wire={wire};table_frac={row['table_overhead_frac']:.2e};"
            f"banned={row['banned']}",
        )
    return {
        "arch": "albert-large (reduced, scaled)",
        "aggregator": MODEL_SCALING_AGG,
        "n_peers": n_peers,
        "seq_len": seq_len,
        "batch": batch,
        "steps": steps,
        "rows": rows,
    }


def scan_engine_bench(steps=None, fast=True, out_dir=None):
    """Legacy host loop vs jitted lax.scan ProtocolState engine: steps/s on
    the controlled classification workload (16 peers, 7 Byzantine,
    sign-flip), at clip_iters=60 (the protocol default), at the warm-start
    budget clip_iters=15, and with the adaptive early-exit budget
    (``adaptive_tol``, cap 60) — plus adaptive-vs-fixed CURVES so the
    budget/steps-per-second trade-off is machine-trackable. Writes
    BENCH_scan.json."""
    from benchmarks.common import classification_setup
    from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
    from repro.optim import sgd

    if steps is None:
        # 30-step sections put the jit-dispatch overhead at ~30% of the
        # measurement and compress the adaptive-vs-fixed ratio; 60 keeps
        # quick mode quick while the ratio tracks the full-mode value
        steps = 60 if fast else 100
    scan_json = os.path.join(out_dir or _DIR, "BENCH_scan.json")
    # dim=512 -> d ≈ 2k: CenteredClip is a real fraction of the step, so
    # the adaptive-vs-fixed ratio measures the clip budget rather than
    # per-step dispatch jitter (at the tests' dim=16 the clip is ~nothing
    # and the ratio is noise-bound)
    loss_fn, params0, batch_fn, accuracy = classification_setup(dim=512)

    def make(clip_iters, warm_start=False, adaptive_tol=None,
             defense="btard"):
        cfg = TrainerConfig(
            n_peers=16,
            byzantine=tuple(range(9, 16)),
            attack=AttackConfig(kind="sign_flip", start_step=5),
            defense=defense,
            tau=1.0,
            clip_iters=clip_iters,
            m_validators=2,
            seed=0,
            warm_start=warm_start,
            adaptive_tol=adaptive_tol,
        )
        return BTARDTrainer(
            loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.3, momentum=0.9)
        )

    def time_run(method, clip_iters, warm_start=False, adaptive_tol=None,
                 reps=None):
        tr = make(clip_iters, warm_start, adaptive_tol)
        fn = getattr(tr, method)
        fn(steps)  # warmup: traces + compiles everything
        if reps is None:
            # a 30-step scan section is ~10 ms — single-shot timing is
            # dispatch-jitter noise, so take best-of-many for the fast
            # methods (the legacy host loop is 50x slower; 2 reps suffice)
            reps = 2 if method == "run" else 8
        best = float("inf")
        for _ in range(reps):  # best-of-reps: steady state (bans settled —
            t0 = time.perf_counter()  # the regime a long run lives in)
            fn(steps)
            best = min(best, time.perf_counter() - t0)
        iters = [
            h["clip_iters_used"]
            for h in tr.history[steps:]
            if "clip_iters_used" in h
        ]
        cell = {
            "steps_per_s": steps / best,
            "clip_iters": clip_iters,
            "acc": accuracy(tr.unraveled_params()),
            "banned": len(tr.banned),
        }
        if warm_start:
            cell["warm_start"] = True
        if adaptive_tol is not None:
            cell["adaptive_tol"] = adaptive_tol
            cell["clip_iters_used_mean"] = float(np.mean(iters)) if iters else None
        return cell

    loop = time_run("run", 60, reps=1)
    scan = time_run("run_scan", 60)
    warm = time_run("run_scan", 15, warm_start=True)
    # the device-resident default: adaptive early exit at the protocol-default
    # cap (60) with warm start — the acceptance headline vs the fixed scan
    adaptive = time_run("run_scan", 60, warm_start=True, adaptive_tol=1e-4)

    # headline ratio from INTERLEAVED paired timing: the two cells alternate
    # within one loop, so a machine-wide slowdown (CI runners!) hits both
    # symmetrically and best-of picks each cell's cleanest samples — the
    # independently-timed cells above keep the absolute steps/s numbers
    tr_fixed = make(60)
    tr_adapt = make(60, warm_start=True, adaptive_tol=1e-4)
    tr_fixed.run_scan(steps)
    tr_adapt.run_scan(steps)
    best_fixed = best_adapt = float("inf")
    for _ in range(8):
        t0 = time.perf_counter()
        tr_fixed.run_scan(steps)
        best_fixed = min(best_fixed, time.perf_counter() - t0)
        t0 = time.perf_counter()
        tr_adapt.run_scan(steps)
        best_adapt = min(best_adapt, time.perf_counter() - t0)
    adaptive_vs_scan = best_fixed / max(best_adapt, 1e-9)

    # --- the AggregatorSpec comparison axis: every registered aggregator
    # through the SAME scanned engine on the same attacked workload. The
    # block existing at all proves each spec is jit/scan-clean; the
    # flagship's advantage over the fixed scan stays gated separately
    # (adaptive_speedup_vs_scan_x >= 1.15 in check_regression.py).
    from repro.core.aggregators import REGISTRY, registered_aggregators

    agg_steps = max(steps // 2, 20)
    aggregator_comparison = {}
    for name in registered_aggregators():
        defense = "btard" if name == "butterfly_clip" else name
        tr = make(60, warm_start=name == "butterfly_clip",
                  adaptive_tol=1e-4 if name == "butterfly_clip" else None,
                  defense=defense)
        tr.run_scan(agg_steps)  # warmup: trace + compile
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            tr.run_scan(agg_steps)
            best = min(best, time.perf_counter() - t0)
        aggregator_comparison[name] = {
            "steps_per_s": agg_steps / best,
            "acc": accuracy(tr.unraveled_params()),
            "banned": len(tr.banned),
            "verifiable": REGISTRY[name].verifiable,
        }
        emit(
            f"overhead/aggregator/{name}",
            1e6 * best / agg_steps,
            f"sps={agg_steps / best:.1f};"
            f"acc={aggregator_comparison[name]['acc']:.3f};"
            f"banned={aggregator_comparison[name]['banned']}",
        )

    fixed_curve = [scan, warm] + [time_run("run_scan", 30)]
    adaptive_curve = [
        time_run("run_scan", 60, warm_start=True, adaptive_tol=tol)
        for tol in (1e-2, 1e-6)
    ] + [adaptive]
    payload = {
        "bench": "scan_engine",
        "backend": jax.default_backend(),
        "steps": steps,
        "n_peers": 16,
        "legacy_loop": loop,
        "scan_engine": scan,
        "scan_engine_warm15": warm,
        "scan_engine_adaptive": adaptive,
        "aggregator_comparison": aggregator_comparison,
        # real-model gauntlet: scanned BTARD over scaled zoo LMs
        "model_scaling": model_scaling_bench(fast=fast),
        "fixed_curve": fixed_curve,
        "adaptive_curve": adaptive_curve,
        "scan_speedup_x": scan["steps_per_s"] / max(loop["steps_per_s"], 1e-9),
        "warm_speedup_x": warm["steps_per_s"] / max(loop["steps_per_s"], 1e-9),
        "adaptive_speedup_x": adaptive["steps_per_s"]
        / max(loop["steps_per_s"], 1e-9),
        # the acceptance ratio: adaptive early exit vs the PR 2 fixed-budget
        # scan path, both at protocol-default settings (cap/budget 60),
        # measured pairwise-interleaved (above)
        "adaptive_speedup_vs_scan_x": adaptive_vs_scan,
    }
    with open(scan_json, "w") as f:
        json.dump(payload, f, indent=2)
    emit(
        "overhead/scan_engine",
        1e6 / max(scan["steps_per_s"], 1e-9),
        f"loop_sps={loop['steps_per_s']:.1f};scan_sps={scan['steps_per_s']:.1f};"
        f"warm15_sps={warm['steps_per_s']:.1f};"
        f"adaptive_sps={adaptive['steps_per_s']:.1f};"
        f"speedup={payload['scan_speedup_x']:.1f}x;"
        f"adaptive_vs_scan={payload['adaptive_speedup_vs_scan_x']:.2f}x;"
        f"acc_loop={loop['acc']:.3f};acc_scan={scan['acc']:.3f};"
        f"acc_adaptive={adaptive['acc']:.3f};"
        f"iters_used={adaptive['clip_iters_used_mean']}",
    )
    print(f"wrote {scan_json}", flush=True)
    return payload


def main(fast=True, out_dir=None):
    if fast and out_dir is None:
        # quick mode must never clobber the committed (CI-gated, full-mode)
        # baselines: park its JSON in a scratch subdir unless the caller
        # explicitly chose a destination
        out_dir = os.path.join(_DIR, "quick")
        os.makedirs(out_dir, exist_ok=True)
        print(f"quick mode: writing BENCH_*.json to {out_dir} "
              "(committed baselines are full-mode; pass --out-dir to "
              "override)", flush=True)
    json_path = os.path.join(out_dir or _DIR, "BENCH_overhead.json")
    n, n_iters = 16, 20
    dims = [1 << 14, 1 << 17] if fast else [1 << 14, 1 << 17, 1 << 20, 1 << 23]
    # interpret-mode pallas is CPU-interpreter-bound; keep its sizes sane
    fused_dims = [d for d in dims if d <= 1 << 17]
    records = []
    for d in dims:
        g = jax.random.normal(jax.random.key(0), (n, d))
        z = get_random_directions(7, n, -(-d // n))

        mean_fn = jax.jit(lambda x: x.mean(0))
        us_mean = timer(mean_fn, g, reps=10)

        def full_btard(x):
            agg, parts = butterfly_clip(x, tau=1.0, n_iters=n_iters)
            s, norms = verification_tables(parts, agg, z, 1.0)
            return agg, s, norms

        us_btard = timer(jax.jit(full_btard), g, reps=5)

        us_fused = None
        if d in fused_dims:
            def fused_btard(x):
                agg, _parts, s, norms = butterfly_clip_verified(
                    x, 1.0, z, n_iters=n_iters, use_pallas=True
                )
                return agg, s, norms

            us_fused = timer(jax.jit(fused_btard), g, reps=3)

        ar, extra, _ = comm_model(n, d)
        passes = hbm_pass_model(n_iters, n, d)
        emit(
            f"overhead/d={d}",
            us_btard,
            f"mean_us={us_mean:.1f};overhead_x={us_btard/max(us_mean,1e-9):.2f};"
            f"fused_us={-1.0 if us_fused is None else us_fused:.1f};"
            f"passes_seed={passes['seed_passes']};passes_fused={passes['fused_passes']};"
            f"pass_speedup={passes['pass_speedup']:.2f};"
            f"comm_ar_bytes={ar};comm_btard_extra_bytes={extra};"
            f"extra_frac={extra/ar:.4f}",
        )
        records.append(
            {
                "d": d,
                "n_peers": n,
                "n_iters": n_iters,
                "mean_us": us_mean,
                "btard_jnp_us": us_btard,
                "btard_fused_interpret_us": us_fused,
                "overhead_x": us_btard / max(us_mean, 1e-9),
                "hbm_pass_model": passes,
                "comm_ar_bytes": ar,
                "comm_btard_extra_bytes": extra,
            }
        )
    # the tentpole scaling curve + the symbolic per-phase comm model: table
    # bytes flat in n under sampling/hierarchy, cross-checked sympy-vs-
    # implementation, with measured engine cells where CI can afford them
    scaling = flat_cost_scaling(fast=fast)
    symbolic = symbolic_comm_model()
    for chk in symbolic["cross_check"]:
        if not chk["match"]:
            emit("overhead/symbolic_mismatch", 1.0, str(chk))
    # per-aggregator communication model at the largest measured dim: the
    # verified: wrapper's butterfly O(d) per peer vs the PS O(n*d) gather
    comm_per_spec = comm_model_per_spec(n, dims[-1])
    for spec_name, cell in comm_per_spec.items():
        emit(
            f"overhead/comm/{spec_name}",
            cell["per_peer_bytes"] / 1e3,
            f"topology={cell['topology']};table_bytes={cell['table_bytes']};"
            f"per_peer_over_ar={cell['per_peer_over_ar']:.2f};"
            f"bytes_on_wire={cell['bytes_on_wire']};"
            f"wire_reduction={cell['wire_reduction_x']:.2f}x",
        )
    payload = {
        "bench": "overhead",
        "backend": jax.default_backend(),
        "pallas_mode": "interpret"
        if os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"
        else "compiled",
        "comm_per_spec": {"n_peers": n, "d": dims[-1], "specs": comm_per_spec},
        "flat_cost_scaling": scaling,
        "symbolic_comm": symbolic,
        "records": records,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {json_path}", flush=True)
    scan_engine_bench(fast=fast, out_dir=out_dir)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: small dims, 60-step scan cells, output "
                         "parked in benchmarks/quick/ unless --out-dir")
    ap.add_argument("--out-dir", default=None,
                    help="write BENCH_*.json here instead of benchmarks/ "
                         "(CI writes to a scratch dir and diffs against the "
                         "committed baselines via check_regression.py)")
    args = ap.parse_args()
    if args.out_dir:
        os.makedirs(args.out_dir, exist_ok=True)
    main(fast=args.quick, out_dir=args.out_dir)
