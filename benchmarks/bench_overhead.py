"""Paper App. I.2: BTARD overhead vs plain All-Reduce.

Two views:
  * measured step time of the butterfly robust aggregation vs a plain mean
    over stacked peer gradients, as d grows (CPU timings — relative overhead
    is the signal);
  * the communication model: per-peer bytes for AR vs BTARD
    (2d for ring/butterfly AR; BTARD adds O(n^2) scalars — independent of d,
    exactly the paper's §3.1 cost accounting).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timer
from repro.core.butterfly import butterfly_clip, get_random_directions, verification_tables


def comm_model(n, d, bytes_per=4):
    ar = 2 * d * bytes_per  # reduce-scatter + all-gather per peer
    btard_extra = (2 * n * n + 3 * n) * bytes_per  # s-table, norms, hashes, mprng
    return ar, btard_extra


def main(fast=True):
    n = 16
    dims = [1 << 14, 1 << 17] if fast else [1 << 14, 1 << 17, 1 << 20, 1 << 23]
    for d in dims:
        g = jax.random.normal(jax.random.key(0), (n, d))

        mean_fn = jax.jit(lambda x: x.mean(0))
        us_mean = timer(mean_fn, g, reps=10)

        def full_btard(x):
            agg, parts = butterfly_clip(x, tau=1.0, n_iters=20)
            z = get_random_directions(7, agg.shape[0], agg.shape[1])
            s, norms = verification_tables(parts, agg, z, 1.0)
            return agg, s, norms

        us_btard = timer(jax.jit(full_btard), g, reps=5)
        ar, extra = comm_model(n, d)
        emit(
            f"overhead/d={d}",
            us_btard,
            f"mean_us={us_mean:.1f};overhead_x={us_btard/max(us_mean,1e-9):.2f};"
            f"comm_ar_bytes={ar};comm_btard_extra_bytes={extra};"
            f"extra_frac={extra/ar:.4f}",
        )


if __name__ == "__main__":
    main(fast=False)
