"""Paper App. I.2: BTARD overhead vs plain All-Reduce.

Four views:
  * measured step time of the butterfly robust aggregation + verification
    tables vs a plain mean over stacked peer gradients, as d grows, for both
    the pure-jnp pipeline and the fused Pallas kernel (interpret mode on
    CPU — the interpreter is slow, so the *pass model* is the bandwidth
    signal there; on a TPU set REPRO_PALLAS_COMPILE=1);
  * the HBM-pass model: the seed kernel family streamed the (n, d) peer
    stack 2*n_iters + 1 times per aggregation (norm phase + update phase per
    clip iteration, then a standalone table pass); the fused kernel's
    incremental-norm recurrence + verification epilogue does it in
    n_iters + 2 (see src/repro/kernels/DESIGN.md);
  * the communication model: per-peer bytes for AR vs BTARD
    (2d for ring/butterfly AR; BTARD adds O(n^2) scalars — independent of d,
    exactly the paper's §3.1 cost accounting);
  * the scan-engine view: steps/s of the legacy host protocol loop vs the
    jitted lax.scan ProtocolState engine (core.engine), at the default
    clip_iters=60 and at warm-start clip_iters=15 -> BENCH_scan.json.

Emits BENCH_overhead.json + BENCH_scan.json next to this file so the perf
trajectory is machine-trackable across PRs.
"""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timer
from repro.core.butterfly import (
    butterfly_clip,
    butterfly_clip_verified,
    get_random_directions,
    verification_tables,
)

_DIR = os.path.dirname(os.path.abspath(__file__))
JSON_PATH = os.path.join(_DIR, "BENCH_overhead.json")
SCAN_JSON_PATH = os.path.join(_DIR, "BENCH_scan.json")


def comm_model(n, d, bytes_per=4):
    ar = 2 * d * bytes_per  # reduce-scatter + all-gather per peer
    btard_extra = (2 * n * n + 3 * n) * bytes_per  # s-table, norms, hashes, mprng
    return ar, btard_extra


def hbm_pass_model(n_iters, n, d, bytes_per=4):
    """HBM traffic of the full aggregation workload per robust all-reduce:
    across all n partitions the streamed stack totals n * d values (each
    partition is an (n, d/n) peer stack).

    seed two-phase kernel + standalone table kernel: 2*n_iters + 1 passes;
    fused incremental-norm kernel with verification epilogue: n_iters + 2.
    """
    stack = n * d * bytes_per
    return {
        "seed_passes": 2 * n_iters + 1,
        "fused_passes": n_iters + 2,
        "seed_bytes": (2 * n_iters + 1) * stack,
        "fused_bytes": (n_iters + 2) * stack,
        "pass_speedup": (2 * n_iters + 1) / (n_iters + 2),
    }


def scan_engine_bench(steps=None, fast=True):
    """Legacy host loop vs jitted lax.scan ProtocolState engine: steps/s on
    the controlled classification workload (16 peers, 7 Byzantine,
    sign-flip), at clip_iters=60 (the protocol default) and at the
    warm-start budget clip_iters=15. Writes BENCH_scan.json."""
    from benchmarks.common import classification_setup
    from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
    from repro.optim import sgd

    if steps is None:
        steps = 30 if fast else 100
    loss_fn, params0, batch_fn, accuracy = classification_setup()

    def make(clip_iters, warm_start=False):
        cfg = TrainerConfig(
            n_peers=16,
            byzantine=tuple(range(9, 16)),
            attack=AttackConfig(kind="sign_flip", start_step=5),
            defense="btard",
            tau=1.0,
            clip_iters=clip_iters,
            m_validators=2,
            seed=0,
            warm_start=warm_start,
        )
        return BTARDTrainer(
            loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.3, momentum=0.9)
        )

    def time_run(method, clip_iters, warm_start=False):
        tr = make(clip_iters, warm_start)
        getattr(tr, method)(steps)  # warmup: traces + compiles everything
        t0 = time.perf_counter()
        getattr(tr, method)(steps)  # steady state (bans settled — the
        dt = time.perf_counter() - t0  # regime a long run lives in)
        return steps / dt, accuracy(tr.unraveled_params()), len(tr.banned)

    loop_sps, loop_acc, loop_ban = time_run("run", 60)
    scan_sps, scan_acc, scan_ban = time_run("run_scan", 60)
    warm_sps, warm_acc, warm_ban = time_run("run_scan", 15, warm_start=True)
    payload = {
        "bench": "scan_engine",
        "backend": jax.default_backend(),
        "steps": steps,
        "n_peers": 16,
        "legacy_loop": {
            "steps_per_s": loop_sps, "clip_iters": 60,
            "acc": loop_acc, "banned": loop_ban,
        },
        "scan_engine": {
            "steps_per_s": scan_sps, "clip_iters": 60,
            "acc": scan_acc, "banned": scan_ban,
        },
        "scan_engine_warm15": {
            "steps_per_s": warm_sps, "clip_iters": 15, "warm_start": True,
            "acc": warm_acc, "banned": warm_ban,
        },
        "scan_speedup_x": scan_sps / max(loop_sps, 1e-9),
        "warm_speedup_x": warm_sps / max(loop_sps, 1e-9),
    }
    with open(SCAN_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit(
        "overhead/scan_engine",
        1e6 / max(scan_sps, 1e-9),
        f"loop_sps={loop_sps:.1f};scan_sps={scan_sps:.1f};"
        f"warm15_sps={warm_sps:.1f};speedup={payload['scan_speedup_x']:.1f}x;"
        f"acc_loop={loop_acc:.3f};acc_scan={scan_acc:.3f};"
        f"acc_warm={warm_acc:.3f}",
    )
    print(f"wrote {SCAN_JSON_PATH}", flush=True)
    return payload


def main(fast=True):
    n, n_iters = 16, 20
    dims = [1 << 14, 1 << 17] if fast else [1 << 14, 1 << 17, 1 << 20, 1 << 23]
    # interpret-mode pallas is CPU-interpreter-bound; keep its sizes sane
    fused_dims = [d for d in dims if d <= 1 << 17]
    records = []
    for d in dims:
        g = jax.random.normal(jax.random.key(0), (n, d))
        z = get_random_directions(7, n, -(-d // n))

        mean_fn = jax.jit(lambda x: x.mean(0))
        us_mean = timer(mean_fn, g, reps=10)

        def full_btard(x):
            agg, parts = butterfly_clip(x, tau=1.0, n_iters=n_iters)
            s, norms = verification_tables(parts, agg, z, 1.0)
            return agg, s, norms

        us_btard = timer(jax.jit(full_btard), g, reps=5)

        us_fused = None
        if d in fused_dims:
            def fused_btard(x):
                agg, _parts, s, norms = butterfly_clip_verified(
                    x, 1.0, z, n_iters=n_iters, use_pallas=True
                )
                return agg, s, norms

            us_fused = timer(jax.jit(fused_btard), g, reps=3)

        ar, extra = comm_model(n, d)
        passes = hbm_pass_model(n_iters, n, d)
        emit(
            f"overhead/d={d}",
            us_btard,
            f"mean_us={us_mean:.1f};overhead_x={us_btard/max(us_mean,1e-9):.2f};"
            f"fused_us={-1.0 if us_fused is None else us_fused:.1f};"
            f"passes_seed={passes['seed_passes']};passes_fused={passes['fused_passes']};"
            f"pass_speedup={passes['pass_speedup']:.2f};"
            f"comm_ar_bytes={ar};comm_btard_extra_bytes={extra};"
            f"extra_frac={extra/ar:.4f}",
        )
        records.append(
            {
                "d": d,
                "n_peers": n,
                "n_iters": n_iters,
                "mean_us": us_mean,
                "btard_jnp_us": us_btard,
                "btard_fused_interpret_us": us_fused,
                "overhead_x": us_btard / max(us_mean, 1e-9),
                "hbm_pass_model": passes,
                "comm_ar_bytes": ar,
                "comm_btard_extra_bytes": extra,
            }
        )
    payload = {
        "bench": "overhead",
        "backend": jax.default_backend(),
        "pallas_mode": "interpret"
        if os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"
        else "compiled",
        "records": records,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {JSON_PATH}", flush=True)
    scan_engine_bench(fast=fast)


if __name__ == "__main__":
    main(fast=False)
