"""Paper App. I.2: BTARD overhead vs plain All-Reduce.

Four views:
  * measured step time of the butterfly robust aggregation + verification
    tables vs a plain mean over stacked peer gradients, as d grows, for both
    the pure-jnp pipeline and the fused Pallas kernel (interpret mode on
    CPU — the interpreter is slow, so the *pass model* is the bandwidth
    signal there; on a TPU set REPRO_PALLAS_COMPILE=1);
  * the HBM-pass model: the seed kernel family streamed the (n, d) peer
    stack 2*n_iters + 1 times per aggregation (norm phase + update phase per
    clip iteration, then a standalone table pass); the fused kernel's
    incremental-norm recurrence + verification epilogue does it in
    n_iters + 2 (see src/repro/kernels/DESIGN.md);
  * the communication model: per-peer bytes for AR vs BTARD
    (2d for ring/butterfly AR; BTARD adds O(n^2) scalars — independent of d,
    exactly the paper's §3.1 cost accounting), now PER AGGREGATOR SPEC:
    verifiable specs (the flagship and every verified:* wrapper) ride the
    butterfly at O(d) per peer plus size-independent table bytes, while the
    unwrapped baselines pay the trusted-PS O(n*d) all_gather; compressed:*
    specs carry per-codec ``bytes_on_wire`` / ``wire_reduction_x`` columns
    (int8 ~4x fewer all_to_all bytes; regression-gated);
  * the scan-engine view: steps/s of the legacy host protocol loop vs the
    jitted lax.scan ProtocolState engine (core.engine), at the default
    clip_iters=60 and at warm-start clip_iters=15 -> BENCH_scan.json.

Emits BENCH_overhead.json + BENCH_scan.json next to this file (or --out-dir)
so the perf trajectory is machine-trackable across PRs; CI regenerates both
with --quick and gates merges on benchmarks/check_regression.py.
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timer
from repro.core.butterfly import (
    butterfly_clip,
    butterfly_clip_verified,
    get_random_directions,
    verification_tables,
)

_DIR = os.path.dirname(os.path.abspath(__file__))
JSON_PATH = os.path.join(_DIR, "BENCH_overhead.json")
SCAN_JSON_PATH = os.path.join(_DIR, "BENCH_scan.json")


def comm_model(n, d, bytes_per=4, payload_bytes=None, sidecar_bytes=0):
    """AR vs BTARD per-peer bytes, parameterized by the gradient payload
    dtype: ``payload_bytes`` is the bytes/coordinate on the butterfly
    all_to_all leg (defaults to ``bytes_per``, the f32 baseline; compressed
    specs ship 1-2), ``sidecar_bytes`` the codec sidecar traffic (one f32
    scale per payload each way). Returns (ar, btard_extra, bytes_on_wire)
    where bytes_on_wire is the all_to_all payload leg — the bytes a wire
    codec actually compresses."""
    pb = bytes_per if payload_bytes is None else payload_bytes
    ar = 2 * d * bytes_per  # reduce-scatter + all-gather per peer
    btard_extra = (2 * n * n + 3 * n) * bytes_per  # s-table, norms, hashes, mprng
    bytes_on_wire = d * pb + sidecar_bytes
    return ar, btard_extra, bytes_on_wire


def comm_model_per_spec(n, d, bytes_per=4):
    """Per-peer communication bytes per robust all-reduce, by registered
    AggregatorSpec (launch/steps.aggregation_stage topologies):

    * verifiable specs (butterfly_clip + every verified:* wrapper) run the
      butterfly — all_to_all its d/n-sized partition to every peer (~d
      sent) + the aggregated-partition all_gather (~d received) + the
      O(n^2)-scalar broadcast tables, independent of d;
    * compressed:* specs additionally quantize the all_to_all payload to
      their wire codec (int8: 1 byte/coordinate + one f32 scale sidecar
      per payload each way; bf16: 2 bytes) — ``bytes_on_wire`` is that
      compressed leg and ``wire_reduction_x`` its reduction vs the f32
      butterfly payload (the regression-gated codec claim); the aggregate
      all_gather rides the transport dtype, codec-independent;
    * non-verifiable specs all_gather the FULL peer stack (the trusted-PS
      model): n*d received per peer, zero tables.

    This is the paper's §3.1 cost accounting extended across the spec
    registry: wrapping a baseline into its verified: form REPLACES the
    O(n*d) PS gather with the O(d)-per-peer butterfly plus size-independent
    table traffic — verification makes the communication model BETTER, not
    worse, for n > 2 — and the compressed: wrapper then shrinks the
    dominant butterfly leg by ~4x (int8) on top.
    """
    from repro.core import compression as comp
    from repro.core.aggregators import REGISTRY, AggregatorSpec

    out = {}

    def cell(defn, payload_bytes, sidecar):
        if defn.verifiable:
            table = (2 * n * n + 3 * n) * bytes_per
            _, _, wire = comm_model(
                n, d, bytes_per, payload_bytes, sidecar
            )
            # + the aggregated-partition all_gather (transport dtype)
            per_peer = wire + d * bytes_per + table
            topology = "butterfly"
        else:
            table = 0
            wire = (n + 1) * d * bytes_per  # send d, gather the n*d stack
            per_peer = wire
            topology = "ps_all_gather"
        return {
            "topology": topology,
            "payload_bytes_per_coord": payload_bytes,
            "sidecar_bytes": sidecar,
            "bytes_on_wire": wire,
            "per_peer_bytes": per_peer,
            "table_bytes": table,
            "per_peer_over_ar": per_peer / (2 * d * bytes_per),
            # the codec claim: f32 all_to_all leg / this spec's leg
            "wire_reduction_x": (d * bytes_per) / wire
            if topology == "butterfly" else 1.0,
        }

    for name, defn in sorted(REGISTRY.items()):
        if name.startswith(comp.PREFIX):
            codec = comp.codec_of(AggregatorSpec(name))  # declared default
            out[name] = cell(
                defn, comp.CODEC_BYTES[codec], 2 * n * bytes_per
            )
            # the non-default codec variant, same spec machinery
            for alt in comp.CODECS:
                if alt != codec:
                    out[f"{name}:codec={alt}"] = cell(
                        defn, comp.CODEC_BYTES[alt], 2 * n * bytes_per
                    )
        else:
            out[name] = cell(defn, bytes_per, 0)
    return out


def hbm_pass_model(n_iters, n, d, bytes_per=4, adaptive_iters=2):
    """HBM traffic of the full aggregation workload per robust all-reduce:
    across all n partitions the streamed stack totals n * d values (each
    partition is an (n, d/n) peer stack).

    seed two-phase kernel + standalone table kernel: 2*n_iters + 1 passes;
    fused incremental-norm kernel with verification epilogue: n_iters + 2;
    adaptive early-exit driver: iters_run + 2 (jnp prologue + one pass per
    iteration actually run + the single verification epilogue) —
    ``adaptive_iters`` is the warm-start steady-state iteration count
    (measured 1-2 on the convergence workloads, vs the fixed 60 budget).
    """
    stack = n * d * bytes_per
    return {
        "seed_passes": 2 * n_iters + 1,
        "fused_passes": n_iters + 2,
        "adaptive_passes": adaptive_iters + 2,
        "seed_bytes": (2 * n_iters + 1) * stack,
        "fused_bytes": (n_iters + 2) * stack,
        "adaptive_bytes": (adaptive_iters + 2) * stack,
        "pass_speedup": (2 * n_iters + 1) / (n_iters + 2),
        "adaptive_pass_speedup": (n_iters + 2) / (adaptive_iters + 2),
    }


def scan_engine_bench(steps=None, fast=True, out_dir=None):
    """Legacy host loop vs jitted lax.scan ProtocolState engine: steps/s on
    the controlled classification workload (16 peers, 7 Byzantine,
    sign-flip), at clip_iters=60 (the protocol default), at the warm-start
    budget clip_iters=15, and with the adaptive early-exit budget
    (``adaptive_tol``, cap 60) — plus adaptive-vs-fixed CURVES so the
    budget/steps-per-second trade-off is machine-trackable. Writes
    BENCH_scan.json."""
    from benchmarks.common import classification_setup
    from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
    from repro.optim import sgd

    if steps is None:
        # 30-step sections put the jit-dispatch overhead at ~30% of the
        # measurement and compress the adaptive-vs-fixed ratio; 60 keeps
        # quick mode quick while the ratio tracks the full-mode value
        steps = 60 if fast else 100
    scan_json = os.path.join(out_dir or _DIR, "BENCH_scan.json")
    # dim=512 -> d ≈ 2k: CenteredClip is a real fraction of the step, so
    # the adaptive-vs-fixed ratio measures the clip budget rather than
    # per-step dispatch jitter (at the tests' dim=16 the clip is ~nothing
    # and the ratio is noise-bound)
    loss_fn, params0, batch_fn, accuracy = classification_setup(dim=512)

    def make(clip_iters, warm_start=False, adaptive_tol=None,
             defense="btard"):
        cfg = TrainerConfig(
            n_peers=16,
            byzantine=tuple(range(9, 16)),
            attack=AttackConfig(kind="sign_flip", start_step=5),
            defense=defense,
            tau=1.0,
            clip_iters=clip_iters,
            m_validators=2,
            seed=0,
            warm_start=warm_start,
            adaptive_tol=adaptive_tol,
        )
        return BTARDTrainer(
            loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.3, momentum=0.9)
        )

    def time_run(method, clip_iters, warm_start=False, adaptive_tol=None,
                 reps=None):
        tr = make(clip_iters, warm_start, adaptive_tol)
        fn = getattr(tr, method)
        fn(steps)  # warmup: traces + compiles everything
        if reps is None:
            # a 30-step scan section is ~10 ms — single-shot timing is
            # dispatch-jitter noise, so take best-of-many for the fast
            # methods (the legacy host loop is 50x slower; 2 reps suffice)
            reps = 2 if method == "run" else 8
        best = float("inf")
        for _ in range(reps):  # best-of-reps: steady state (bans settled —
            t0 = time.perf_counter()  # the regime a long run lives in)
            fn(steps)
            best = min(best, time.perf_counter() - t0)
        iters = [
            h["clip_iters_used"]
            for h in tr.history[steps:]
            if "clip_iters_used" in h
        ]
        cell = {
            "steps_per_s": steps / best,
            "clip_iters": clip_iters,
            "acc": accuracy(tr.unraveled_params()),
            "banned": len(tr.banned),
        }
        if warm_start:
            cell["warm_start"] = True
        if adaptive_tol is not None:
            cell["adaptive_tol"] = adaptive_tol
            cell["clip_iters_used_mean"] = float(np.mean(iters)) if iters else None
        return cell

    loop = time_run("run", 60, reps=1)
    scan = time_run("run_scan", 60)
    warm = time_run("run_scan", 15, warm_start=True)
    # the device-resident default: adaptive early exit at the protocol-default
    # cap (60) with warm start — the acceptance headline vs the fixed scan
    adaptive = time_run("run_scan", 60, warm_start=True, adaptive_tol=1e-4)

    # headline ratio from INTERLEAVED paired timing: the two cells alternate
    # within one loop, so a machine-wide slowdown (CI runners!) hits both
    # symmetrically and best-of picks each cell's cleanest samples — the
    # independently-timed cells above keep the absolute steps/s numbers
    tr_fixed = make(60)
    tr_adapt = make(60, warm_start=True, adaptive_tol=1e-4)
    tr_fixed.run_scan(steps)
    tr_adapt.run_scan(steps)
    best_fixed = best_adapt = float("inf")
    for _ in range(8):
        t0 = time.perf_counter()
        tr_fixed.run_scan(steps)
        best_fixed = min(best_fixed, time.perf_counter() - t0)
        t0 = time.perf_counter()
        tr_adapt.run_scan(steps)
        best_adapt = min(best_adapt, time.perf_counter() - t0)
    adaptive_vs_scan = best_fixed / max(best_adapt, 1e-9)

    # --- the AggregatorSpec comparison axis: every registered aggregator
    # through the SAME scanned engine on the same attacked workload. The
    # block existing at all proves each spec is jit/scan-clean; the
    # flagship's advantage over the fixed scan stays gated separately
    # (adaptive_speedup_vs_scan_x >= 1.15 in check_regression.py).
    from repro.core.aggregators import REGISTRY, registered_aggregators

    agg_steps = max(steps // 2, 20)
    aggregator_comparison = {}
    for name in registered_aggregators():
        defense = "btard" if name == "butterfly_clip" else name
        tr = make(60, warm_start=name == "butterfly_clip",
                  adaptive_tol=1e-4 if name == "butterfly_clip" else None,
                  defense=defense)
        tr.run_scan(agg_steps)  # warmup: trace + compile
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            tr.run_scan(agg_steps)
            best = min(best, time.perf_counter() - t0)
        aggregator_comparison[name] = {
            "steps_per_s": agg_steps / best,
            "acc": accuracy(tr.unraveled_params()),
            "banned": len(tr.banned),
            "verifiable": REGISTRY[name].verifiable,
        }
        emit(
            f"overhead/aggregator/{name}",
            1e6 * best / agg_steps,
            f"sps={agg_steps / best:.1f};"
            f"acc={aggregator_comparison[name]['acc']:.3f};"
            f"banned={aggregator_comparison[name]['banned']}",
        )

    fixed_curve = [scan, warm] + [time_run("run_scan", 30)]
    adaptive_curve = [
        time_run("run_scan", 60, warm_start=True, adaptive_tol=tol)
        for tol in (1e-2, 1e-6)
    ] + [adaptive]
    payload = {
        "bench": "scan_engine",
        "backend": jax.default_backend(),
        "steps": steps,
        "n_peers": 16,
        "legacy_loop": loop,
        "scan_engine": scan,
        "scan_engine_warm15": warm,
        "scan_engine_adaptive": adaptive,
        "aggregator_comparison": aggregator_comparison,
        "fixed_curve": fixed_curve,
        "adaptive_curve": adaptive_curve,
        "scan_speedup_x": scan["steps_per_s"] / max(loop["steps_per_s"], 1e-9),
        "warm_speedup_x": warm["steps_per_s"] / max(loop["steps_per_s"], 1e-9),
        "adaptive_speedup_x": adaptive["steps_per_s"]
        / max(loop["steps_per_s"], 1e-9),
        # the acceptance ratio: adaptive early exit vs the PR 2 fixed-budget
        # scan path, both at protocol-default settings (cap/budget 60),
        # measured pairwise-interleaved (above)
        "adaptive_speedup_vs_scan_x": adaptive_vs_scan,
    }
    with open(scan_json, "w") as f:
        json.dump(payload, f, indent=2)
    emit(
        "overhead/scan_engine",
        1e6 / max(scan["steps_per_s"], 1e-9),
        f"loop_sps={loop['steps_per_s']:.1f};scan_sps={scan['steps_per_s']:.1f};"
        f"warm15_sps={warm['steps_per_s']:.1f};"
        f"adaptive_sps={adaptive['steps_per_s']:.1f};"
        f"speedup={payload['scan_speedup_x']:.1f}x;"
        f"adaptive_vs_scan={payload['adaptive_speedup_vs_scan_x']:.2f}x;"
        f"acc_loop={loop['acc']:.3f};acc_scan={scan['acc']:.3f};"
        f"acc_adaptive={adaptive['acc']:.3f};"
        f"iters_used={adaptive['clip_iters_used_mean']}",
    )
    print(f"wrote {scan_json}", flush=True)
    return payload


def main(fast=True, out_dir=None):
    if fast and out_dir is None:
        # quick mode must never clobber the committed (CI-gated, full-mode)
        # baselines: park its JSON in a scratch subdir unless the caller
        # explicitly chose a destination
        out_dir = os.path.join(_DIR, "quick")
        os.makedirs(out_dir, exist_ok=True)
        print(f"quick mode: writing BENCH_*.json to {out_dir} "
              "(committed baselines are full-mode; pass --out-dir to "
              "override)", flush=True)
    json_path = os.path.join(out_dir or _DIR, "BENCH_overhead.json")
    n, n_iters = 16, 20
    dims = [1 << 14, 1 << 17] if fast else [1 << 14, 1 << 17, 1 << 20, 1 << 23]
    # interpret-mode pallas is CPU-interpreter-bound; keep its sizes sane
    fused_dims = [d for d in dims if d <= 1 << 17]
    records = []
    for d in dims:
        g = jax.random.normal(jax.random.key(0), (n, d))
        z = get_random_directions(7, n, -(-d // n))

        mean_fn = jax.jit(lambda x: x.mean(0))
        us_mean = timer(mean_fn, g, reps=10)

        def full_btard(x):
            agg, parts = butterfly_clip(x, tau=1.0, n_iters=n_iters)
            s, norms = verification_tables(parts, agg, z, 1.0)
            return agg, s, norms

        us_btard = timer(jax.jit(full_btard), g, reps=5)

        us_fused = None
        if d in fused_dims:
            def fused_btard(x):
                agg, _parts, s, norms = butterfly_clip_verified(
                    x, 1.0, z, n_iters=n_iters, use_pallas=True
                )
                return agg, s, norms

            us_fused = timer(jax.jit(fused_btard), g, reps=3)

        ar, extra, _ = comm_model(n, d)
        passes = hbm_pass_model(n_iters, n, d)
        emit(
            f"overhead/d={d}",
            us_btard,
            f"mean_us={us_mean:.1f};overhead_x={us_btard/max(us_mean,1e-9):.2f};"
            f"fused_us={-1.0 if us_fused is None else us_fused:.1f};"
            f"passes_seed={passes['seed_passes']};passes_fused={passes['fused_passes']};"
            f"pass_speedup={passes['pass_speedup']:.2f};"
            f"comm_ar_bytes={ar};comm_btard_extra_bytes={extra};"
            f"extra_frac={extra/ar:.4f}",
        )
        records.append(
            {
                "d": d,
                "n_peers": n,
                "n_iters": n_iters,
                "mean_us": us_mean,
                "btard_jnp_us": us_btard,
                "btard_fused_interpret_us": us_fused,
                "overhead_x": us_btard / max(us_mean, 1e-9),
                "hbm_pass_model": passes,
                "comm_ar_bytes": ar,
                "comm_btard_extra_bytes": extra,
            }
        )
    # per-aggregator communication model at the largest measured dim: the
    # verified: wrapper's butterfly O(d) per peer vs the PS O(n*d) gather
    comm_per_spec = comm_model_per_spec(n, dims[-1])
    for spec_name, cell in comm_per_spec.items():
        emit(
            f"overhead/comm/{spec_name}",
            cell["per_peer_bytes"] / 1e3,
            f"topology={cell['topology']};table_bytes={cell['table_bytes']};"
            f"per_peer_over_ar={cell['per_peer_over_ar']:.2f};"
            f"bytes_on_wire={cell['bytes_on_wire']};"
            f"wire_reduction={cell['wire_reduction_x']:.2f}x",
        )
    payload = {
        "bench": "overhead",
        "backend": jax.default_backend(),
        "pallas_mode": "interpret"
        if os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"
        else "compiled",
        "comm_per_spec": {"n_peers": n, "d": dims[-1], "specs": comm_per_spec},
        "records": records,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {json_path}", flush=True)
    scan_engine_bench(fast=fast, out_dir=out_dir)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: small dims, 60-step scan cells, output "
                         "parked in benchmarks/quick/ unless --out-dir")
    ap.add_argument("--out-dir", default=None,
                    help="write BENCH_*.json here instead of benchmarks/ "
                         "(CI writes to a scratch dir and diffs against the "
                         "committed baselines via check_regression.py)")
    args = ap.parse_args()
    if args.out_dir:
        os.makedirs(args.out_dir, exist_ok=True)
    main(fast=args.quick, out_dir=args.out_dir)
