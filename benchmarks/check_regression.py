"""CI benchmark-regression gate.

Compares freshly generated BENCH_*.json (``bench_overhead.py --quick
--out-dir <fresh>``) against the baselines committed in benchmarks/:

* HBM-pass counts — EXACT. The pass model is analytic (kernel structure,
  not wall clock); any drift means someone changed the kernel dataflow and
  must regenerate the committed baselines deliberately.
* machine-independent ratio invariants on the FRESH run — the scan engine
  must still be >= MIN_SCAN_X faster than the legacy host loop, and the
  adaptive early-exit budget >= MIN_ADAPTIVE_X faster than the fixed-budget
  scan path (the PR acceptance floor 1.3x minus CI-runner noise margin;
  the bench measures this ratio pairwise-interleaved, so it is stable —
  ~1.7x on the committed baseline).
* protocol invariants — every cell converges (acc within ACC_SLACK of the
  baseline) and bans exactly the baseline's Byzantine count. A perf "win"
  that changes bans is a correctness regression, not a speedup. The
  aggregator_comparison ban columns extend this to every verifiable spec:
  verified:* wrapped baselines must keep banning (and match the committed
  count), non-verifiable ones must never ban; the per-spec communication
  model (butterfly vs PS all_gather topology, table bytes) is analytic and
  gated exactly, including the compressed:* wire-codec columns (the int8
  all_to_all leg must stay >= 3.5x smaller than the f32 payload).
* flat-cost verification gates (:func:`check_flat_cost`) — at n=1024 the
  sampled / hierarchical / composed per-peer table bytes must each stay
  <= 10% of full Alg. 6; every measured scaling cell must ban zero honest
  peers, and cells run past their detection bound must ban EXACTLY their
  Byzantine peers; the sympy symbolic comm model must agree with
  core.hierarchy.table_scalars at every cross-check point.
* absolute steps/s — fresh >= baseline * (1 - tol). The band is wide
  (default 0.6) because hosted runners are noisy and slower than the dev
  machine; the ratio invariants above are the sharp gate.

Exit code 0 = no regression; 1 = regression (each failure printed).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

MIN_SCAN_X = 4.0  # scan engine vs legacy host loop at the bench's dim=512
# workload (~6-7x measured; the PR 2 ~40x figure was the dim=16 toy, where
# per-step host overhead dwarfed the compute)
MIN_ADAPTIVE_X = 1.15  # acceptance says 1.3x on the committed baseline;
# CI re-measures on shared runners, so the gate keeps a noise margin
ACC_SLACK = 0.02

CELLS = ("legacy_loop", "scan_engine", "scan_engine_warm15",
         "scan_engine_adaptive")

# every registered AggregatorSpec must appear in the BENCH_scan.json
# aggregator_comparison block (keep in sync with
# repro.core.aggregators.registered_aggregators())
AGG_NAMES = ("butterfly_clip", "centered_clip",
             "compressed:butterfly_clip",
             "compressed:verified:coordinate_median",
             "compressed:verified:mean",
             "compressed:verified:trimmed_mean",
             "coordinate_median", "geometric_median", "krum", "mean",
             "trimmed_mean", "verified:coordinate_median", "verified:mean",
             "verified:trimmed_mean")

# wire-codec acceptance floors: the compressed:* all_to_all leg must shrink
# by at least this factor vs the f32 butterfly payload (the comm model is
# analytic — int8 is ~3.999x at the bench dim, so 3.5 is pure safety margin)
MIN_WIRE_X = {1: 3.5, 2: 1.75}


def _is_verifiable_name(name):
    return (name == "butterfly_clip" or name.startswith("verified:")
            or name.startswith("compressed:"))


def _load(path):
    with open(path) as f:
        return json.load(f)


def check_overhead(fresh, base, errors):
    fresh_by_d = {r["d"]: r for r in fresh["records"]}
    compared = 0
    for rec in base["records"]:
        d = rec["d"]
        if d not in fresh_by_d:
            continue  # --quick runs a dim subset; only shared dims compare
        compared += 1
        got = fresh_by_d[d]["hbm_pass_model"]
        want = rec["hbm_pass_model"]
        n_iters = rec["n_iters"]
        if want["seed_passes"] != 2 * n_iters + 1:
            errors.append(f"baseline seed_passes model broken at d={d}")
        for key in ("seed_passes", "fused_passes", "adaptive_passes"):
            if key in want and got.get(key) != want[key]:
                errors.append(
                    f"HBM pass count changed at d={d}: {key} "
                    f"{want[key]} -> {got.get(key)} (kernel dataflow drift — "
                    "regenerate baselines deliberately if intended)"
                )
    if compared == 0:
        # a dim-list change must not turn the exactness gate into a no-op
        errors.append(
            "no overhead dims shared between fresh run "
            f"({sorted(fresh_by_d)}) and baseline "
            f"({sorted(r['d'] for r in base['records'])}) — the HBM-pass "
            "gate compared nothing; align the --quick dims with the "
            "baseline or regenerate it"
        )

    # per-spec communication model — analytic, so gate it EXACTLY like the
    # pass counts: every spec present, verifiable specs on the butterfly
    # with size-independent table bytes, non-verifiable on the PS gather.
    comm = fresh.get("comm_per_spec")
    if comm is None:
        errors.append("fresh BENCH_overhead.json missing comm_per_spec block")
        return
    specs = comm.get("specs", {})
    for name in AGG_NAMES:
        cell = specs.get(name)
        if cell is None:
            errors.append(f"comm_per_spec missing spec: {name}")
            continue
        verifiable = _is_verifiable_name(name)
        want_topo = "butterfly" if verifiable else "ps_all_gather"
        if cell.get("topology") != want_topo:
            errors.append(
                f"comm_per_spec[{name}]: topology {cell.get('topology')!r} "
                f"!= {want_topo!r} (launch dispatch drift)"
            )
        if verifiable != (cell.get("table_bytes", 0) > 0):
            errors.append(
                f"comm_per_spec[{name}]: table_bytes "
                f"{cell.get('table_bytes')} inconsistent with "
                f"verifiable={verifiable}"
            )
        if name.startswith("compressed:"):
            pb = cell.get("payload_bytes_per_coord")
            floor = MIN_WIRE_X.get(pb)
            if floor is None:
                errors.append(
                    f"comm_per_spec[{name}]: unexpected payload width "
                    f"{pb} bytes/coord (codec model drift)"
                )
            elif cell.get("wire_reduction_x", 0.0) < floor:
                errors.append(
                    f"comm_per_spec[{name}]: wire reduction "
                    f"{cell.get('wire_reduction_x', 0.0):.2f}x < floor "
                    f"{floor}x for a {pb}-byte codec (bytes_on_wire="
                    f"{cell.get('bytes_on_wire')} — sidecar/payload model "
                    "drift)"
                )
            if not cell.get("bytes_on_wire", 0) > 0:
                errors.append(
                    f"comm_per_spec[{name}] missing bytes_on_wire column"
                )


def check_flat_cost(fresh, errors):
    """Flat-cost verification gates (sampled digests + hierarchy).

    Analytic and protocol-behaviour gates on the FRESH run only — the
    table model and the symbolic cross-check are machine-independent, and
    the measured ban outcomes are guarantees (each cell runs past its
    detection bound when ``bans_gated``), not wall-clock races.
    """
    scaling = fresh.get("flat_cost_scaling")
    if scaling is None:
        errors.append("fresh BENCH_overhead.json missing flat_cost_scaling")
    else:
        rows = {r["n"]: r for r in scaling.get("rows", [])}
        for n in (16, 64, 256, 1024):
            if n not in rows:
                errors.append(f"flat_cost_scaling missing n={n} row")
        big = rows.get(1024)
        if big is not None:
            frac = big["table_frac_vs_full"]
            # the tentpole acceptance: composed sampling+hierarchy shrinks
            # per-peer table bytes to <= 10% of full Alg. 6 at n=1024
            # (each single axis must already clear the same bar there)
            for mode in ("sampled", "hierarchical", "hierarchical_sampled"):
                if frac.get(mode, 1.0) > 0.10:
                    errors.append(
                        f"flat_cost_scaling n=1024 {mode}: table bytes "
                        f"{frac.get(mode, 1.0):.4f} of full > 0.10 ceiling "
                        "(table model drift)"
                    )
        for n, row in rows.items():
            for mode, cell in row.get("measured", {}).items():
                tag = f"flat_cost_scaling n={n} {mode}"
                if cell.get("honest_banned"):
                    errors.append(
                        f"{tag}: banned honest peers "
                        f"{cell['honest_banned']} (protocol regression)"
                    )
                if cell.get("bans_gated") and not cell.get("bans_exact"):
                    errors.append(
                        f"{tag}: ran {cell.get('steps')} steps past the "
                        f"detection bound {cell.get('detect_bound')} but "
                        f"banned {cell.get('banned')} != byzantine "
                        f"{cell.get('byzantine')} (detection arm regressed)"
                    )
                if not cell.get("steps_per_s", 0) > 0:
                    errors.append(f"{tag}: not jit/scan-clean")

    symbolic = fresh.get("symbolic_comm")
    if symbolic is None:
        errors.append("fresh BENCH_overhead.json missing symbolic_comm")
        return
    checks = symbolic.get("cross_check", [])
    if not checks:
        errors.append("symbolic_comm has no cross_check points")
    for c in checks:
        if not c.get("match"):
            errors.append(
                f"symbolic_comm cross-check diverged at {c.get('point')}: "
                f"symbolic {c.get('symbolic')} != implemented "
                f"{c.get('implemented')} (hierarchy.table_scalars and the "
                "sympy model must move together)"
            )


def check_scan(fresh, base, tol, errors):
    x = fresh.get("scan_speedup_x", 0.0)
    if x < MIN_SCAN_X:
        errors.append(
            f"scan engine only {x:.1f}x over the legacy loop (floor {MIN_SCAN_X}x)"
        )
    ax = fresh.get("adaptive_speedup_vs_scan_x", 0.0)
    if ax < MIN_ADAPTIVE_X:
        errors.append(
            f"adaptive clip only {ax:.2f}x over the fixed-budget scan "
            f"(floor {MIN_ADAPTIVE_X}x)"
        )
    for cell in CELLS:
        f, b = fresh.get(cell), base.get(cell)
        if f is None or b is None:
            errors.append(f"missing bench cell: {cell}")
            continue
        if f["acc"] < b["acc"] - ACC_SLACK:
            errors.append(
                f"{cell}: accuracy regressed {b['acc']:.3f} -> {f['acc']:.3f}"
            )
        if f["banned"] != b["banned"]:
            errors.append(
                f"{cell}: ban count changed {b['banned']} -> {f['banned']} "
                "(protocol behaviour regression)"
            )
        floor = b["steps_per_s"] * (1.0 - tol)
        if f["steps_per_s"] < floor:
            errors.append(
                f"{cell}: {f['steps_per_s']:.1f} steps/s < tolerance floor "
                f"{floor:.1f} (baseline {b['steps_per_s']:.1f}, tol {tol})"
            )
    used = fresh.get("scan_engine_adaptive", {}).get("clip_iters_used_mean")
    cap = fresh.get("scan_engine_adaptive", {}).get("clip_iters", 60)
    if used is not None and used > cap / 2:
        errors.append(
            f"adaptive clip no longer early-exits (mean {used:.1f} of cap {cap})"
        )

    # aggregator-comparison block (the AggregatorSpec axis): every
    # registered spec must be present and jit/scan-clean — a cell only
    # exists if its scanned run compiled and executed. Non-verifiable
    # specs must never ban (their verification degrades to a no-op); the
    # flagship ButterflyClip must keep the baseline's ban count and
    # accuracy. Its >= MIN_ADAPTIVE_X advantage over the fixed scan is
    # already gated above via adaptive_speedup_vs_scan_x.
    base_block = base.get("aggregator_comparison")
    if base_block is None:
        errors.append(
            "committed BENCH_scan.json missing aggregator_comparison block "
            "(regenerate the baseline)"
        )
    block = fresh.get("aggregator_comparison")
    if block is None:
        errors.append("fresh BENCH_scan.json missing aggregator_comparison "
                      "block (bench did not run the aggregator axis?)")
        return
    for name in AGG_NAMES:
        cell = block.get(name)
        if cell is None:
            errors.append(f"aggregator_comparison missing cell: {name}")
            continue
        if not cell.get("steps_per_s", 0) > 0:
            errors.append(
                f"aggregator_comparison[{name}] not jit-clean "
                f"(steps_per_s={cell.get('steps_per_s')})"
            )
        bcell = (base_block or {}).get(name)
        if not cell.get("verifiable"):
            if cell.get("banned", 0) != 0:
                errors.append(
                    f"aggregator_comparison[{name}]: non-verifiable spec "
                    f"banned {cell['banned']} peers (verification must be a "
                    "no-op)"
                )
            continue
        # verifiable column (flagship + every verified:* wrapped spec):
        # the detection arm must fire — the whole point of the wrapper —
        # and the ban column must match the committed baseline exactly
        # (a perf "win" that changes bans is a protocol regression).
        if cell.get("banned", 0) <= 0:
            errors.append(
                f"aggregator_comparison[{name}]: verifiable spec banned "
                "nobody under the Byzantine workload (detection arm "
                "regressed)"
            )
        if bcell is not None and cell.get("banned") != bcell.get("banned"):
            errors.append(
                f"aggregator_comparison[{name}]: ban count changed "
                f"{bcell.get('banned')} -> {cell.get('banned')}"
            )
        if name == "butterfly_clip" and bcell is not None:
            if cell.get("acc", 0.0) < bcell.get("acc", 0.0) - ACC_SLACK:
                errors.append(
                    "aggregator_comparison[butterfly_clip]: accuracy "
                    f"regressed {bcell.get('acc'):.3f} -> "
                    f"{cell.get('acc'):.3f}"
                )


def check_model_scaling(fresh, base, errors):
    """Real-model scaling-curve gates (BENCH_scan.json model_scaling).

    Analytic + protocol gates only — the byte columns are exact functions
    of (n, d, codec), the ban columns are guarantees; steps/s is recorded
    for the curve but not wall-clock-gated (real-model cells are the
    noisiest thing CI times)."""
    block = fresh.get("model_scaling")
    if block is None:
        errors.append("fresh BENCH_scan.json missing model_scaling block "
                      "(real-model gauntlet bench did not run?)")
        return
    rows = block.get("rows", [])
    if len(rows) < 3:
        errors.append(
            f"model_scaling has {len(rows)} sizes; the scaling curve needs "
            ">= 3 (params vs steps/s, wire bytes, table overhead)"
        )
    n = block.get("n_peers", 0)
    prev_params, prev_frac = 0, float("inf")
    for row in rows:
        tag = f"model_scaling[{row.get('name')}]"
        params = row.get("params", 0)
        if params <= prev_params:
            errors.append(f"{tag}: params {params} not increasing along the "
                          "curve (size ladder broken)")
        prev_params = params
        # exact analytic byte model: bf16 payload + f32 scale sidecars,
        # size-independent tables (2n^2 + 3n scalars)
        pb = row.get("payload_bytes_per_coord", 0)
        want_wire = params * pb + 2 * n * 4
        if row.get("wire_bytes_per_peer") != want_wire:
            errors.append(
                f"{tag}: wire_bytes_per_peer {row.get('wire_bytes_per_peer')}"
                f" != analytic {want_wire} (codec/sidecar model drift)"
            )
        want_table = (2 * n * n + 3 * n) * 4
        if row.get("table_bytes") != want_table:
            errors.append(
                f"{tag}: table_bytes {row.get('table_bytes')} != analytic "
                f"{want_table} (tables must be size-independent)"
            )
        frac = row.get("table_overhead_frac", 1.0)
        if frac >= prev_frac:
            errors.append(
                f"{tag}: table overhead fraction {frac:.2e} not decreasing "
                "with model size (the flat-cost claim on real models)"
            )
        prev_frac = frac
        if not row.get("steps_per_s", 0) > 0:
            errors.append(f"{tag}: scanned real-model step not jit-clean")
        if row.get("honest_banned"):
            errors.append(f"{tag}: banned honest peers "
                          f"{row['honest_banned']} (protocol regression)")
        if row.get("banned") != row.get("byzantine"):
            errors.append(
                f"{tag}: banned {row.get('banned')} != byzantine "
                f"{row.get('byzantine')} (detection arm regressed on real "
                "gradients)"
            )
    if rows and rows[-1].get("table_overhead_frac", 1.0) > 1e-3:
        errors.append(
            "model_scaling: table overhead still "
            f"{rows[-1].get('table_overhead_frac'):.2e} of per-peer bytes at "
            "the largest size (> 0.1% ceiling)"
        )
    base_rows = {r.get("name"): r for r in
                 (base.get("model_scaling") or {}).get("rows", [])}
    for row in rows:
        brow = base_rows.get(row.get("name"))
        if brow is not None and row.get("banned") != brow.get("banned"):
            errors.append(
                f"model_scaling[{row.get('name')}]: ban outcome changed "
                f"{brow.get('banned')} -> {row.get('banned')}"
            )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="dir holding the freshly generated BENCH_*.json")
    ap.add_argument("--baseline",
                    default=os.path.dirname(os.path.abspath(__file__)),
                    help="dir holding the committed baselines")
    ap.add_argument("--tol", type=float, default=0.6,
                    help="fractional steps/s slack vs the baseline "
                         "(hosted runners are slow AND noisy)")
    args = ap.parse_args()

    errors = []
    for name, checker in (("BENCH_overhead.json", check_overhead),
                          ("BENCH_scan.json", None)):
        fresh_p = os.path.join(args.fresh, name)
        base_p = os.path.join(args.baseline, name)
        if not os.path.exists(fresh_p):
            errors.append(f"fresh {name} missing (bench did not run?)")
            continue
        if not os.path.exists(base_p):
            errors.append(f"committed baseline {name} missing")
            continue
        fresh, base = _load(fresh_p), _load(base_p)
        if checker is not None:
            checker(fresh, base, errors)
            check_flat_cost(fresh, errors)
        else:
            check_scan(fresh, base, args.tol, errors)
            check_model_scaling(fresh, base, errors)

    if errors:
        print("BENCH REGRESSION:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("bench regression check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
