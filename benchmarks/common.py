"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
from repro.data import classification_batch, peer_seed
from repro.optim import sgd

DIM, CLASSES = 16, 4


def timer(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def classification_setup(dim=DIM, classes=CLASSES):
    """Controlled §4.1 workload. ``dim`` scales the gradient dimension (the
    scan bench uses a larger dim so CenteredClip is a real fraction of the
    step and the adaptive-vs-fixed ratio measures the clip, not dispatch).
    The class-mean margin shrinks with sqrt(dim) so difficulty stays
    dim-invariant — otherwise high dims separate so fast the softmax
    saturates to exact-zero gradients before the attack window opens and
    sign-flip becomes an undetectable no-op (nothing to ban)."""
    margin = 2.0 * (DIM / dim) ** 0.5

    def batch_fn(peer, step, flipped):
        return classification_batch(
            peer_seed(0, step, peer), 16, dim, classes,
            flip_labels=flipped, margin=margin,
        )

    def loss_fn(params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        return -jnp.mean(
            jnp.take_along_axis(
                jax.nn.log_softmax(logits), batch["y"][:, None], axis=1
            )
        )

    params0 = {"w": jnp.zeros((dim, classes)), "b": jnp.zeros((classes,))}
    eval_batch = classification_batch(10**7, 1024, dim, classes, margin=margin)

    def accuracy(params):
        logits = eval_batch["x"] @ params["w"] + params["b"]
        return float((jnp.argmax(logits, 1) == eval_batch["y"]).mean())

    return loss_fn, params0, batch_fn, accuracy


def run_cell(defense, attack, n_peers=16, n_byz=7, steps=40, tau=1.0, m=2,
             seed=0, scan=False, clip_iters=60, warm_start=False):
    """One attack x defense cell. scan=True routes the defense through the
    jitted lax.scan engine (core.engine) — same protocol, one compiled
    program for all ``steps`` rounds instead of a host loop. Any registered
    AggregatorSpec name works as ``defense`` ("btard" = the verifiable
    ButterflyClip flagship; baselines run with verification degraded)."""
    from repro.core.aggregators import REGISTRY
    loss_fn, params0, batch_fn, accuracy = classification_setup()
    byz = tuple(range(n_peers - n_byz, n_peers))
    cfg = TrainerConfig(
        n_peers=n_peers,
        byzantine=byz,
        attack=AttackConfig(kind=attack, start_step=5, delay=5),
        defense=defense,
        tau=tau,
        clip_iters=clip_iters,
        m_validators=m,
        seed=seed,
        warm_start=warm_start,
    )
    tr = BTARDTrainer(
        loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.3, momentum=0.9)
    )
    use_scan = scan and (defense == "btard" or defense in REGISTRY)
    if use_scan:
        # warm the compile cache on the (pure) runner so the timed section
        # measures steps, not the one-off trace of an N-step lax.scan
        runner = tr._get_scan_runner(steps)
        jax.block_until_ready(
            runner(tr.protocol.state, jnp.asarray(tr.params), tr._opt_state)
        )
    t0 = time.perf_counter()
    if use_scan:
        tr.run_scan(steps)
    else:
        tr.run(steps)
    dt = time.perf_counter() - t0
    return accuracy(tr.unraveled_params()), len(tr.banned), dt / steps * 1e6
