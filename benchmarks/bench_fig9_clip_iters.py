"""Paper Fig. 9 / App. I.1: the CenteredClip iteration budget matters —
'limiting the number of iterations can significantly decrease the final
model quality'; running to convergence (eps=1e-6) recovers the fixed point.

Setting mirrors the paper's regime: delta below the CenteredClip theory
bound (3/16 Byzantine), a coherent IPM-style attack, tau chosen relative to
the honest spread (weaker tau=20 / stronger tau=5 — paper §4.1 tau=10/1
scaled to this problem). Also times the fixed-point loop (jnp vs Pallas).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timer
from repro.core.centered_clip import centered_clip, centered_clip_to_tol
from repro.kernels.ops import centered_clip_op


def _problem(d=1024, n=16, b=3):
    mu = jax.random.normal(jax.random.key(1), (d,))
    mu = mu / jnp.linalg.norm(mu) * 50.0
    honest = mu + jax.random.normal(jax.random.key(2), (n - b, d))
    attack = jnp.broadcast_to(-10.0 * mu, (b, d))
    return jnp.concatenate([honest, attack]), honest.mean(0)


def main(fast=True):
    xs, hm = _problem()
    for tau, label in [(20.0, "weaker"), (5.0, "stronger")]:
        ref, iters = centered_clip_to_tol(xs, tau, eps=1e-6, max_iters=3000)
        err_conv = float(jnp.linalg.norm(ref - hm))
        emit(f"fig9/tau_{label}/to_convergence", 0.0,
             f"iters={int(iters)};err={err_conv:.3f}")
        for budget in [1, 5, 20, 100]:
            v = centered_clip(xs, tau, n_iters=budget)
            err = float(jnp.linalg.norm(v - hm))
            emit(
                f"fig9/tau_{label}/iters={budget}", 0.0,
                f"err={err:.3f};excess_vs_converged={err - err_conv:.3f}",
            )
        # warm start (v0 = last step's aggregate, modelled as the fixed point
        # of a slightly drifted stack): iterations to tolerance collapse —
        # the engine's warm_start flag rides exactly this (DESIGN.md)
        # eps=1e-4: in the strongly-clipped regime (|attack| >> tau) the
        # tail of the fixed-point iteration is sublinear, so 1e-6 exceeds
        # the 3000-iteration cap for BOTH starts and hides the cut
        drift = 0.05 * jax.random.normal(jax.random.key(5), xs.shape)
        _, it_cold = centered_clip_to_tol(xs + drift, tau, eps=1e-4,
                                          max_iters=3000)
        _, it_warm = centered_clip_to_tol(xs + drift, tau, eps=1e-4,
                                          max_iters=3000, v0=ref)
        emit(
            f"fig9/tau_{label}/warm_start", 0.0,
            f"iters_cold={int(it_cold)};iters_warm={int(it_warm)};"
            f"cut={1.0 - int(it_warm) / max(int(it_cold), 1):.2f}",
        )
        for budget in [1, 5, 20]:
            err_c = float(jnp.linalg.norm(
                centered_clip(xs + drift, tau, n_iters=budget) - hm))
            err_w = float(jnp.linalg.norm(
                centered_clip(xs + drift, tau, n_iters=budget, v0=ref) - hm))
            emit(
                f"fig9/tau_{label}/warm_iters={budget}", 0.0,
                f"err_cold={err_c:.3f};err_warm={err_w:.3f}",
            )

    f_jnp = jax.jit(lambda x: centered_clip(x, 5.0, n_iters=20))
    us = timer(f_jnp, xs, reps=10)
    emit("fig9/jnp_clip_20it", us, "d=1024")
    us2 = timer(lambda x: centered_clip_op(x, 5.0, n_iters=20), xs, reps=3)
    emit("fig9/pallas_interpret_clip_20it", us2, "interpret=True on CPU")


if __name__ == "__main__":
    main(fast=False)
