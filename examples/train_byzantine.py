"""Paper §4.1-style controlled experiment: pick an attack and a defense,
watch the bans and the accuracy trajectory.

  PYTHONPATH=src python examples/train_byzantine.py --attack alie --defense btard
  PYTHONPATH=src python examples/train_byzantine.py --attack sign_flip --defense mean

The default workload is the toy gaussian-mixture classifier. ``--model``
swaps in a real LM from the config registry (the §4.2-style setup) and runs
the SCANNED engine — per-peer gradients from ``Model.loss_fn``, flattened at
the core.flatten ravel boundary, any registered aggregator on the wire:

  PYTHONPATH=src python examples/train_byzantine.py --model albert_large \\
      --aggregator compressed:verified:mean --attack sign_flip --steps 6
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
from repro.optim import sgd


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--attack", default="sign_flip",
                    choices=["none", "sign_flip", "random_direction", "label_flip",
                             "delayed_gradient", "ipm_01", "ipm_06", "alie"])
    ap.add_argument("--defense", default="btard",
                    choices=["btard", "mean", "coordinate_median",
                             "geometric_median", "trimmed_mean", "krum",
                             "centered_clip"])
    ap.add_argument("--peers", type=int, default=None,
                    help="default: 16 (toy) / 4 (--model)")
    ap.add_argument("--byzantine", type=int, default=None,
                    help="default: 7 (toy) / 1 (--model)")
    ap.add_argument("--steps", type=int, default=None,
                    help="default: 60 (toy) / 6 (--model)")
    ap.add_argument("--attack-start", type=int, default=None,
                    help="default: 10 (toy) / 0 (--model)")
    ap.add_argument("--tau", type=float, default=1.0)
    ap.add_argument("--validators", type=int, default=2)
    # ------------------------------------------------- real-model gauntlet
    ap.add_argument("--model", default=None, metavar="ARCH",
                    help="train a zoo LM (e.g. albert_large, qwen3-1.7b) "
                         "through the scanned BTARD engine instead of the "
                         "toy classifier")
    ap.add_argument("--aggregator", default=None,
                    help="AggregatorSpec string for the engine path, e.g. "
                         "compressed:verified:mean (overrides --defense)")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced smoke variant)")
    ap.add_argument("--dtype", default=None,
                    choices=["float32", "bfloat16"],
                    help="override param/activation storage dtype")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--clip-iters", type=int, default=None,
                    help="CenteredClip iteration budget (default 60 toy / 5 model)")
    return ap


def run_model(args):
    """Scanned BTARD over a real LM; prints a SUMMARY json line."""
    from repro.models.workload import lm_setup

    peers = args.peers or 4
    n_byz = 1 if args.byzantine is None else args.byzantine
    steps = args.steps or 6
    loss_fn, params0, batch_fn, model = lm_setup(
        args.model, seq_len=args.seq, batch_size=args.batch,
        reduced=not args.full, dtype=args.dtype,
    )
    cfg = TrainerConfig(
        n_peers=peers,
        byzantine=tuple(range(peers - n_byz, peers)),
        attack=AttackConfig(
            kind=args.attack,
            start_step=args.attack_start or 0,
            delay=5,
        ),
        defense=args.defense if args.aggregator is None else "btard",
        aggregator=args.aggregator,
        tau=args.tau,
        clip_iters=args.clip_iters or 5,
        m_validators=args.validators,
    )
    tr = BTARDTrainer(loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.05))
    print(f"model={model.cfg.name} d={tr.d} peers={peers} byz={n_byz} "
          f"aggregator={args.aggregator or args.defense} dtype={model.cfg.dtype}")
    tr.run_scan(steps)
    byz = set(cfg.byzantine)
    ban_steps = {}
    honest_accused = set()
    for rec in tr.history:
        print(f"step {rec['step']:3d}  |g|={rec['grad_norm']:10.4f}  "
              f"banned={rec['n_banned']}"
              + (f"  BANNED {rec['banned_now']}" if rec["banned_now"] else ""))
        for p, _ in rec["banned_now"]:
            ban_steps.setdefault(p, rec["step"])
        honest_accused |= set(rec.get("accused_peers", [])) - byz
    summary = {
        "model": model.cfg.name,
        "d": tr.d,
        "dtype": model.cfg.dtype,
        "aggregator": args.aggregator or args.defense,
        "attack": args.attack,
        "steps": steps,
        "byzantine": sorted(byz),
        "banned": sorted(tr.banned),
        "ban_steps": ban_steps,
        "honest_accused": sorted(honest_accused),
        "final_grad_norm": tr.history[-1]["grad_norm"],
    }
    print("SUMMARY " + json.dumps(summary))


def run_toy(args):
    from benchmarks.common import classification_setup

    peers = args.peers or 16
    n_byz = 7 if args.byzantine is None else args.byzantine
    loss_fn, params0, batch_fn, accuracy = classification_setup()
    cfg = TrainerConfig(
        n_peers=peers,
        byzantine=tuple(range(peers - n_byz, peers)),
        attack=AttackConfig(
            kind=args.attack,
            start_step=10 if args.attack_start is None else args.attack_start,
            delay=5,
        ),
        defense=args.defense,
        aggregator=args.aggregator,
        tau=args.tau,
        clip_iters=args.clip_iters or 60,
        m_validators=args.validators,
    )
    tr = BTARDTrainer(loss_fn, params0, batch_fn, cfg,
                      optimizer=sgd(0.3, momentum=0.9))

    def log(rec):
        if rec["step"] % 5 == 0 or rec.get("banned_now"):
            acc = accuracy(tr.unraveled_params())
            extra = f" BANNED {rec['banned_now']}" if rec.get("banned_now") else ""
            print(f"step {rec['step']:3d}  acc={acc:.3f}  "
                  f"banned={rec['n_banned']}/{n_byz}{extra}")

    tr.run(args.steps or 60, log=log)
    print(f"\nfinal accuracy: {accuracy(tr.unraveled_params()):.3f}")
    print(f"banned peers  : {sorted(tr.banned)}")


def main():
    args = build_parser().parse_args()
    if args.model:
        run_model(args)
    else:
        run_toy(args)


if __name__ == "__main__":
    main()
