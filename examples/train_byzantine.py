"""Paper §4.1-style controlled experiment: pick an attack and a defense,
watch the bans and the accuracy trajectory.

  PYTHONPATH=src python examples/train_byzantine.py --attack alie --defense btard
  PYTHONPATH=src python examples/train_byzantine.py --attack sign_flip --defense mean
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp

from benchmarks.common import classification_setup
from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
from repro.optim import sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--attack", default="sign_flip",
                    choices=["none", "sign_flip", "random_direction", "label_flip",
                             "delayed_gradient", "ipm_01", "ipm_06", "alie"])
    ap.add_argument("--defense", default="btard",
                    choices=["btard", "mean", "coordinate_median",
                             "geometric_median", "trimmed_mean", "krum",
                             "centered_clip"])
    ap.add_argument("--peers", type=int, default=16)
    ap.add_argument("--byzantine", type=int, default=7)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--attack-start", type=int, default=10)
    ap.add_argument("--tau", type=float, default=1.0)
    ap.add_argument("--validators", type=int, default=2)
    args = ap.parse_args()

    loss_fn, params0, batch_fn, accuracy = classification_setup()
    cfg = TrainerConfig(
        n_peers=args.peers,
        byzantine=tuple(range(args.peers - args.byzantine, args.peers)),
        attack=AttackConfig(kind=args.attack, start_step=args.attack_start, delay=5),
        defense=args.defense,
        tau=args.tau,
        m_validators=args.validators,
    )
    tr = BTARDTrainer(loss_fn, params0, batch_fn, cfg,
                      optimizer=sgd(0.3, momentum=0.9))

    def log(rec):
        if rec["step"] % 5 == 0 or rec.get("banned_now"):
            acc = accuracy(tr.unraveled_params())
            extra = f" BANNED {rec['banned_now']}" if rec.get("banned_now") else ""
            print(f"step {rec['step']:3d}  acc={acc:.3f}  "
                  f"banned={rec['n_banned']}/{args.byzantine}{extra}")

    tr.run(args.steps, log=log)
    print(f"\nfinal accuracy: {accuracy(tr.unraveled_params()):.3f}")
    print(f"banned peers  : {sorted(tr.banned)}")


if __name__ == "__main__":
    main()
