"""Quickstart: the BTARD public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
from repro.core.centered_clip import centered_clip
from repro.data import classification_batch, peer_seed
from repro.optim import sgd

# --- 1. CenteredClip: the robust mean -------------------------------------
honest = jax.random.normal(jax.random.key(0), (9, 64)) * 0.3
attackers = 1000.0 * jnp.ones((7, 64))  # amplified sign-flip style garbage
stacked = jnp.concatenate([honest, attackers])
robust = centered_clip(stacked, tau=1.0, n_iters=100)
print(f"mean error      : {float(jnp.linalg.norm(stacked.mean(0) - honest.mean(0))):9.2f}")
print(f"CenteredClip err: {float(jnp.linalg.norm(robust - honest.mean(0))):9.2f}")

# --- 2. BTARD-SGD: 16 peers, 7 Byzantine, full protocol --------------------
def batch_fn(peer, step, flipped):
    return classification_batch(peer_seed(0, step, peer), 16, 16, 4,
                                flip_labels=flipped)

def loss_fn(params, batch):
    logits = batch["x"] @ params["w"]
    return -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(logits), batch["y"][:, None], axis=1))

trainer = BTARDTrainer(
    loss_fn,
    {"w": jnp.zeros((16, 4))},
    batch_fn,
    TrainerConfig(
        n_peers=16,
        byzantine=tuple(range(9, 16)),
        attack=AttackConfig(kind="sign_flip", start_step=5),
        defense="btard",
        tau=1.0,
        m_validators=2,
    ),
    optimizer=sgd(0.3, momentum=0.9),
)
trainer.run(30)
eval_b = classification_batch(10**7, 512, 16, 4)
acc = float((jnp.argmax(eval_b["x"] @ trainer.unraveled_params()["w"], 1)
             == eval_b["y"]).mean())
print(f"banned Byzantines: {sorted(trainer.banned)}")
print(f"final accuracy   : {acc:.3f}")
assert trainer.banned == set(range(9, 16))
