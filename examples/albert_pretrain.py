"""Paper §4.2 in miniature: ALBERT-large + LAMB + BTARD-Clipped-SGD with
7/16 Byzantine peers (Fig. 4 setup; synthetic public-seed token stream
instead of WikiText-103 — no external data in this container).

  PYTHONPATH=src python examples/albert_pretrain.py --steps 40
  PYTHONPATH=src python examples/albert_pretrain.py --full --steps 300   # full-size ALBERT
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
from repro.data import TokenPipeline
from repro.models import get_model
from repro.models.model import Model
from repro.optim import lamb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--full", action="store_true", help="full ALBERT-large")
    ap.add_argument("--attack", default="sign_flip")
    ap.add_argument("--attack-start", type=int, default=10)
    ap.add_argument("--clip-lambda", type=float, default=20.0)
    args = ap.parse_args()

    m = get_model("albert-large", reduced=not args.full)
    cfg = dataclasses.replace(m.cfg, vocab_size=min(m.cfg.vocab_size, 512))
    m = Model(cfg)
    pipe = TokenPipeline(cfg.vocab_size, 32, 4, noise=0.15)

    def batch_fn(peer, step, flipped):
        return pipe.batch(step, peer)

    def loss_fn(params, batch):
        return m.loss_fn(params, batch)[0]

    tcfg = TrainerConfig(
        n_peers=16,
        byzantine=tuple(range(9, 16)),
        attack=AttackConfig(kind=args.attack, start_step=args.attack_start),
        defense="btard",
        tau=2.0,
        clip_lambda=args.clip_lambda,  # => BTARD-Clipped-SGD (Alg. 9)
        m_validators=1,
        clip_iters=40,
    )
    tr = BTARDTrainer(loss_fn, m.init_params(jax.random.key(0)), batch_fn, tcfg,
                      optimizer=lamb(2e-3))

    eval_batch = pipe.batch(10**6)
    uniform = float(np.log(cfg.vocab_size))
    print(f"ALBERT {'full' if args.full else 'reduced'} "
          f"({m.param_count():,} params), uniform CE = {uniform:.3f}")
    def log(rec):
        if rec["step"] % 5 == 0 or rec.get("banned_now"):
            loss = float(loss_fn(tr.unraveled_params(), eval_batch))
            extra = (f"  BANNED {rec['banned_now']}" if rec.get("banned_now") else "")
            print(f"step {rec['step']:4d}  eval_loss={loss:.4f}  "
                  f"banned={len(tr.banned)}/7{extra}", flush=True)

    tr.run(args.steps, log=log)
    final = float(loss_fn(tr.unraveled_params(), eval_batch))
    print(f"\nfinal eval loss {final:.4f} (uniform {uniform:.4f}); "
          f"banned={sorted(tr.banned)}")


if __name__ == "__main__":
    main()
