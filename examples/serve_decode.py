"""Batched serving example: prefill + token-by-token decode with the
distributed serving steps (single device here; same code drives the pod).

  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-27b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape
from repro.data import TokenPipeline
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import get_model
from repro.sharding import set_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    set_mesh(mesh)
    m = get_model(args.arch, reduced=True)
    total = args.prompt_len + args.gen
    shape = InputShape("x", total, args.batch, "decode")
    prefill_fn, _ = make_prefill_step(m, mesh, shape)
    decode_fn, _ = make_decode_step(m, mesh, shape)

    params = m.init_params(jax.random.key(0))
    pipe = TokenPipeline(m.cfg.vocab_size, args.prompt_len, args.batch)
    prompts = pipe.batch(0)["tokens"][:, : args.prompt_len]
    batch = {"tokens": prompts}
    if m.cfg.encoder_len:
        batch["memory_raw"] = jax.random.normal(
            jax.random.key(1), (args.batch, m.cfg.encoder_len, m.cfg.encoder_dim)
        ) * 0.02

    cache = m.init_cache(args.batch, total)
    logits, cache = prefill_fn(params, batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, cache = decode_fn(params, cache, {"token": tok, "pos": pos})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    ms = (time.time() - t0) / max(args.gen - 1, 1) * 1000
    print(f"{m.cfg.name}: {args.batch} seqs, {ms:.1f} ms/token (CPU, reduced model)")
    print("generations:", jnp.stack(generated, 1)[:2].tolist())


if __name__ == "__main__":
    main()
