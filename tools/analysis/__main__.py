"""CLI for btard-lint: ``python -m tools.analysis``.

Exit status 0 iff every selected check passes. ``--json PATH`` writes the
per-check machine-readable report CI uploads as an artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    # force CPU before jax loads: the checks are pure abstract eval and
    # must not grab a TPU out from under a training job
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from tools.analysis import check_names, run_checks

    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="btard-lint: static protocol-invariant checks",
    )
    ap.add_argument("--only", action="append", metavar="CHECK",
                    help="run just this check (repeatable)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the per-check JSON report here")
    ap.add_argument("--list", action="store_true",
                    help="list check names and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in check_names():
            print(name)
        return 0

    results = run_checks(only=args.only)
    for res in results:
        status = "ERROR" if res.error else ("FAIL" if res.findings else "ok")
        print(f"[{status:>5}] {res.name:<20} "
              f"traced={res.traced:<3} {res.seconds:5.1f}s")
        if res.error:
            print(f"        {res.error}")
        for f in res.findings:
            print(f"        {f.where}: {f.message}")

    ok = all(r.ok for r in results)
    n_findings = sum(len(r.findings) for r in results)
    print(f"btard-lint: {len(results)} checks, {n_findings} findings"
          f" -> {'PASS' if ok else 'FAIL'}")

    if args.json:
        report = {
            "ok": ok,
            "checks": [r.to_dict() for r in results],
        }
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"report written to {args.json}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
