"""btard-lint: static invariant checks for the BTARD protocol stack.

Four layers, all jaxpr/abstract-eval based — no TPU, no multi-host ring,
no concrete training step required:

1. ``jaxpr_checks`` — engine purity (no host callbacks, no off-chain PRNG
   seeds inside any protocol phase) and scan-carry stability across the
   engine's tagged config matrix.
2. ``wire_dtype`` — the launch-layer collective contract: payload
   collectives ship the declared wire/transport dtype, upcasts are pinned
   behind ``optimization_barrier`` so XLA cannot hoist them across the
   wire, digests stay float32.
3. ``contracts`` — AggregatorSpec registry: name round-trips, capability
   flags vs traced behavior, bitwise coordinatewise splits.
4. ``kernels_check`` — Pallas completeness (oracle + wrapper + Mosaic
   lowering test per kernel) and TPU block-spec legality by abstract eval.

Run ``python -m tools.analysis`` (see ``__main__``) or call
:func:`run_checks` directly.
"""
from __future__ import annotations

from tools.analysis.common import CheckResult, Finding  # noqa: F401


def _registry():
    # imports deferred: each module traces against src/repro on import of
    # its check functions, and the CLI wants --list to be instant
    from tools.analysis import contracts, jaxpr_checks, kernels_check, wire_dtype

    return {
        "engine_purity": jaxpr_checks.check_engine_purity,
        "engine_carry": jaxpr_checks.check_engine_carry,
        "wire_dtype": wire_dtype.check_wire_dtype,
        "registry_roundtrip": contracts.check_registry_roundtrip,
        "capability_flags": contracts.check_capability_flags,
        "coordinatewise": contracts.check_coordinatewise,
        "pallas_completeness": kernels_check.check_pallas_completeness,
        "pallas_block_specs": kernels_check.check_pallas_block_specs,
    }


def check_names() -> tuple:
    return tuple(_registry())


def run_checks(only=None) -> list:
    """Run the selected (default: all) checks, returning CheckResults.

    A check that raises is reported as an errored CheckResult rather than
    aborting the sweep — the report always covers every requested check."""
    import time

    registry = _registry()
    names = list(only) if only else list(registry)
    unknown = [n for n in names if n not in registry]
    if unknown:
        raise KeyError(f"unknown checks: {unknown}; have {list(registry)}")
    results = []
    for name in names:
        t0 = time.time()
        try:
            results.append(registry[name]())
        except Exception as e:  # noqa: BLE001 — surface as errored result
            res = CheckResult(name)
            res.error = f"{type(e).__name__}: {e}"
            res.seconds = time.time() - t0
            results.append(res)
    return results
