"""btard-lint layer 4: Pallas kernel completeness + TPU block-spec legality.

Every ``*_pallas`` kernel in ``repro.kernels.centered_clip`` must ship with
its full support surface, or the next refactor silently loses coverage:

* **K1 — completeness**: a ``ref.py`` oracle (the jnp ground truth the
  parity tests compare against), a jitted ``ops.py`` wrapper (directly or
  via the public kernel that composes it), and a Mosaic lowering test in
  ``tests/test_pallas_compile.py``. The manifest below is the authoritative
  map; a kernel missing from it — or naming a wrapper/oracle/test that
  does not exist — is a finding.
* **K2 — block-spec legality** via abstract eval (no TPU needed): trace
  each ops wrapper with the canonical shapes and walk every
  ``pallas_call``'s grid mapping. Scalars (all-ones blocks) must live in
  SMEM — a (1, 1) VMEM block is an illegal sub-tile on real TPUs — and
  vector blocks must tile to the dtype's sublane/lane minimums (f32 (8,
  128), bf16 (16, 128), int8 (32, 128)) unless the block spans the full
  array dimension. Exactly the PR 2 bug class, checked statically.
"""
from __future__ import annotations

import inspect
import pathlib
import time

import jax
import jax.numpy as jnp

from tools.analysis.common import CheckResult, Finding, iter_eqns

# canonical trace shapes — mirrors tests/test_pallas_compile.py
N, D, PARTS, ITERS = 8, 384, 4, 5
PART = D // PARTS

# kernel -> (ref.py oracle, ops.py wrapper that reaches it); the Mosaic
# lowering test is located by the kernel's own name in
# tests/test_pallas_compile.py (the tests call kernels directly)
KERNEL_MANIFEST = {
    "centered_clip_pallas": ("centered_clip_ref", "centered_clip_op"),
    "butterfly_clip_pallas": ("centered_clip_ref", "butterfly_clip_op"),
    "centered_clip_fused_pallas": (
        "centered_clip_fused_ref", "centered_clip_fused_op"),
    "butterfly_clip_fused_pallas": (
        "centered_clip_fused_ref", "butterfly_clip_fused_op"),
    "butterfly_clip_fused_dequant_pallas": (
        "centered_clip_fused_dequant_ref", "butterfly_clip_fused_dequant_op"),
    "adaptive_clip_step_pallas": (
        "adaptive_step_ref", "butterfly_clip_adaptive_op"),
    "butterfly_clip_adaptive_pallas": (
        "adaptive_step_ref", "butterfly_clip_adaptive_op"),
    "verify_tables_pallas": ("verify_tables_ref", "verify_tables_op"),
    "verify_tables_batched_pallas": (
        "verify_tables_ref", "verify_tables_all_op"),
    "digest_tables_batched_pallas": (
        "digest_tables_ref", "digest_tables_all_op"),
    "digest_tables_rows_pallas": (
        "digest_tables_rows_ref", "digest_tables_rows_op"),
    "mean_digest_fused_pallas": (
        "mean_digest_fused_ref", "mean_digest_fused_op"),
    "mean_digest_fused_dequant_pallas": (
        "mean_digest_fused_dequant_ref", "mean_digest_fused_dequant_op"),
}

# minimum sublane per element size (pallas_guide: f32/i32 (8,128),
# bf16 (16,128), int8/fp8 (32,128))
_MIN_SUBLANE = {4: 8, 2: 16, 1: 32}
_LANE = 128


def _trace_cases():
    """(label, thunk) per ops wrapper, canonical shapes. Thunks return the
    traced callable + abstract args — built lazily so import stays light."""
    from repro.kernels import ops

    f32 = jnp.float32
    xs = jax.ShapeDtypeStruct((N, D), f32)
    vec = jax.ShapeDtypeStruct((D,), f32)
    w = jax.ShapeDtypeStruct((N,), f32)
    parts = jax.ShapeDtypeStruct((PARTS, N, PART), f32)
    pvec = jax.ShapeDtypeStruct((PARTS, PART), f32)
    qs = jax.ShapeDtypeStruct((PARTS, N, PART), jnp.int8)
    scales = jax.ShapeDtypeStruct((PARTS, N), f32)
    rows = jax.ShapeDtypeStruct((2,), jnp.int32)
    return (
        ("centered_clip_op", lambda: jax.make_jaxpr(
            lambda a, b, c: ops.centered_clip_op(
                a, 1.0, b, c, n_iters=ITERS))(xs, w, vec)),
        ("verify_tables_op", lambda: jax.make_jaxpr(
            lambda a, b, c: ops.verify_tables_op(a, b, c, 1.0))(
                xs, vec, vec)),
        ("butterfly_clip_op", lambda: jax.make_jaxpr(
            lambda a, b, c: ops.butterfly_clip_op(
                a, 1.0, b, c, n_iters=ITERS))(parts, w, pvec)),
        ("centered_clip_fused_op", lambda: jax.make_jaxpr(
            lambda a, z, b, c: ops.centered_clip_fused_op(
                a, 1.0, z, b, v0=c, n_iters=ITERS))(xs, vec, w, vec)),
        ("butterfly_clip_fused_op", lambda: jax.make_jaxpr(
            lambda a, z, b, c: ops.butterfly_clip_fused_op(
                a, 1.0, z, b, v0=c, n_iters=ITERS))(parts, pvec, w, pvec)),
        ("butterfly_clip_fused_dequant_op", lambda: jax.make_jaxpr(
            lambda a, s, z, b: ops.butterfly_clip_fused_dequant_op(
                a, s, 1.0, z, b, n_iters=ITERS))(qs, scales, pvec, w)),
        ("butterfly_clip_adaptive_op", lambda: jax.make_jaxpr(
            lambda a, b: ops.butterfly_clip_adaptive_op(
                a, 1.0, 1e-4, b, max_iters=ITERS))(parts, w)),
        ("butterfly_clip_fused_adaptive_op", lambda: jax.make_jaxpr(
            lambda a, z, b: ops.butterfly_clip_fused_adaptive_op(
                a, 1.0, z, 1e-4, b, max_iters=ITERS))(parts, pvec, w)),
        ("verify_tables_all_op", lambda: jax.make_jaxpr(
            lambda a, b, z: ops.verify_tables_all_op(a, b, z, 1.0))(
                parts, pvec, pvec)),
        ("digest_tables_all_op", lambda: jax.make_jaxpr(
            ops.digest_tables_all_op)(parts, pvec, pvec)),
        ("digest_tables_rows_op", lambda: jax.make_jaxpr(
            lambda a, b, z, r: ops.digest_tables_rows_op(
                a, b, z, r, tau=1.0))(parts, pvec, pvec, rows)),
        ("mean_digest_fused_op", lambda: jax.make_jaxpr(
            ops.mean_digest_fused_op)(parts, pvec, w)),
        ("mean_digest_fused_dequant_op", lambda: jax.make_jaxpr(
            ops.mean_digest_fused_dequant_op)(qs, scales, pvec, w)),
    )


def discovered_kernels():
    from repro.kernels import centered_clip as _k

    return tuple(sorted(
        name for name in dir(_k)
        if name.endswith("_pallas") and callable(getattr(_k, name))
        and not name.startswith("_")
    ))


def completeness_findings(repo_root: str | pathlib.Path | None = None):
    """K1 over the discovered kernel set."""
    from repro.kernels import centered_clip as _k
    from repro.kernels import ops, ref

    root = pathlib.Path(repo_root) if repo_root else (
        pathlib.Path(inspect.getfile(_k)).resolve().parents[3])
    test_path = root / "tests" / "test_pallas_compile.py"
    test_src = test_path.read_text() if test_path.exists() else ""
    ops_src = inspect.getsource(ops)
    kernels_src = inspect.getsource(_k)

    findings = []
    for kernel in discovered_kernels():
        entry = KERNEL_MANIFEST.get(kernel)
        if entry is None:
            findings.append(Finding(
                "pallas_completeness", kernel,
                "kernel is not in KERNEL_MANIFEST: declare its ref.py "
                "oracle, ops.py wrapper and lowering test",
            ))
            continue
        oracle, wrapper = entry
        if not hasattr(ref, oracle):
            findings.append(Finding(
                "pallas_completeness", kernel,
                f"declared oracle ref.{oracle} does not exist",
            ))
        if not hasattr(ops, wrapper):
            findings.append(Finding(
                "pallas_completeness", kernel,
                f"declared wrapper ops.{wrapper} does not exist",
            ))
        # the kernel must be reachable from ops: referenced there directly,
        # or called by another kernel in centered_clip.py (composition)
        called_in_ops = f"{kernel}(" in ops_src
        composed = kernels_src.count(f"{kernel}(") > 1  # beyond its def
        if not (called_in_ops or composed):
            findings.append(Finding(
                "pallas_completeness", kernel,
                "kernel is unreachable: no ops.py wrapper calls it and no "
                "other kernel composes it",
            ))
        if kernel not in test_src:
            findings.append(Finding(
                "pallas_completeness", kernel,
                f"no Mosaic lowering test: {test_path.name} never "
                f"references {kernel}",
            ))
    return findings


def block_spec_findings(closed, where: str):
    """K2 over every pallas_call in one traced wrapper."""
    findings = []
    for e in iter_eqns(closed.jaxpr):
        if e.primitive.name != "pallas_call":
            continue
        gm = e.params["grid_mapping"]
        for bm in gm.block_mappings:
            arr = bm.array_shape_dtype
            dims = [s for s in bm.block_shape if isinstance(s, int)]
            if not dims:
                continue
            space = str(getattr(bm.block_aval, "memory_space", None) or "")
            origin = f"{where}:{bm.origin}"
            if all(s == 1 for s in dims):
                if "smem" not in space.lower():
                    findings.append(Finding(
                        "pallas_block_specs", origin,
                        f"scalar block {tuple(bm.block_shape)} of "
                        f"{arr.shape}/{arr.dtype} placed in "
                        f"{space or 'VMEM'}: scalars must use "
                        "BlockSpec(memory_space=SMEM) (illegal (1, 1) "
                        "VMEM sub-tile on TPU)",
                    ))
                continue
            if "smem" in space.lower():
                continue  # scalar-prefetch / SMEM arrays have no tiling
            lane = dims[-1]
            if lane % _LANE != 0 and lane != arr.shape[-1]:
                findings.append(Finding(
                    "pallas_block_specs", origin,
                    f"lane dim {lane} of block {tuple(bm.block_shape)} is "
                    f"neither a multiple of {_LANE} nor the full array "
                    f"dim {arr.shape[-1]}",
                ))
            if len(dims) >= 2 and len(arr.shape) >= 2:
                sub = dims[-2]
                want = _MIN_SUBLANE.get(jnp.dtype(arr.dtype).itemsize, 8)
                if sub % want != 0 and sub != arr.shape[-2]:
                    findings.append(Finding(
                        "pallas_block_specs", origin,
                        f"sublane dim {sub} of block "
                        f"{tuple(bm.block_shape)} ({arr.dtype}) is neither "
                        f"a multiple of {want} nor the full array dim "
                        f"{arr.shape[-2]}",
                    ))
    return findings


def check_pallas_completeness() -> CheckResult:
    t0 = time.time()
    res = CheckResult("pallas_completeness")
    res.findings += completeness_findings()
    res.traced = len(discovered_kernels())
    res.seconds = time.time() - t0
    return res


def check_pallas_block_specs() -> CheckResult:
    t0 = time.time()
    res = CheckResult("pallas_block_specs")
    for label, thunk in _trace_cases():
        res.findings += block_spec_findings(thunk(), label)
        res.traced += 1
    res.seconds = time.time() - t0
    return res
