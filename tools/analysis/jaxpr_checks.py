"""btard-lint layer 1: jaxpr purity / determinism / carry stability.

Every security claim in the BTARD reproduction rests on honest recomputes
matching *bitwise*: a validator re-derives a peer's digests from the public
seed and accuses on any nonzero difference. That only holds if the engine's
phase functions are pure functions of their traced inputs — no host
callbacks, no io/ordered effects, no PRNG source outside the MPRNG fold-in
chain — and if the scan carry (``ProtocolState``) is shape/dtype/treedef
stable, so scanned and stepwise execution are the same program.

This layer traces :func:`repro.core.engine.protocol_step`, every individual
phase function (via :func:`repro.core.engine.traceable_phases`), and a
``lax.scan`` of the step, over a config matrix that lights up every phase:
attacks on/off, adaptive clip, warm start, sampled digests, hierarchical
groups, elastic membership, verified/compressed wrappers, non-verifiable
baselines.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from tools.analysis.common import (
    CheckResult,
    Finding,
    callback_findings,
    constant_key_findings,
)

# one entry per engine feature axis — each config's protocol_step trace
# must be pure, key-disciplined, and carry-stable
ENGINE_CONFIGS = (
    ("base", dict(n=8, d=64)),
    ("attack_full", dict(n=8, d=64, attack="sign_flip", m_validators=2,
                         aggregator_attack=True, aggregator_scale=3.0,
                         misreport_s=True, false_accuse=True,
                         mprng_abort=True, delta_max=5.0)),
    ("adaptive_warm", dict(n=8, d=64, adaptive_tol=1e-4, warm_start=True)),
    ("sampled", dict(n=8, d=64, audit_k=2, m_validators=2)),
    ("hier", dict(n=8, d=64, groups=2, attack="sign_flip")),
    ("elastic", dict(n=8, d=64, n_events=4, attack="sign_flip")),
    ("verified_wrap", dict(n=8, d=64, aggregator="verified:trimmed_mean")),
    ("compressed", dict(
        n=8, d=64, attack="sign_flip",
        aggregator="compressed:butterfly_clip:codec=int8")),
    ("compressed_hier", dict(
        n=8, d=64, groups=2, aggregator="compressed:verified:mean")),
    ("nonverifiable", dict(n=8, d=64, aggregator="krum:n_byzantine=1",
                           attack="sign_flip")),
)


def purity_findings_for(fn, args, where: str):
    """Trace ``fn(*args)`` and return purity findings (callbacks, effects,
    off-chain PRNG). The reusable core — the negative-test suite points it
    at deliberately impure functions."""
    closed = jax.make_jaxpr(fn)(*args)
    return callback_findings(closed, where) + constant_key_findings(
        closed, where)


def carry_findings_for(fn, state_abs, args, where: str):
    """Shape/dtype/treedef stability of a state->state function: the first
    output of ``fn(state, *args)`` must be a pytree identical in structure
    and leaf avals to the input state. This is what makes the step
    ``lax.scan``-able without implicit promotion or silent reshapes."""
    findings = []
    out = jax.eval_shape(fn, state_abs, *args)
    new_state = out[0] if isinstance(out, tuple) else out
    in_leaves, in_tree = jax.tree.flatten(state_abs)
    out_leaves, out_tree = jax.tree.flatten(new_state)
    if in_tree != out_tree:
        findings.append(Finding(
            "carry_stability", where,
            f"state treedef drifts across the step: {in_tree} -> {out_tree}",
        ))
        return findings
    names = list(type(state_abs)._fields) if hasattr(
        type(state_abs), "_fields") else [str(i) for i in
                                          range(len(in_leaves))]
    for name, a, b in zip(names, in_leaves, out_leaves):
        if tuple(a.shape) != tuple(b.shape) or a.dtype != b.dtype:
            findings.append(Finding(
                "carry_stability", where,
                f"state field '{name}' drifts: {a.shape}/{a.dtype} -> "
                f"{b.shape}/{b.dtype} (scan carry must be fixed-point)",
            ))
    return findings


def scan_findings_for(cfg, engine, where: str):
    """Prove the step actually scans: trace ``lax.scan`` over T abstract
    steps. An unstable carry raises at trace time — reported as a finding,
    not a crash."""
    state = engine.abstract_state(cfg)
    n, d = cfg.n, cfg.d
    Gs = jax.ShapeDtypeStruct((3, n, d), jnp.float32)
    byz = jax.ShapeDtypeStruct((n,), jnp.float32)

    def scanned(state, Gs, byz_mask):
        def body(s, G):
            s2, out = engine.protocol_step(cfg, s, byz_mask, G, G)
            return s2, (out.g_hat, out.banned_now)
        return jax.lax.scan(body, state, Gs)

    try:
        jax.make_jaxpr(scanned)(state, Gs, byz)
    except (TypeError, ValueError) as e:
        return [Finding(
            "carry_stability", where,
            f"protocol_step does not scan: {e}",
        )]
    return []


def check_engine_purity() -> CheckResult:
    """Purity + PRNG discipline for protocol_step and every phase fn."""
    from repro.core import engine

    t0 = time.time()
    res = CheckResult("engine_purity")
    for tag, kw in ENGINE_CONFIGS:
        cfg = engine.EngineConfig(**kw)
        state = engine.abstract_state(cfg)
        n, d = cfg.n, cfg.d
        byz = jax.ShapeDtypeStruct((n,), jnp.float32)
        G = jax.ShapeDtypeStruct((n, d), jnp.float32)
        res.findings += purity_findings_for(
            partial(engine.protocol_step, cfg), (state, byz, G, G),
            f"protocol_step[{tag}]",
        )
        res.traced += 1
        for name, (fn, args) in engine.traceable_phases(cfg).items():
            res.findings += purity_findings_for(
                fn, args, f"{name}[{tag}]")
            res.traced += 1
    res.seconds = time.time() - t0
    return res


def check_engine_carry() -> CheckResult:
    """ProtocolState in == out (shape/dtype/treedef) + the scan proof."""
    from repro.core import engine

    t0 = time.time()
    res = CheckResult("engine_carry")
    for tag, kw in ENGINE_CONFIGS:
        cfg = engine.EngineConfig(**kw)
        state = engine.abstract_state(cfg)
        n, d = cfg.n, cfg.d
        byz = jax.ShapeDtypeStruct((n,), jnp.float32)
        G = jax.ShapeDtypeStruct((n, d), jnp.float32)
        res.findings += carry_findings_for(
            partial(engine.protocol_step, cfg), state, (byz, G, G),
            f"protocol_step[{tag}]",
        )
        res.findings += scan_findings_for(cfg, engine,
                                          f"scan_protocol[{tag}]")
        res.traced += 2
    res.seconds = time.time() - t0
    return res
