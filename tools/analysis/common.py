"""Shared jaxpr-walking machinery for btard-lint (``tools.analysis``).

Every check in this package reduces to the same move: trace real repo code
with abstract inputs (``jax.make_jaxpr`` — no FLOPs, no devices), then walk
the jaxpr — including every sub-jaxpr hiding in ``scan`` / ``while`` /
``cond`` / ``pjit`` / ``shard_map`` / ``pallas_call`` params — and assert
protocol invariants on the primitives found there. This module owns the
walking; the per-layer rule sets live in ``jaxpr_checks`` / ``wire_dtype``
/ ``contracts`` / ``kernels_check``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax import core as jcore

# Primitives that reach outside the traced program. Any of these inside a
# protocol phase breaks bitwise recomputability: a validator re-running the
# step cannot reproduce what a host callback did.
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed",
})

# Cross-peer collectives — the wire. Operand dtype at these IS the wire
# dtype; everything the digests commit to crosses one of these.
COLLECTIVE_PRIMS = frozenset({
    "all_to_all", "all_gather", "psum", "reduce_scatter", "psum_scatter",
    "ppermute", "pmax", "pmin",
})

# PRNG key creation. Keys must be created from *traced inputs* (the
# MPRNG chain: state.key / the shared seed); a key minted from a literal
# is randomness the protocol transcript does not cover.
KEY_CREATION_PRIMS = frozenset({"random_seed", "threefry_seed"})

# Shape/layout-only ops the dataflow walks look through when connecting a
# ``convert_element_type`` to the collective that produced (or consumes)
# its operand. ``optimization_barrier`` is deliberately NOT here — the
# barrier is the sanctioned way to pin a dtype boundary, so hitting one
# ends the walk.
TRANSPARENT_PRIMS = frozenset({
    "reshape", "transpose", "squeeze", "broadcast_in_dim", "slice",
    "dynamic_slice", "rev", "copy", "concatenate", "pad", "expand_dims",
})


@dataclass
class Finding:
    """One invariant violation. ``check`` names the rule that fired,
    ``where`` the traced target (function / spec / kernel), ``message``
    the violation itself."""

    check: str
    where: str
    message: str

    def to_dict(self) -> dict:
        return {"check": self.check, "where": self.where,
                "message": self.message}

    def __str__(self) -> str:  # CLI text rendering
        return f"[{self.check}] {self.where}: {self.message}"


@dataclass
class CheckResult:
    """Outcome of one named check: pass/fail + findings + trace count."""

    name: str
    findings: list = field(default_factory=list)
    traced: int = 0
    seconds: float = 0.0
    error: str | None = None

    @property
    def ok(self) -> bool:
        return not self.findings and self.error is None

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "status": "pass" if self.ok else "fail",
            "traced": self.traced,
            "seconds": round(self.seconds, 2),
            "error": self.error,
            "findings": [f.to_dict() for f in self.findings],
        }


def _param_jaxprs(eqn):
    """Every Jaxpr/ClosedJaxpr nested in an eqn's params (scan/while/cond
    bodies, pjit/shard_map/pallas_call callees, custom_* rules)."""
    out = []
    for v in eqn.params.values():
        for item in v if isinstance(v, (list, tuple)) else (v,):
            if isinstance(item, jcore.ClosedJaxpr):
                out.append(item.jaxpr)
            elif isinstance(item, jcore.Jaxpr):
                out.append(item)
    return out


def iter_jaxprs(jaxpr):
    """Yield ``jaxpr`` and every sub-jaxpr reachable from it."""
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        yield j
        for e in j.eqns:
            stack.extend(_param_jaxprs(e))


def iter_eqns(jaxpr):
    """Yield every eqn in ``jaxpr`` and all nested sub-jaxprs."""
    for j in iter_jaxprs(jaxpr):
        yield from j.eqns


def as_jaxpr(closed_or_open):
    return (closed_or_open.jaxpr
            if isinstance(closed_or_open, jcore.ClosedJaxpr)
            else closed_or_open)


def producer_map(jaxpr):
    """var -> producing eqn, for ONE jaxpr level (vars are jaxpr-scoped)."""
    prod = {}
    for e in jaxpr.eqns:
        for v in e.outvars:
            prod[v] = e
    return prod


def trace_back(var, prod):
    """Walk ``var`` backwards through layout-only (TRANSPARENT) eqns and
    return the first structural producer eqn, or None for jaxpr inputs/
    consts. Multi-input transparent ops (concatenate, pad) stop the walk —
    a merged value has no single producer."""
    seen = 0
    while True:
        e = prod.get(var)
        if e is None:
            return None
        if e.primitive.name not in TRANSPARENT_PRIMS:
            return e
        data_in = [v for v in e.invars if isinstance(v, jcore.Var)]
        if len(data_in) != 1:
            return e  # merged value: treat the transparent op as structural
        var = data_in[0]
        seen += 1
        if seen > 1000:  # defensive: malformed jaxpr
            return e


def is_widening(eqn) -> bool:
    """True for a ``convert_element_type`` that grows the element size —
    the upcast direction XLA is allowed to hoist across a collective,
    which is exactly what undoes wire compression (PR 6)."""
    if eqn.primitive.name != "convert_element_type":
        return False
    src = eqn.invars[0].aval.dtype
    dst = eqn.params["new_dtype"]
    try:
        return jax.numpy.dtype(dst).itemsize > jax.numpy.dtype(src).itemsize
    except TypeError:
        return False


def _is_key_like(aval) -> bool:
    """PRNG key material: a typed key array, or the raw uint32[2] pair."""
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return False
    if jax.dtypes.issubdtype(dtype, jax.dtypes.prng_key):
        return True
    shape = getattr(aval, "shape", ())
    return dtype == jax.numpy.uint32 and tuple(shape[-1:]) == (2,)


def constant_key_findings(closed, where: str, check: str = "purity"):
    """Findings for PRNG key material baked into the program as constants
    or minted from literals — randomness outside the MPRNG fold-in chain.

    Two ways a hidden key enters a traced phase: (a) ``jax.random.key(0)``
    / ``PRNGKey(0)`` traced with a literal seed (a ``random_seed`` /
    ``threefry_seed`` eqn whose operand is a Literal), (b) a key built
    eagerly on the host and closed over (a key-dtype / uint32[2] constvar).
    Honest recomputation still matches — the bits are deterministic — but
    the randomness is pinned across runs and invisible to the transcript,
    so the lint bans both forms outright.
    """
    findings = []
    jaxpr = as_jaxpr(closed)
    for cv in jaxpr.constvars:
        if _is_key_like(cv.aval):
            findings.append(Finding(
                check, where,
                f"constant PRNG key baked into the trace ({cv.aval}); "
                "derive keys from the state key / shared seed inputs",
            ))
    for e in iter_eqns(jaxpr):
        if e.primitive.name in KEY_CREATION_PRIMS:
            seed_in = e.invars[0]
            if isinstance(seed_in, jcore.Literal):
                findings.append(Finding(
                    check, where,
                    f"{e.primitive.name} from literal seed "
                    f"{seed_in.val!r}: off-chain PRNG (key material must "
                    "derive from traced inputs — the MPRNG chain)",
                ))
    return findings


def callback_findings(closed, where: str, check: str = "purity"):
    """Findings for host callbacks / io primitives / ordered effects."""
    findings = []
    jaxpr = as_jaxpr(closed)
    effects = getattr(closed, "effects", None) or jaxpr.effects
    if effects:
        findings.append(Finding(
            check, where,
            f"trace carries effects {sorted(str(x) for x in effects)}; "
            "protocol phases must be effect-free (bitwise recomputable)",
        ))
    for e in iter_eqns(jaxpr):
        if e.primitive.name in CALLBACK_PRIMS:
            findings.append(Finding(
                check, where,
                f"host-callback primitive '{e.primitive.name}' inside the "
                "traced program: validators cannot recompute host effects",
            ))
    return findings
