"""btard-lint layer 3: AggregatorSpec registry contracts.

The engine, the launch stages and the CLI all dispatch on a spec's
*capability flags* — ``verifiable`` decides whether the verification
pipeline runs, ``warm_startable`` whether the previous aggregate is carried
into the region, ``coordinatewise`` whether model shards may be aggregated
independently. A flag that disagrees with what the maker actually does is a
protocol bug waiting for the first config that trusts it. This layer checks
every registered spec (bases + ``verified:``/``compressed:`` wrappers)
against its *traced or executed* behavior:

* **C1 — name round-trip**: ``parse -> canonical -> parse`` is the
  identity, for the bare name and with every declared param set to a
  non-default value.
* **C2 — verifiable <=> tables**: under the engine's aggregation phase,
  verifiable specs produce (n, n) f32 digest tables; non-verifiable specs
  produce none (and :func:`verified_aggregate` rejects them).
* **C3 — warm_startable <=> v0 read**: built with ``warm_start=true``, a
  warm-startable spec's fn consumes the v0 input in its jaxpr; a
  non-warm-startable spec's fn ignores it.
* **C4 — weighted <=> weights read**: same, for the weights input.
* **C5 — coordinatewise is bitwise**: a flagged spec applied to two
  coordinate slices concatenates to the full-vector result *bitwise*
  (the exact property the launch path uses to skip the model-shard join).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax import core as jcore

from tools.analysis.common import CheckResult, Finding

# non-default value for every declared param name in the registry —
# exercises parse/canonical over every param's type (float/int/bool/str)
ALT_PARAMS = {
    "trim_ratio": 0.25,
    "eps": 1e-5,
    "max_iters": 7,
    "n_byzantine": 1,
    "tau": 0.5,
    "n_iters": 7,
    "adaptive_tol": 1e-3,
    "warm_start": True,
    "codec": "bf16",
}

_N, _D = 4, 16  # tiny concrete sizes for the bitwise probe


def _build_args(n, d):
    return (
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


def _consumed_inputs(fn, n, d):
    """Which of (xs, weights, v0, key) the built fn's jaxpr actually reads.

    Returns a 4-tuple of bools. An input is 'read' if its top-level invar
    appears in any equation (values threaded into sub-jaxprs surface in the
    carrying eqn's invars, so one level is enough)."""
    def wrapped(xs, weights, v0, key):
        out, _info = fn(xs, weights, v0,
                        jax.random.wrap_key_data(key))
        return out

    closed = jax.make_jaxpr(wrapped)(*_build_args(n, d))
    invars = closed.jaxpr.invars
    used = set()
    for e in closed.jaxpr.eqns:
        for v in e.invars:
            if isinstance(v, jcore.Var):
                used.add(v)
    return tuple(v in used for v in invars)


def check_registry_roundtrip() -> CheckResult:
    """C1 over every registered name, bare and fully parameterized."""
    from repro.core import aggregators as agg_mod

    t0 = time.time()
    res = CheckResult("registry_roundtrip")
    for name in agg_mod.registered_aggregators():
        defn = agg_mod.REGISTRY[name]
        texts = [name]
        if defn.defaults:
            alt = {k: ALT_PARAMS[k] for k, _ in defn.defaults}
            spec = agg_mod.AggregatorSpec(name, tuple(sorted(alt.items())))
            texts.append(spec.canonical())
        for text in texts:
            res.traced += 1
            try:
                spec = agg_mod.AggregatorSpec.parse(text)
            except Exception as e:  # noqa: BLE001 — report, don't crash
                res.findings.append(Finding(
                    "registry_roundtrip", name,
                    f"parse({text!r}) raised {e!r}"))
                continue
            canon = spec.canonical()
            again = agg_mod.AggregatorSpec.parse(canon)
            if again != spec or again.canonical() != canon:
                res.findings.append(Finding(
                    "registry_roundtrip", name,
                    f"{text!r} -> {canon!r} -> {again.canonical()!r} "
                    "is not a fixed point",
                ))
    res.seconds = time.time() - t0
    return res


def check_capability_flags() -> CheckResult:
    """C2-C4: flags vs traced behavior, every registered spec."""
    from repro.core import aggregators as agg_mod
    from repro.core import engine

    t0 = time.time()
    res = CheckResult("capability_flags")
    for name in agg_mod.registered_aggregators():
        defn = agg_mod.REGISTRY[name]
        spec = agg_mod.AggregatorSpec(name).with_defaults(
            warm_start=True, n_byzantine=1)
        res.traced += 1

        # C2: tables under the engine aggregation phase
        cfg = engine.EngineConfig(n=8, d=64, aggregator=spec.canonical())
        state = engine.abstract_state(cfg)
        out = jax.eval_shape(
            lambda s, G, w, sd: engine.phase_aggregation(cfg, s, G, w, sd),
            state,
            jax.ShapeDtypeStruct((8, 64), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.int32),
        )
        _agg, _parts, _z, s_tbl, norm_tbl, _it = out
        if defn.verifiable and (s_tbl is None or norm_tbl is None):
            res.findings.append(Finding(
                "capability_flags", name,
                "flagged verifiable but the aggregation phase emits no "
                "digest tables",
            ))
        elif defn.verifiable:
            if (tuple(s_tbl.shape) != (8, 8)
                    or s_tbl.dtype != jnp.float32
                    or norm_tbl.dtype != jnp.float32):
                res.findings.append(Finding(
                    "capability_flags", name,
                    f"digest tables are {s_tbl.shape}/{s_tbl.dtype}, "
                    "expected (n, n) float32",
                ))
        elif s_tbl is not None:
            res.findings.append(Finding(
                "capability_flags", name,
                "flagged non-verifiable but the aggregation phase emits "
                "digest tables",
            ))

        # C3/C4: does the built fn read v0 / weights?
        fn = spec.build(8, 64)
        _xs_used, w_used, v0_used, _k = _consumed_inputs(fn, 8, 64)
        if defn.warm_startable and not v0_used:
            res.findings.append(Finding(
                "capability_flags", name,
                "flagged warm_startable (built with warm_start=true) but "
                "the fn never reads v0: the launch carry would be wasted",
            ))
        if not defn.warm_startable and v0_used:
            res.findings.append(Finding(
                "capability_flags", name,
                "not flagged warm_startable but the fn reads v0: the "
                "launch path would never thread the carry it needs",
            ))
        if defn.weighted and not w_used:
            res.findings.append(Finding(
                "capability_flags", name,
                "flagged weighted but the fn never reads weights: "
                "banned peers would keep their votes",
            ))
    res.seconds = time.time() - t0
    return res


def check_coordinatewise() -> CheckResult:
    """C5: the bitwise split/concat probe for every flagged spec.

    The launch path trusts ``coordinatewise`` to aggregate model shards
    independently; digests are then recomputed per shard, so anything
    short of BITWISE equality lets honest peers accuse each other."""
    from repro.core import aggregators as agg_mod

    t0 = time.time()
    res = CheckResult("coordinatewise")
    key = jax.random.PRNGKey(7)
    xs = jax.random.normal(key, (_N, _D), jnp.float32)
    w = jnp.ones((_N,), jnp.float32)
    h = _D // 2
    for name in agg_mod.registered_aggregators():
        defn = agg_mod.REGISTRY[name]
        if not defn.coordinatewise:
            continue
        res.traced += 1
        spec = agg_mod.AggregatorSpec(name)
        full, _ = spec.build(_N, _D)(xs, w, None, None)
        left, _ = spec.build(_N, h)(xs[:, :h], w, None, None)
        right, _ = spec.build(_N, h)(xs[:, h:], w, None, None)
        stitched = jnp.concatenate([left, right])
        if bool(jnp.any(full != stitched)):
            mx = float(jnp.max(jnp.abs(
                full.astype(jnp.float32) - stitched.astype(jnp.float32))))
            res.findings.append(Finding(
                "coordinatewise", name,
                "flagged coordinatewise but split/concat is not bitwise "
                f"(max |diff| {mx:.3e}): per-shard aggregation would "
                "diverge from the full-vector recompute",
            ))
    res.seconds = time.time() - t0
    return res
