"""btard-lint layer 2: wire-dtype contracts of the launch aggregation stage.

The robust all-reduce ships gradients over collectives in a *declared* wire
dtype — bf16 transport for the plain butterfly, the codec dtype (int8/bf16)
for ``compressed:*`` specs — and every digest is f32 computed from the
post-exchange wire values. XLA is free to hoist a later f32 upcast across a
collective unless an ``optimization_barrier`` pins the boundary; when it
does, the wire silently carries f32 and the compression is undone (the PR 6
bug class). These rules catch that statically:

* **W1 — unpinned upcast of a collective result**: a widening
  ``convert_element_type`` whose operand dataflows (through layout-only
  ops) straight from a collective output, with no barrier in between.
* **W2 — widened operand feeding a collective**: the same hoist written by
  hand — upcasting *before* the exchange.
* **W3 — wire presence**: at least one collective actually carries the
  declared wire dtype (compression that never reaches the wire is a no-op).
* **W4 — collective dtype allow-list**: no collective ships anything
  outside {wire dtype, f32 scalars/tables, integers, bool}.
* **W5 — digests are f32**: the broadcast verification tables and checksum
  leave the stage as float32.

Tracing needs ZERO devices: an ``AbstractMesh`` + ``shard_map`` +
``jax.make_jaxpr`` stages the collectives abstractly.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax import core as jcore
from jax.experimental.shard_map import shard_map
from jax.sharding import AbstractMesh, PartitionSpec as P

from tools.analysis.common import (
    COLLECTIVE_PRIMS,
    CheckResult,
    Finding,
    as_jaxpr,
    callback_findings,
    constant_key_findings,
    is_widening,
    iter_jaxprs,
    producer_map,
    trace_back,
)

N_PEERS = 8
D = 512

# (label, spec string, aggregation_stage kwargs, declared wire dtype).
# Transport is always bf16 — the narrow dtype is what makes a hoisted
# upcast visible in the trace (an f32->f32 convert stages no eqn at all).
SPEC_MATRIX = (
    ("butterfly_clip", "butterfly_clip", {}, jnp.bfloat16),
    ("butterfly_warm", "butterfly_clip:warm_start=true", {"v0": True},
     jnp.bfloat16),
    ("butterfly_adaptive", "butterfly_clip:adaptive_tol=1e-4", {},
     jnp.bfloat16),
    ("verified_mean", "verified:mean", {}, jnp.bfloat16),
    ("verified_trimmed", "verified:trimmed_mean", {}, jnp.bfloat16),
    ("compressed_int8", "compressed:butterfly_clip:codec=int8", {},
     jnp.int8),
    ("compressed_bf16", "compressed:butterfly_clip:codec=bf16", {},
     jnp.bfloat16),
    ("compressed_verified", "compressed:verified:mean:codec=int8", {},
     jnp.int8),
    ("hier", "butterfly_clip", {"groups": 2}, jnp.bfloat16),
    ("hier_compressed", "compressed:butterfly_clip:codec=int8",
     {"groups": 2}, jnp.int8),
    ("sampled", "butterfly_clip", {"audit_k": 2}, jnp.bfloat16),
    ("lying_owner", "butterfly_clip", {"agg_attack": 2.0}, jnp.bfloat16),
    ("nonverifiable_mean", "mean", {}, jnp.bfloat16),
    ("nonverifiable_krum", "krum:n_byzantine=1", {}, jnp.bfloat16),
)

# The real-model gauntlet cell: the same stage traced at the flat gradient
# dim of the reduced zoo transformer (core.flatten boundary over
# abstract_params — no weights materialize), under the mixed-precision
# contract the gauntlet ships: bf16 payload on the wire, f32 digests over
# dequantized wire values. Synthetic-D green + real-D red would mean the
# contract breaks at scale (e.g. a dim-dependent rewrite hoists the upcast).
REAL_MODEL_SPEC = (
    "real_model_albert", "compressed:verified:mean:codec=bf16", jnp.bfloat16
)


def _real_model_dim() -> int:
    """Flat gradient dim of the gauntlet's reference arch, padded to the
    peer count (the same ravel boundary BTARDTrainer flattens at)."""
    from repro.configs import get_config, reduce_config
    from repro.core.flatten import FlatBoundary
    from repro.models.model import Model

    model = Model(reduce_config(get_config("albert-large")))
    d = FlatBoundary(model.abstract_params()).d
    return -(-d // N_PEERS) * N_PEERS

# dtypes that may legitimately cross a collective besides the wire dtype:
# f32 sidecar scales / digest tables / level-2 combines, index/mask ints
_ALWAYS_OK = frozenset({
    jnp.dtype(jnp.float32), jnp.dtype(jnp.int32), jnp.dtype(jnp.uint32),
    jnp.dtype(jnp.bool_),
})

_VERIF_KEYS = ("checksum", "votes", "clip_iters", "s_table", "norm_table",
               "audit_target", "audit_grad_mismatch", "audit_agg_mismatch")


def trace_aggregation_stage(spec: str, *, groups=None, audit_k=None,
                            agg_attack=None, v0=False, use_pallas=False,
                            d=D):
    """Trace one launch-side robust all-reduce on an abstract 8-peer mesh.

    Returns (closed_jaxpr, out_avals) for ``aggregation_stage`` wrapped in
    the same manual-region harness the real train step uses. ``d`` is the
    per-peer gradient dim (default the synthetic ``D``; the real-model cell
    passes the zoo arch's flat dim).
    """
    from repro.launch.steps import aggregation_stage

    mesh = AbstractMesh((("peers", N_PEERS),))
    hier = groups is not None and groups > 1

    def region(g_vec, weights, seed, byz_mask, v0_full):
        return aggregation_stage(
            g_vec, "peers", N_PEERS, spec, weights, seed,
            use_pallas=use_pallas, delta_max=10.0,
            v0_full=v0_full if v0 else None,
            groups=groups, audit_k=audit_k,
            agg_attack_scale=agg_attack,
            byz_mask=byz_mask if agg_attack is not None else None,
        )

    verif_specs = {k: P("peers") for k in _VERIF_KEYS}
    verif_specs["s_table"] = P("peers", None) if hier else P(None, None)
    verif_specs["norm_table"] = verif_specs["s_table"]
    f = shard_map(
        region, mesh=mesh,
        in_specs=(P("peers"), P(), P(), P(), P()),
        out_specs=(P(), verif_specs),
        check_rep=False,
    )
    args = (
        jax.ShapeDtypeStruct((N_PEERS * d,), jnp.bfloat16),
        jax.ShapeDtypeStruct((N_PEERS,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((N_PEERS,), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
    )
    closed = jax.make_jaxpr(f)(*args)
    out = jax.eval_shape(f, *args)
    return closed, out


def wire_findings(closed, where: str, wire_dtype,
                  transport_dtype=jnp.bfloat16):
    """Rules W1-W4 over one traced stage. ``wire_dtype`` is the payload-
    exchange dtype (the codec dtype for compressed specs); the aggregate
    redistribution still travels in ``transport_dtype``, so both are
    sanctioned on the wire — anything else (beyond f32 scalars/tables and
    integers) is a leak."""
    findings = []
    wire = jnp.dtype(wire_dtype)
    ok = _ALWAYS_OK | {wire, jnp.dtype(transport_dtype)}
    saw_wire = False
    for j in iter_jaxprs(as_jaxpr(closed)):
        prod = producer_map(j)
        for e in j.eqns:
            if is_widening(e) and isinstance(e.invars[0], jcore.Var):
                src = trace_back(e.invars[0], prod)
                if src is not None and src.primitive.name in COLLECTIVE_PRIMS:
                    findings.append(Finding(
                        "wire_dtype", where,
                        f"f-widening convert ({e.invars[0].aval.dtype} -> "
                        f"{e.params['new_dtype']}) consumes the result of "
                        f"'{src.primitive.name}' with no optimization_barrier"
                        " between them: XLA may hoist the upcast across the "
                        "collective and ship the wide dtype on the wire",
                    ))
            if e.primitive.name in COLLECTIVE_PRIMS:
                for v in e.invars:
                    if not isinstance(v, jcore.Var):
                        continue
                    if jnp.dtype(v.aval.dtype) == wire:
                        saw_wire = True
                    elif jnp.dtype(v.aval.dtype) not in ok:
                        findings.append(Finding(
                            "wire_dtype", where,
                            f"'{e.primitive.name}' ships dtype "
                            f"{v.aval.dtype}; sanctioned wire dtypes are "
                            f"{wire} (payload) / "
                            f"{jnp.dtype(transport_dtype)} (transport) "
                            "plus f32 scalars/tables",
                        ))
                    src = trace_back(v, prod)
                    if src is not None and is_widening(src):
                        findings.append(Finding(
                            "wire_dtype", where,
                            f"operand of '{e.primitive.name}' was widened "
                            f"to {src.params['new_dtype']} before the "
                            "exchange: upcast after the collective (behind "
                            "a barrier), not before it",
                        ))
    if not saw_wire:
        findings.append(Finding(
            "wire_dtype", where,
            f"no collective carries the declared wire dtype {wire}: the "
            "narrow transport/codec never reaches the wire",
        ))
    return findings


def digest_findings(out, where: str):
    """Rule W5: tables/checksum leave the stage as f32 (digests are f32
    computed from wire values — the dtype every validator recomputes in)."""
    findings = []
    _, verif = out
    for k in ("checksum", "s_table", "norm_table"):
        if jnp.dtype(verif[k].dtype) != jnp.dtype(jnp.float32):
            findings.append(Finding(
                "wire_dtype", where,
                f"digest output '{k}' has dtype {verif[k].dtype}, "
                "expected float32",
            ))
    return findings


def check_wire_dtype() -> CheckResult:
    t0 = time.time()
    res = CheckResult("wire_dtype")
    for label, spec, kw, wire in SPEC_MATRIX:
        where = f"aggregation_stage[{label}]"
        closed, out = trace_aggregation_stage(spec, **kw)
        res.findings += wire_findings(closed, where, wire)
        res.findings += digest_findings(out, where)
        # the stage is protocol-critical launch code: purity applies too
        res.findings += callback_findings(closed, where)
        res.findings += constant_key_findings(closed, where)
        res.traced += 1
    # real-model cell: same rules at the gauntlet arch's flat dim
    label, spec, wire = REAL_MODEL_SPEC
    where = f"aggregation_stage[{label}]"
    closed, out = trace_aggregation_stage(spec, d=_real_model_dim())
    res.findings += wire_findings(closed, where, wire)
    res.findings += digest_findings(out, where)
    res.findings += callback_findings(closed, where)
    res.findings += constant_key_findings(closed, where)
    res.traced += 1
    res.seconds = time.time() - t0
    return res
