from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adam,
    cosine_schedule,
    constant_schedule,
    global_norm,
    clip_by_global_norm,
    lamb,
    sgd,
    warmup_cosine_schedule,
)
