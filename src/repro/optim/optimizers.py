"""Optimizers (SGD+Nesterov, Adam, LAMB) and LR schedules.

Minimal optax-style API: ``opt.init(params) -> state``,
``opt.update(grads, state, params, step) -> (updates, state)``; updates are
ADDED to params. All states are pytrees of jnp arrays (checkpointable,
shardable with the same specs as params).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def constant_schedule(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr, total_steps, final_scale=0.0):
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(np.pi * frac))
        return lr * (final_scale + (1 - final_scale) * cos)

    return fn


def warmup_cosine_schedule(lr, warmup_steps, total_steps, final_scale=0.0):
    cos = cosine_schedule(lr, max(1, total_steps - warmup_steps), final_scale)

    def fn(step):
        warm = lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return fn


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-30))
    return jax.tree.map(lambda l: (l * scale).astype(l.dtype), tree), g


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def _sched(lr):
    return lr if callable(lr) else constant_schedule(lr)


def _is_float(leaf):
    return jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating)


def sgd(lr, momentum=0.0, nesterov=False, weight_decay=0.0):
    lr = _sched(lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        lr_t = lr(step)

        def upd(g, p, m=None):
            if not _is_float(p):
                # integer / bool leaves (counters, ids): no decay, no moment
                return jnp.zeros(p.shape, jnp.float32), m
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if m is None:
                return -lr_t * g, None
            m_new = momentum * m + g
            d = g + momentum * m_new if nesterov else m_new
            return -lr_t * d, m_new

        if momentum == 0.0:
            ups = jax.tree.map(lambda g, p: upd(g, p)[0], grads, params)
            return ups, state
        pairs = jax.tree.map(upd, grads, params, state["m"])
        ups = jax.tree.map(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return ups, {"m": m}

    return Optimizer(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0):
    lr = _sched(lr)

    def init(params):
        z = lambda l: jnp.zeros(l.shape, jnp.float32)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
        }

    def update(grads, state, params, step):
        lr_t = lr(step)
        t = step + 1

        def upd(g, p, m, v):
            if not _is_float(p):
                return jnp.zeros(p.shape, jnp.float32), m, v
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / (1 - b1**t)
            vhat = v_new / (1 - b2**t)
            d = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return -lr_t * d, m_new, v_new

        tri = jax.tree.map(upd, grads, params, state["m"], state["v"])
        leaf = lambda x: isinstance(x, tuple)
        return (
            jax.tree.map(lambda tr: tr[0], tri, is_leaf=leaf),
            {
                "m": jax.tree.map(lambda tr: tr[1], tri, is_leaf=leaf),
                "v": jax.tree.map(lambda tr: tr[2], tri, is_leaf=leaf),
            },
        )

    return Optimizer(init, update)


def lamb(lr, b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.01):
    """LAMB (You et al. 2020) — the paper's ALBERT optimizer (§4.2)."""
    lr = _sched(lr)

    def init(params):
        z = lambda l: jnp.zeros(l.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr_t = lr(step)
        t = step + 1

        def upd(g, p, m, v):
            if not _is_float(p):
                return jnp.zeros(p.shape, jnp.float32), m, v
            g = g.astype(jnp.float32)
            pf = p.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / (1 - b1**t)
            vhat = v_new / (1 - b2**t)
            u = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf
            w_norm = jnp.linalg.norm(pf.reshape(-1))
            u_norm = jnp.linalg.norm(u.reshape(-1))
            trust = jnp.where(
                (w_norm > 0) & (u_norm > 0), w_norm / jnp.maximum(u_norm, 1e-30), 1.0
            )
            return -lr_t * trust * u, m_new, v_new

        tri = jax.tree.map(upd, grads, params, state["m"], state["v"])
        leaf = lambda x: isinstance(x, tuple)
        return (
            jax.tree.map(lambda tr: tr[0], tri, is_leaf=leaf),
            {
                "m": jax.tree.map(lambda tr: tr[1], tri, is_leaf=leaf),
                "v": jax.tree.map(lambda tr: tr[2], tri, is_leaf=leaf),
            },
        )

    return Optimizer(init, update)


def apply_updates(params, updates):
    # non-float leaves pass through untouched: an int32 counter round-tripped
    # through f32 would lose bits above 2**24 even with a zero update
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype) if _is_float(p) else p,
        params,
        updates,
    )
