"""Sybil-resistance heuristic (paper §3.3 / App. F).

A new peer joining mid-run must prove continuous honest work before it is
counted: for ``probation_steps`` consecutive steps it computes gradients from
its assigned public seeds and broadcasts commitments; existing peers spot-
check them (same validator machinery). Only after a clean probation does the
peer enter the active set — so a Sybil attacker's influence stays
proportional to its actual compute, not to how many identities it forges.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.protocol import grad_hash


@dataclass
class JoinRequest:
    peer_id: int
    joined_at: int
    clean_steps: int = 0
    dishonest: bool = False  # simulation: does this identity actually compute?


class SybilGate:
    """Tracks probation for joining peers; spot-checks their commitments."""

    def __init__(self, grad_fn, probation_steps: int = 20, check_prob: float = 0.5, seed: int = 0):
        self.grad_fn = grad_fn
        self.probation = probation_steps
        self.check_prob = check_prob
        self.rng = np.random.default_rng(seed)
        self.pending: dict[int, JoinRequest] = {}
        self.admitted: list[int] = []
        self.rejected: list[int] = []

    def request_join(self, peer_id: int, step: int, dishonest: bool = False):
        self.pending[peer_id] = JoinRequest(peer_id, step, dishonest=dishonest)

    def step(self, params, t):
        """One probation round: each pending peer submits a gradient hash;
        admitted once `probation` clean (spot-checked) rounds accumulate."""
        done = []
        for pid, req in self.pending.items():
            honest = np.asarray(self.grad_fn(pid, t, params, False), np.float32)
            if req.dishonest:
                # a Sybil identity with no compute behind it sends garbage
                submitted = self.rng.normal(size=honest.shape).astype(np.float32)
            else:
                submitted = honest
            commitment = grad_hash(submitted)
            if self.rng.random() < self.check_prob:
                if commitment != grad_hash(honest):
                    req.dishonest_caught = True
                    self.rejected.append(pid)
                    done.append(pid)
                    continue
            req.clean_steps += 1
            if req.clean_steps >= self.probation:
                self.admitted.append(pid)
                done.append(pid)
        for pid in done:
            self.pending.pop(pid, None)
        return list(self.admitted), list(self.rejected)
