"""Sybil-gated admission + slot lifecycle (paper §3.3 / App. F).

The volunteer-compute setting (Diskin et al., PAPERS.md) has peers joining
and leaving mid-run, so the engine's peer axis is a static ``max_peers``
capacity of SLOTS, each in one of four lifecycle states:

    vacant ──join──▶ probation ──clean window──▶ active
       ▲                 │                          │
       └──────leave──────┼───────leave──────────────┤
                         ▼                          ▼
                      banned ◀──accuse/checksum/audit

A joining peer does not vote: for ``probation_steps`` consecutive rounds it
computes gradients from its assigned PUBLIC seeds and broadcasts the
commitment; validators recompute from the same seeds and compare — exactly
the CheckComputations digest machinery, applied to a row that never enters
the aggregate. One mismatch bans the identity (``BAN_SYBIL``); only a fully
clean window flips the slot to active. A Sybil attacker's influence is
therefore bounded by the honest public-seed work it actually performs —
forging identities buys probation seats, not aggregate weight.

Ban and accusation ledgers are keyed by IDENTITY, not slot: a slot freed by
a leave can be reclaimed by a new joiner without laundering the previous
occupant's history (Karimireddy et al.'s history argument, PAPERS.md). A
banned identity rejoining under the SAME key is re-banned at admission from
the identity ledger; rejoining under a NEW key starts a fresh identity that
must survive probation — where its Byzantine behaviour is caught before it
ever re-enters the aggregate.

Three call surfaces share the rule:

* the jit-safe functions below (``probation_check`` / ``probation_step``)
  — pure, statically shaped, called from ``core.engine.protocol_step`` so
  churn composes with ``lax.scan``;
* :class:`HostMembership` — the launch path's host-side mirror: the same
  lifecycle state machine driven between scan dispatches by the in-program
  probe/audit observations (``launch.train --churn``);
* :class:`SybilGate` — the legacy host simulation of App. F (kept for the
  probation-economics test), now expressed over the same digest check.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

# Slot lifecycle codes (ProtocolState.lifecycle / HostMembership.lifecycle)
SLOT_VACANT = 0
SLOT_PROBATION = 1
SLOT_ACTIVE = 2
SLOT_BANNED = 3

LIFECYCLE_NAMES = {
    SLOT_VACANT: "vacant",
    SLOT_PROBATION: "probation",
    SLOT_ACTIVE: "active",
    SLOT_BANNED: "banned",
}


# ---------------------------------------------------------------------------
# Jit-safe probation gate (engine-side)
# ---------------------------------------------------------------------------
def probation_check(G, honest_G, probation_b):
    """Validator spot-check of the probation rows' public-seed work.

    ``G`` is what each probation peer broadcast for this step; ``honest_G``
    is what any validator recomputing from the same public seed obtains.
    Commitment equality ≡ array equality (the engine's standing
    equivalence): a row that differs in ANY coordinate fails the check.
    Probation rows never enter the aggregate, so this comparison is over
    the raw committed payload, not the wire projection.

    Returns (n,) bool — probation rows caught misbehaving this step.
    """
    return jnp.any(G != honest_G, axis=1) & probation_b


def probation_step(probation_b, mismatch, clean, probation_steps: int):
    """Advance the probation window one step (pure, statically shaped).

    clean counter: reset on a mismatch, +1 on a clean spot-check, and
    pinned to 0 outside probation (a fresh joiner always starts at 0).
    Returns (new_clean, promote, sybil_ban):

    * ``sybil_ban``  — probation rows banned NOW (any mismatch; one strike);
    * ``promote``    — probation rows whose window completed this step
      (``probation_steps`` consecutive clean checks): active from the next
      round's aggregate on;
    * ``new_clean``  — the updated counter.
    """
    new_clean = jnp.where(
        probation_b & ~mismatch, clean + 1, jnp.zeros_like(clean)
    )
    promote = probation_b & ~mismatch & (new_clean >= probation_steps)
    sybil_ban = mismatch & probation_b
    return new_clean, promote, sybil_ban


# ---------------------------------------------------------------------------
# Host-side membership ledger (launch path)
# ---------------------------------------------------------------------------
@dataclass
class MembershipEvent:
    step: int
    kind: str  # "join" | "leave"
    slot: int


class HostMembership:
    """The slot lifecycle state machine on the host, for the launch path.

    ``launch.train`` keeps one of these next to its weights vector: events
    from the ``--churn`` schedule toggle slots between scan dispatches, the
    in-program probe observations (``verif["probe_mismatch"]`` — each
    peer's max deviation from its public-seed recompute) drive the
    probation window, and ban observations (checksum / audit offenders)
    feed the identity ledger. Identities are allocated monotonically: a
    slot reclaimed after a leave gets a FRESH identity (the new-key rejoin
    adversary), so the banned set never shrinks — bans survive churn by
    construction.

    The whole state round-trips through :meth:`to_tree` /
    :meth:`from_tree` for checkpointed recovery (``--checkpoint-dir`` /
    ``--resume``).
    """

    def __init__(self, n_slots: int, probation_steps: int = 3,
                 events: list[MembershipEvent] | None = None,
                 start_vacant: tuple[int, ...] = ()):
        self.n = int(n_slots)
        self.probation_steps = int(probation_steps)
        self.events = sorted(events or [], key=lambda e: e.step)
        self.lifecycle = np.full((self.n,), SLOT_ACTIVE, np.int32)
        self.slot_identity = np.arange(self.n, dtype=np.int32)
        self.clean = np.zeros((self.n,), np.int32)
        for s in start_vacant:
            self.lifecycle[s] = SLOT_VACANT
            self.slot_identity[s] = -1
        self.next_identity = int(self.n)
        self.banned_identities: dict[int, int] = {}  # identity -> ban step
        self.log: list[str] = []

    # -- views ------------------------------------------------------------
    def weights(self) -> np.ndarray:
        return (self.lifecycle == SLOT_ACTIVE).astype(np.float32)

    def probation_mask(self) -> np.ndarray:
        return self.lifecycle == SLOT_PROBATION

    def banned_slots(self) -> list[int]:
        return sorted(np.nonzero(self.lifecycle == SLOT_BANNED)[0].tolist())

    # -- transitions ------------------------------------------------------
    def apply_events(self, step: int):
        """Fire every scheduled join/leave with event.step == step."""
        for ev in self.events:
            if ev.step != step:
                continue
            if ev.kind == "leave":
                if self.lifecycle[ev.slot] == SLOT_VACANT:
                    continue
                self.log.append(
                    f"step {step}: slot {ev.slot} "
                    f"(identity {self.slot_identity[ev.slot]}) left"
                )
                self.lifecycle[ev.slot] = SLOT_VACANT
                self.slot_identity[ev.slot] = -1
                self.clean[ev.slot] = 0
            elif ev.kind == "join":
                if self.lifecycle[ev.slot] != SLOT_VACANT:
                    continue  # join onto an occupied slot is a no-op
                ident = self.next_identity
                self.next_identity += 1
                self.slot_identity[ev.slot] = ident
                self.clean[ev.slot] = 0
                # a fresh identity can never be pre-banned; same-key rejoin
                # (identity reuse) would short-circuit here
                if ident in self.banned_identities:
                    self.lifecycle[ev.slot] = SLOT_BANNED
                else:
                    self.lifecycle[ev.slot] = SLOT_PROBATION
                self.log.append(
                    f"step {step}: identity {ident} joined at slot "
                    f"{ev.slot} (probation)"
                )
            else:
                raise ValueError(f"unknown membership event kind {ev.kind!r}")

    def ban_slots(self, slots, step: int):
        """Ban the current OCCUPANTS of ``slots`` (identity-keyed)."""
        newly = []
        for s in sorted(set(int(x) for x in slots)):
            ident = int(self.slot_identity[s])
            if ident < 0 or self.lifecycle[s] == SLOT_BANNED:
                continue
            self.lifecycle[s] = SLOT_BANNED
            self.banned_identities.setdefault(ident, int(step))
            newly.append((s, ident))
        if newly:
            self.log.append(
                f"step {step}: banned " +
                ", ".join(f"slot {s} (identity {i})" for s, i in newly)
            )
        return [s for s, _ in newly]

    def observe_probe(self, probe_row, step: int, tol: float = 1e-6):
        """One step's probation spot-check results: ``probe_row`` is the
        per-slot max deviation between the broadcast payload and the
        public-seed recompute (exact zero for honest peers). Any excess
        over float tolerance during probation bans the identity; a clean
        window of ``probation_steps`` checks promotes the slot."""
        probe_row = np.asarray(probe_row, np.float64)
        for s in range(self.n):
            if self.lifecycle[s] != SLOT_PROBATION:
                continue
            if probe_row[s] > tol:
                ident = int(self.slot_identity[s])
                self.lifecycle[s] = SLOT_BANNED
                self.banned_identities.setdefault(ident, int(step))
                self.log.append(
                    f"step {step}: probation spot-check failed — banned "
                    f"slot {s} (identity {ident})"
                )
            else:
                self.clean[s] += 1
                if self.clean[s] >= self.probation_steps:
                    self.lifecycle[s] = SLOT_ACTIVE
                    self.log.append(
                        f"step {step}: identity "
                        f"{int(self.slot_identity[s])} admitted at slot {s}"
                    )

    # -- checkpoint round-trip -------------------------------------------
    def to_tree(self) -> dict:
        ids = sorted(self.banned_identities)
        return {
            "lifecycle": self.lifecycle.copy(),
            "slot_identity": self.slot_identity.copy(),
            "clean": self.clean.copy(),
            "next_identity": np.asarray(self.next_identity, np.int32),
            "banned_ids": np.asarray(ids, np.int32),
            "banned_steps": np.asarray(
                [self.banned_identities[i] for i in ids], np.int32
            ),
        }

    def restore_tree(self, tree: dict):
        self.lifecycle = np.asarray(tree["lifecycle"], np.int32).copy()
        self.slot_identity = np.asarray(
            tree["slot_identity"], np.int32
        ).copy()
        self.clean = np.asarray(tree["clean"], np.int32).copy()
        self.next_identity = int(tree["next_identity"])
        self.banned_identities = {
            int(i): int(s)
            for i, s in zip(tree["banned_ids"], tree["banned_steps"])
        }
        return self

    def summary(self) -> dict:
        return {
            "lifecycle": self.lifecycle.tolist(),
            "slot_identity": self.slot_identity.tolist(),
            "weights": self.weights().tolist(),
            "banned_slots": self.banned_slots(),
            "banned_identities": sorted(self.banned_identities),
            "next_identity": self.next_identity,
        }


def parse_churn(spec: str) -> list[MembershipEvent]:
    """Parse ``--churn "leave@6:1,join@8:1"`` into membership events:
    ``KIND@STEP:SLOT`` comma-separated, kind in {join, leave}. A join always
    allocates a FRESH identity for the slot (the new-key rejoin model)."""
    events = []
    for item in filter(None, (s.strip() for s in spec.split(","))):
        try:
            kind, rest = item.split("@", 1)
            step, slot = rest.split(":", 1)
        except ValueError:
            raise ValueError(
                f"bad churn event {item!r}: expected KIND@STEP:SLOT"
            ) from None
        if kind not in ("join", "leave"):
            raise ValueError(f"bad churn kind {kind!r} (join|leave)")
        events.append(MembershipEvent(int(step), kind, int(slot)))
    return events


# ---------------------------------------------------------------------------
# Legacy App. F probation-economics simulation (host-side)
# ---------------------------------------------------------------------------
@dataclass
class JoinRequest:
    peer_id: int
    joined_at: int
    clean_steps: int = 0
    dishonest: bool = False  # simulation: does this identity actually compute?


class SybilGate:
    """The original host simulation of App. F probation: pending identities
    submit gradient commitments, spot-checked with ``check_prob``; kept as
    the probabilistic-economics model (expected probation cost ~ honest
    work) next to the engine's deterministic every-step gate above."""

    def __init__(self, grad_fn, probation_steps: int = 20,
                 check_prob: float = 0.5, seed: int = 0):
        self.grad_fn = grad_fn
        self.probation = probation_steps
        self.check_prob = check_prob
        self.rng = np.random.default_rng(seed)
        self.pending: dict[int, JoinRequest] = {}
        self.admitted: list[int] = []
        self.rejected: list[int] = []

    def request_join(self, peer_id: int, step: int, dishonest: bool = False):
        self.pending[peer_id] = JoinRequest(peer_id, step, dishonest=dishonest)

    def step(self, params, t):
        """One probation round: each pending peer submits a gradient
        commitment; admitted once ``probation`` clean (spot-checked) rounds
        accumulate."""
        done = []
        for pid, req in self.pending.items():
            honest = np.asarray(
                self.grad_fn(pid, t, params, False), np.float32
            )
            if req.dishonest:
                # a Sybil identity with no compute behind it sends garbage
                submitted = self.rng.normal(size=honest.shape).astype(
                    np.float32
                )
            else:
                submitted = honest
            if self.rng.random() < self.check_prob:
                caught = bool(
                    np.asarray(
                        probation_check(
                            jnp.asarray(submitted)[None],
                            jnp.asarray(honest)[None],
                            jnp.ones((1,), bool),
                        )
                    )[0]
                )
                if caught:
                    self.rejected.append(pid)
                    done.append(pid)
                    continue
            req.clean_steps += 1
            if req.clean_steps >= self.probation:
                self.admitted.append(pid)
                done.append(pid)
        for pid in done:
            self.pending.pop(pid, None)
        return list(self.admitted), list(self.rejected)
