"""The ravel boundary: mixed-dtype parameter/gradient pytrees <-> flat f32.

Everything inside the BTARD engine — butterfly partitioning, CenteredClip,
the Alg. 6 digest tables, the compressed wire codecs, sampled/hierarchical
audits — operates on the ``(n, d)`` float32 contract. Real models live on
the other side of this file: pytrees of bf16/f32 leaves (params AND their
gradients). ``FlatBoundary`` is the single place the two meet, with an
explicit contract instead of ad-hoc ``ravel_pytree`` calls per call site:

* ``flatten``  : pytree -> (d,) f32. Leaves are widened (bf16 -> f32 is
  exact) and concatenated in ``jax.tree`` leaf order.
* ``unflatten``: (d,) f32 -> pytree with the ORIGINAL leaf dtypes/shapes.
* round-trip   : ``unflatten(flatten(t))`` is BITWISE ``t`` for any tree
  whose leaves are f32 or narrower floats (widen-then-narrow of the same
  value is the identity). The flat f32 vector is the master copy; the bf16
  pytree is the derived cast — the standard mixed-precision split, and the
  reason f32 digests computed from flat vectors are recomputable by any
  validator regardless of the model's storage dtype.

Non-float leaves are rejected at construction: nothing integer belongs on
the gradient wire, and silently round-tripping an int32 through f32 loses
bits above 2**24 (see repro.optim.optimizers.apply_updates for the same
rule on the optimizer side).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class FlatBoundary:
    """Bidirectional pytree <-> (d,) f32 map fixed at construction time.

    Built from a template tree (concrete arrays or ShapeDtypeStructs — use
    ``jax.eval_shape`` / ``Model.abstract_params()`` to avoid materializing
    weights). ``flatten``/``unflatten`` are pure jax functions: traceable,
    jit/scan/vmap-safe.
    """

    def __init__(self, template):
        leaves, self.treedef = jax.tree.flatten(template)
        self.shapes = tuple(tuple(l.shape) for l in leaves)
        self.dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
        for dt, shape in zip(self.dtypes, self.shapes):
            if not jnp.issubdtype(dt, jnp.floating):
                raise TypeError(
                    f"FlatBoundary: non-float leaf {dt} {shape} cannot cross "
                    "the f32 ravel boundary bitwise"
                )
        sizes = [int(np.prod(s, dtype=np.int64)) for s in self.shapes]
        self.offsets = tuple(int(o) for o in np.cumsum([0] + sizes))
        self.d = self.offsets[-1]

    def flatten(self, tree):
        """tree (matching the template's structure/shapes) -> (d,) f32."""
        leaves = self.treedef.flatten_up_to(tree)
        if not leaves:
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate(
            [jnp.ravel(l).astype(jnp.float32) for l in leaves]
        )

    def unflatten(self, flat):
        """(d,) f32 -> pytree with the template's leaf shapes AND dtypes."""
        leaves = [
            jax.lax.slice(flat, (self.offsets[i],), (self.offsets[i + 1],))
            .reshape(self.shapes[i])
            .astype(self.dtypes[i])
            for i in range(len(self.shapes))
        ]
        return self.treedef.unflatten(leaves)


def flat_boundary_for(model) -> FlatBoundary:
    """Boundary for a ``repro.models.Model`` without materializing params."""
    return FlatBoundary(model.abstract_params())
