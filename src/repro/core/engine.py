"""Jit/scan-compatible BTARD protocol engine (paper Alg. 1-7).

The legacy ``core.protocol.BTARDProtocol`` simulated every phase host-side:
numpy loops, sha256 commitments, python accusation lists — one device
round-trip per phase, so the *protocol* dominated step time beyond toy
sizes. This module is the same state machine as pure functions over an
explicit :class:`ProtocolState` pytree, so one full step jit-compiles and N
steps run under ``lax.scan`` with zero host synchronisation:

    compute_grads -> apply_attack -> aggregate (AggregatorSpec) -> verify
    -> accuse/ban

The aggregation phase is spec-dispatched (``EngineConfig.aggregator``,
``core.aggregators``): verifiable specs — the ButterflyClip flagship and
the ``verified:<base>`` wrappers over the coordinatewise baselines
(``core.verification``: generalized contribution digests in place of the
CenteredClip-residual tables) — run the full verification pipeline;
non-verifiable baseline specs (krum, geometric_median, trusted-PS
centered_clip and the unwrapped coordinatewise fns) run the same step with
verify/accuse/ban degraded to no-ops — the paper's Fig. 3 comparison axis
inside one engine.

Equivalences to the wire protocol (all recorded in kernels/DESIGN.md):

* sha256 commitments ≡ array equality — a commitment catches exactly a
  value that differs from the recomputed one, so the engine compares
  arrays directly (bit-identical rows never trip, attacked rows always do);
* MPRNG commit/reveal ≡ a deterministic per-step fold of the run's base
  key — unbiasable by construction, like the host protocol's abort-ban
  rule (the abort-bias attack is modelled by its *outcome*: aborters get
  banned);
* the banned-peer set shrink ≡ a static-shape ``active`` mask: banned rows
  are zeroed and carry weight 0, partition ownership stays peer j <->
  partition j (the butterfly assignment of Alg. 2).

``core.protocol.BTARDProtocol`` is now a thin host wrapper over
:func:`protocol_step` that mirrors bans/accusations out of the state pytree
(host ``grad_fn`` support + the legacy ``StepInfo`` API), so a scanned
N-step run and N wrapper calls are the *same computation* — property-tested
in ``tests/test_engine.py``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg_mod
from repro.core import attacks as attacks_mod
from repro.core import butterfly as bf
from repro.core import compression as comp_mod
from repro.core import hierarchy as hier_mod
from repro.core import sybil as sybil_mod
from repro.core import verification as verif_mod
from repro.core.sybil import (  # noqa: F401 — re-exported lifecycle codes
    SLOT_ACTIVE,
    SLOT_BANNED,
    SLOT_PROBATION,
    SLOT_VACANT,
)

# Ban reason codes (StepOutputs.ban_reason_now / ProtocolState.ban_reason)
BAN_NONE = 0
BAN_CHEATER = 1  # accused and the recompute proved it (ACCUSE, Alg. 4)
BAN_COVERUP = 2  # misreported s for a banned peer's partition (Alg. 4 L11-13)
BAN_FALSE_ACCUSER = 3  # slandered an honest peer (Hammurabi rule, Alg. 3)
BAN_MPRNG = 4  # aborted / mismatched the MPRNG commit-reveal (App. A.2)
BAN_SYBIL = 5  # failed a probation spot-check (Sybil gate, §3.3 / App. F)

BAN_REASON_NAMES = {
    BAN_NONE: "",
    BAN_CHEATER: "accusation verified (ACCUSE)",
    BAN_COVERUP: "covered up a banned peer (s mismatch)",
    BAN_FALSE_ACCUSER: "false accusation",
    BAN_MPRNG: "mprng abort/mismatch",
    BAN_SYBIL: "probation spot-check failed (sybil gate)",
}

# Membership event codes (ProtocolState.events rows: [step, kind, slot, id])
EVENT_NONE = 0
EVENT_JOIN = 1
EVENT_LEAVE = 2


class ProtocolState(NamedTuple):
    """One BTARD run's full per-step carry — a plain pytree of arrays.

    ``key`` is the run's base PRNG key; every draw is a fold of (key, step,
    phase), so a step's randomness is a pure function of the state — the
    property that makes scan and per-step execution bit-identical.

    The peer axis is a static ``n``-slot CAPACITY, not a fixed peer set:
    ``lifecycle`` tracks each slot through vacant → probation → active →
    banned (``core.sybil``), ``events`` is the statically-shaped join/leave
    schedule threaded through the scan (same idiom as the delay ring
    buffer), and the ``id_*`` ledgers are keyed by IDENTITY — they outlive
    the slot's occupant, so churn can never launder a ban or an accusation
    history (``slot_identity`` maps slot → current occupant, -1 vacant).
    """

    step: jnp.ndarray  # () i32 — t
    key: jnp.ndarray  # PRNG key (base of the per-step chain)
    active: jnp.ndarray  # (n,) f32 — 1 active (== lifecycle SLOT_ACTIVE)
    validator: jnp.ndarray  # (n,) f32 — C_t (elected at end of step t-1)
    prev_agg: jnp.ndarray  # (n_parts, part) f32 — last aggregate (warm start)
    ban_step: jnp.ndarray  # (n,) i32 — step banned at, -1 if active
    ban_reason: jnp.ndarray  # (n,) i32 — BAN_* code
    accused_count: jnp.ndarray  # (n,) i32 — accusation ledger (cumulative)
    last_checked: jnp.ndarray  # (n,) i32 — step last audited by a validator
    col_checked: jnp.ndarray  # (n,) i32 — step each digest COLUMN was last
    # broadcast/audited (sampled-digest mode's staleness ledger; all
    # columns every step when sampling is off)
    delay_buf: jnp.ndarray  # (D, n, d) f32 — ring buffer for delayed attack
    # --- elastic membership (core.sybil) ---
    lifecycle: jnp.ndarray  # (n,) i32 — SLOT_* code per slot
    slot_identity: jnp.ndarray  # (n,) i32 — identity occupying each slot
    probation_clean: jnp.ndarray  # (n,) i32 — consecutive clean spot-checks
    events: jnp.ndarray  # (n_events, 4) i32 — [step, kind, slot, identity]
    id_ban_step: jnp.ndarray  # (n_ids,) i32 — identity ban ledger, -1 clean
    id_ban_reason: jnp.ndarray  # (n_ids,) i32 — BAN_* per identity
    id_accused: jnp.ndarray  # (n_ids,) i32 — per-identity accusation ledger


class StepOutputs(NamedTuple):
    """Per-step observables (stacked along the leading axis under scan)."""

    g_hat: jnp.ndarray  # (d,) the robust aggregate
    seed: jnp.ndarray  # () i32 — the step's MPRNG output
    banned_now: jnp.ndarray  # (n,) bool
    ban_reason_now: jnp.ndarray  # (n,) i32
    accuse_mat: jnp.ndarray  # (n, n) bool — accuser x target (peers)
    sys_accuse: jnp.ndarray  # (n,) bool — checksum / Delta_max accusations
    cheated: jnp.ndarray  # (n,) bool — recompute verdict per peer
    checksum_violations: jnp.ndarray  # () i32
    check_averaging: jnp.ndarray  # () i32
    n_active: jnp.ndarray  # () i32 — active count at step start
    validators: jnp.ndarray  # (n,) f32 — this step's validator mask
    clip_iters_used: jnp.ndarray  # () i32 — max CenteredClip iterations any
    # partition ran (== cfg.clip_iters on the fixed path; the adaptive
    # early-exit's actual budget otherwise)
    sampled_parts: jnp.ndarray  # (n,) bool — digest columns broadcast this
    # step (all-True when sampled-digest mode is off)
    lifecycle: jnp.ndarray  # (n,) i32 — post-step SLOT_* code per slot


@dataclass(frozen=True)
class EngineConfig:
    """Static (hashable) protocol configuration — one jit cache entry per
    distinct config; everything dynamic lives in ProtocolState."""

    n: int
    d: int
    tau: float = 1.0
    clip_iters: int = 60
    m_validators: int = 1
    delta_max: float | None = None
    clip_lambda: float | None = None
    # attack switches (core.protocol.AttackConfig, flattened)
    attack: str = "none"
    start_step: int = 0
    end_step: int = 10**9
    lam: float = 1000.0
    delay: int = 1000
    aggregator_attack: bool = False
    aggregator_scale: float = 0.0
    misreport_s: bool = True
    false_accuse: bool = False
    mprng_abort: bool = False
    # engine switches
    warm_start: bool = False  # v0 = previous aggregate (fewer clip iters)
    use_pallas: bool = False
    # adaptive CenteredClip: stop when ||v_{l+1}-v_l|| <= adaptive_tol, with
    # clip_iters as the static cap. None = fixed budget. tol=0.0 reproduces
    # the fixed-budget aggregates bitwise (shared update rule).
    adaptive_tol: float | None = None
    # which robust aggregator runs the aggregation phase: an AggregatorSpec,
    # a "name[:k=v,...]" string, or None for the flagship ButterflyClip.
    # The legacy knobs above (tau/clip_iters/warm_start/adaptive_tol) act as
    # DEFAULTS for the spec's declared params; explicit spec params win.
    # Non-verifiable specs (mean, krum, ...) degrade the verification /
    # accusation / ban phases to no-ops — see core.aggregators.
    aggregator: "agg_mod.AggregatorSpec | str | None" = None
    # --- flat-cost verification at scale (core.hierarchy) ---
    # sampled-digest audit mode: the m validators jointly audit
    # m * audit_k digest COLUMNS per step (top-k by audit age + U(0,1)
    # from the step's MPRNG key — unpredictable, recomputable, staleness-
    # bounded), so table broadcast is O(n*k) instead of O(n^2).
    # None = full Alg. 6 tables. Verifiable specs only.
    audit_k: int | None = None
    # hierarchical butterfly-of-butterflies: peers split into `groups`
    # groups of n/groups; level-1 butterfly + gs x gs tables inside each
    # group, linear level-2 combine across groups with its own g x g
    # digest exchange (always-on zero-sum checksum). None/1 = flat.
    groups: int | None = None
    # --- elastic membership (core.sybil) ---
    # capacity of the device-resident join/leave event table threaded
    # through the scan; 0 = fixed peer set (every existing config), the
    # fast path that skips all membership machinery.
    n_events: int = 0
    # consecutive clean public-seed spot-checks a joining peer must pass
    # before its slot flips probation -> active (App. F probation window)
    probation_steps: int = 4
    # identity-ledger capacity; 0 = n + n_events (every event can
    # introduce at most one fresh identity)
    max_identities: int = 0

    def __post_init__(self):
        if self.audit_k is not None and self.audit_k < 1:
            raise ValueError("audit_k must be >= 1 (None = full tables)")
        if self.groups is not None and self.groups > 1:
            hier_mod.group_shape(self.n, self.groups)  # validates n % g
        if self.n_events < 0 or self.probation_steps < 1:
            raise ValueError("n_events >= 0 and probation_steps >= 1")

    @property
    def hierarchical(self) -> bool:
        return self.groups is not None and self.groups > 1

    @property
    def elastic(self) -> bool:
        return self.n_events > 0

    @property
    def n_ids(self) -> int:
        return max(self.max_identities, self.n + self.n_events)

    def agg_spec(self) -> "agg_mod.AggregatorSpec":
        """The resolved aggregator spec (legacy knobs filled as defaults).
        ``clip_iters`` is the uniform iteration-budget knob: it fills
        ``n_iters`` (fixed-budget specs) AND ``max_iters`` (to-tolerance
        specs) — set e.g. ``centered_clip:max_iters=200`` explicitly to
        restore the paper's run-to-convergence baseline."""
        return agg_mod.resolve_spec(self.aggregator).with_defaults(
            tau=self.tau, n_iters=self.clip_iters,
            max_iters=self.clip_iters,
            adaptive_tol=self.adaptive_tol, warm_start=self.warm_start,
        )

    @property
    def n_parts(self) -> int:
        return self.n

    @property
    def part(self) -> int:
        return bf.pad_to_parts(self.d, self.n) // self.n

    @property
    def has_gradient_attack(self) -> bool:
        return self.attack not in ("none", "label_flip")

    @property
    def has_any_attack(self) -> bool:
        return (
            self.attack != "none"
            or self.aggregator_attack
            or self.false_accuse
            or self.mprng_abort
        )

    @property
    def delay_depth(self) -> int:
        return max(1, self.delay) if self.attack == "delayed_gradient" else 1


def config_from_attack(n, d, attack, **kw) -> EngineConfig:
    """Build an EngineConfig from a core.protocol.AttackConfig plus the
    protocol kwargs (tau, clip_iters, ...)."""
    return EngineConfig(
        n=n,
        d=d,
        attack=attack.kind,
        start_step=attack.start_step,
        end_step=attack.end_step,
        lam=attack.lam,
        delay=attack.delay,
        aggregator_attack=attack.aggregator_attack,
        aggregator_scale=attack.aggregator_scale,
        misreport_s=attack.misreport_s,
        false_accuse=attack.false_accuse,
        mprng_abort=attack.mprng_abort,
        **kw,
    )


def encode_events(cfg: EngineConfig, schedule) -> jnp.ndarray:
    """Encode a host-side churn schedule into the statically-shaped
    ``(cfg.n_events, 4)`` i32 event table carried in :class:`ProtocolState`.

    ``schedule``: iterable of ``(step, kind, slot)`` / ``(step, kind, slot,
    identity)`` tuples (kind ``"join"``/``"leave"`` or EVENT_* code) or
    :class:`repro.core.sybil.MembershipEvent`. A join WITHOUT an explicit
    identity gets a fresh one (``n``, ``n+1``, ... in schedule order) — the
    rejoin-under-new-key model; passing the identity of a previously banned
    peer is the same-key rejoin, re-banned at admission from the identity
    ledger. Events are sorted by (step, leaves-first) so a leave+join on
    the same slot at the same step is a handoff; unused rows are padded
    inert (step -1 never fires).
    """
    kind_codes = {"join": EVENT_JOIN, "leave": EVENT_LEAVE,
                  EVENT_JOIN: EVENT_JOIN, EVENT_LEAVE: EVENT_LEAVE}
    rows, next_id = [], cfg.n
    for ev in schedule:
        if isinstance(ev, sybil_mod.MembershipEvent):
            ev = (ev.step, ev.kind, ev.slot)
        step, kind, slot = ev[0], kind_codes[ev[1]], ev[2]
        if not 0 <= slot < cfg.n:
            raise ValueError(f"event slot {slot} outside [0, {cfg.n})")
        if kind == EVENT_JOIN:
            ident = ev[3] if len(ev) > 3 else next_id
            next_id = max(next_id, ident + 1)
            if not 0 <= ident < cfg.n_ids:
                raise ValueError(
                    f"identity {ident} outside [0, {cfg.n_ids}); raise "
                    "EngineConfig.max_identities"
                )
        else:
            ident = -1
        rows.append((int(step), int(kind), int(slot), int(ident)))
    if len(rows) > cfg.n_events:
        raise ValueError(
            f"{len(rows)} events > EngineConfig.n_events={cfg.n_events}"
        )
    rows.sort(key=lambda r: (r[0], 0 if r[1] == EVENT_LEAVE else 1))
    rows += [(-1, EVENT_NONE, 0, -1)] * (cfg.n_events - len(rows))
    return jnp.asarray(rows, jnp.int32).reshape(cfg.n_events, 4)


def init_state(cfg: EngineConfig, seed: int = 0, events=None,
               vacant=()) -> ProtocolState:
    """Initial protocol state. ``events``: a churn schedule (anything
    :func:`encode_events` accepts, or an already-encoded (n_events, 4)
    array). ``vacant``: slots that start unoccupied (capacity reclaimed by
    later join events)."""
    n = cfg.n
    buf_elems = cfg.delay_depth * n * cfg.d
    if buf_elems > 2**28:  # > ~0.5 GiB of bf16 carried through every step
        raise ValueError(
            f"delayed_gradient ring buffer would be (delay={cfg.delay}, "
            f"n={n}, d={cfg.d}) = {2 * buf_elems / 2**30:.1f} GiB of scan "
            "carry; set AttackConfig.delay to the actual delay you want "
            "(typical runs use 5-50 — the legacy host buffer grew lazily, "
            "the engine's is dense)"
        )
    lifecycle = jnp.full((n,), SLOT_ACTIVE, jnp.int32)
    slot_identity = jnp.arange(n, dtype=jnp.int32)
    for s in vacant:
        lifecycle = lifecycle.at[int(s)].set(SLOT_VACANT)
        slot_identity = slot_identity.at[int(s)].set(-1)
    active0 = (lifecycle == SLOT_ACTIVE).astype(jnp.float32)
    if events is None:
        ev = jnp.full((cfg.n_events, 4), -1, jnp.int32)
    elif isinstance(events, (jnp.ndarray,)) or (
        hasattr(events, "shape") and getattr(events, "ndim", 0) == 2
    ):
        ev = jnp.asarray(events, jnp.int32)
        if ev.shape != (cfg.n_events, 4):
            raise ValueError(
                f"events shape {ev.shape} != ({cfg.n_events}, 4)"
            )
    else:
        ev = encode_events(cfg, events)
    key = jax.random.PRNGKey(seed)
    # elect step-0 validators from the same chain the steps use (fold at -1)
    validator = _elect(cfg, jax.random.fold_in(key, 2**31 - 1), active0)
    return ProtocolState(
        step=jnp.asarray(0, jnp.int32),
        key=key,
        active=active0,
        validator=validator,
        prev_agg=jnp.zeros((cfg.n_parts, cfg.part), jnp.float32),
        ban_step=jnp.full((n,), -1, jnp.int32),
        ban_reason=jnp.zeros((n,), jnp.int32),
        accused_count=jnp.zeros((n,), jnp.int32),
        last_checked=jnp.full((n,), -1, jnp.int32),
        col_checked=jnp.full((n,), -1, jnp.int32),
        # bf16: the buffer only feeds the delayed ATTACK rows (they mismatch
        # honest_G regardless), and it is the one O(delay·n·d) carry
        delay_buf=jnp.zeros(
            (cfg.delay_depth, n, cfg.d),
            jnp.bfloat16 if cfg.delay_depth > 1 else jnp.float32,
        ),
        lifecycle=lifecycle,
        slot_identity=slot_identity,
        probation_clean=jnp.zeros((n,), jnp.int32),
        events=ev,
        id_ban_step=jnp.full((cfg.n_ids,), -1, jnp.int32),
        id_ban_reason=jnp.zeros((cfg.n_ids,), jnp.int32),
        id_accused=jnp.zeros((cfg.n_ids,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Phase functions — each a pure map over (cfg, state fragments)
# ---------------------------------------------------------------------------
def _attacking(cfg: EngineConfig, t):
    if not cfg.has_any_attack:
        return jnp.asarray(False)
    return (t >= cfg.start_step) & (t < cfg.end_step)


def _phase_key(state: ProtocolState, phase: int):
    return jax.random.fold_in(jax.random.fold_in(state.key, state.step), phase)


def flip_mask(cfg: EngineConfig, state: ProtocolState, byz_mask):
    """Peers whose gradients are computed with flipped labels this step
    (LABEL FLIP happens at gradient time — feed this to ``grads_fn``).
    Probation rows flip too: their public-seed work is what the Sybil gate
    spot-checks, so the attack must be allowed to land there."""
    if cfg.attack != "label_flip":
        return jnp.zeros((cfg.n,), bool)
    engaged = (state.active > 0) | (state.lifecycle == SLOT_PROBATION)
    return _attacking(cfg, state.step) & (byz_mask > 0) & engaged


def phase_membership(cfg: EngineConfig, state: ProtocolState) -> ProtocolState:
    """Fire this step's join/leave events (the device-resident schedule in
    ``state.events``) before the round runs.

    Leave: the slot goes vacant; the SLOT ledgers (ban_step/ban_reason/
    accused_count/probation_clean) describe the occupant, so they reset with
    it — the occupant's history lives on in the identity ledgers (id_*),
    which membership never touches. Join: only onto a vacant slot; the
    incoming identity's history is restored from the identity ledgers — a
    previously banned identity (same-key rejoin) lands directly in BANNED,
    anyone else starts PROBATION at zero clean checks. ``col_checked`` /
    ``last_checked`` are column/audit staleness, a property of the
    topology, not the occupant — churn leaves them alone.

    Events are applied in row order (encode_events sorts step-then-
    leaves-first); a row whose step != t, or whose precondition fails
    (leave of a vacant slot, join onto an occupied one), is a no-op via
    out-of-range scatter drop.
    """
    if not cfg.elastic:
        return state
    n = cfg.n
    lifecycle, slot_identity = state.lifecycle, state.slot_identity
    clean, accused = state.probation_clean, state.accused_count
    ban_step, ban_reason = state.ban_step, state.ban_reason
    for e in range(cfg.n_events):  # static unroll — n_events is small
        ev = state.events[e]
        fire = ev[0] == state.step
        kind, slot, ident = ev[1], ev[2], ev[3]
        slot_c = jnp.clip(slot, 0, n - 1)
        ident_c = jnp.clip(ident, 0, cfg.n_ids - 1)

        do_leave = fire & (kind == EVENT_LEAVE) & (
            lifecycle[slot_c] != SLOT_VACANT
        )
        ls = jnp.where(do_leave, slot_c, n)  # n = out of range -> drop
        lifecycle = lifecycle.at[ls].set(SLOT_VACANT, mode="drop")
        slot_identity = slot_identity.at[ls].set(-1, mode="drop")
        clean = clean.at[ls].set(0, mode="drop")
        accused = accused.at[ls].set(0, mode="drop")
        ban_step = ban_step.at[ls].set(-1, mode="drop")
        ban_reason = ban_reason.at[ls].set(BAN_NONE, mode="drop")

        do_join = fire & (kind == EVENT_JOIN) & (
            lifecycle[slot_c] == SLOT_VACANT
        )
        pre_banned = state.id_ban_step[ident_c] >= 0
        js = jnp.where(do_join, slot_c, n)
        lifecycle = lifecycle.at[js].set(
            jnp.where(pre_banned, SLOT_BANNED, SLOT_PROBATION), mode="drop"
        )
        slot_identity = slot_identity.at[js].set(ident_c, mode="drop")
        clean = clean.at[js].set(0, mode="drop")
        accused = accused.at[js].set(state.id_accused[ident_c], mode="drop")
        ban_step = ban_step.at[js].set(
            jnp.where(pre_banned, state.id_ban_step[ident_c], -1),
            mode="drop",
        )
        ban_reason = ban_reason.at[js].set(
            jnp.where(pre_banned, state.id_ban_reason[ident_c], BAN_NONE),
            mode="drop",
        )
    active = (lifecycle == SLOT_ACTIVE).astype(jnp.float32)
    return state._replace(
        lifecycle=lifecycle, slot_identity=slot_identity,
        probation_clean=clean, accused_count=accused,
        ban_step=ban_step, ban_reason=ban_reason,
        active=active, validator=state.validator * active,
    )


def phase_attack(cfg: EngineConfig, state: ProtocolState, G, honest_G, byz,
                 engage_b=None):
    """apply_attack: Byzantine rows swap in their attack vectors; the delay
    ring buffer rotates; honest peers optionally self-clip (Alg. 9).
    ``engage_b`` widens the attacked-row mask beyond the active set (the
    elastic path includes probation rows, so the Sybil spot-check sees the
    attack); defaults to the active mask."""
    t = state.step
    att = _attacking(cfg, t)
    active_b = state.active > 0 if engage_b is None else engage_b
    delay_buf = state.delay_buf

    if cfg.has_gradient_attack:
        slot = t % cfg.delay_depth
        # written at t - delay_depth (zeros before)
        delayed = delay_buf[slot].astype(jnp.float32)
        G = attacks_mod.apply_attack(
            attacks_mod.attack_index(cfg.attack),
            G,
            byz & active_b & att,
            key=_phase_key(state, 1),
            lam=cfg.lam,
            delayed=delayed,
            hon_mask=~byz & active_b,
        )
    # history for the delayed attack (honest rows of byzantine peers)
    if cfg.attack == "delayed_gradient":
        slot = t % cfg.delay_depth
        delay_buf = delay_buf.at[slot].set(
            jnp.where((byz & active_b)[:, None], honest_G, 0.0).astype(
                delay_buf.dtype
            )
        )

    if cfg.clip_lambda is not None:  # BTARD-Clipped-SGD (Alg. 9, honest peers)
        nrm = jnp.linalg.norm(G, axis=1)
        scale = jnp.minimum(1.0, cfg.clip_lambda / jnp.maximum(nrm, 1e-30))
        clip_rows = (~byz)[:, None]
        G = jnp.where(clip_rows, G * scale[:, None], G)
        honest_G = jnp.where(clip_rows, G, honest_G)
    return G, honest_G, delay_buf


def phase_mprng(cfg: EngineConfig, state: ProtocolState, byz):
    """MPRNG: the shared seed plus the abort-ban outcome. The commit/reveal
    transcript (core.mprng) collapses to an unbiased draw; a Byzantine
    aborter (trying the learn-early-and-abort bias) is banned — here modelled
    as: when the abort-bias attack is on and the candidate draw has the
    parity the attacker dislikes, every attacking peer aborts and is banned."""
    seed = jax.random.randint(
        _phase_key(state, 0), (), 0, jnp.int32(2**31 - 1), jnp.int32
    )
    mprng_ban = jnp.zeros((cfg.n,), bool)
    if cfg.mprng_abort:
        abort = (seed % 2 == 1) & _attacking(cfg, state.step)
        mprng_ban = abort & byz & (state.active > 0)
    return seed, mprng_ban


def _scatter_cols(values, idx, n, n_cols):
    """Scatter (n, k) sampled-column tables into zero (n, n_cols) tables.
    Unsampled columns are identically zero on BOTH the reported and the
    recomputed side, so every downstream mismatch/checksum/vote term is
    silent there by construction — no masking plumbing anywhere else."""
    return jnp.zeros((n, n_cols), jnp.float32).at[:, idx].set(values)


def phase_aggregation(cfg: EngineConfig, state: ProtocolState, G, weights,
                      seed, samp_idx=None):
    """Spec-dispatched robust aggregation (``cfg.aggregator``).

    Verifiable specs — the ButterflyClip flagship (per-partition
    CenteredClip + tau-clipped residual tables, optionally warm-started
    and/or adaptive) and the ``verified:<base>`` wrappers over the
    coordinatewise baselines (base aggregation + generalized contribution
    digests, ``core.verification``) — run via
    :func:`verification.spec_aggregate`. The tables/digests are always
    computed exactly once against the final aggregate, so downstream
    accusation semantics never see the iteration budget.

    Non-verifiable specs (mean, median, Krum, ...): the flat registry fn
    runs over the stacked gradients; there are no broadcast tables
    (z/s_tbl/norm_tbl come back None) and the caller degrades the
    verification/accusation phases to no-ops.

    Returns (agg (n_parts, part), parts, z, s_tbl, norm_tbl, iters_used).
    """
    spec = cfg.agg_spec()
    if not spec.verifiable:
        agg_fn = spec.build(cfg.n, cfg.d, use_pallas=cfg.use_pallas)
        v0 = None
        if spec.warm_startable and spec.get("warm_start", False):
            v0 = jnp.where(
                state.step > 0, bf.merge_parts(state.prev_agg, cfg.d), 0.0
            )
        flat, info = agg_fn(
            G, weights if spec.weighted else None, v0, _phase_key(state, 2)
        )
        # keep the butterfly partition layout for the prev_agg carry
        agg = bf.split_parts(
            flat.astype(jnp.float32)[None, :], cfg.n_parts
        )[0]
        parts = bf.split_parts(G, cfg.n_parts)
        return (agg, parts, None, None, None,
                jnp.asarray(info.iters, jnp.int32))

    z = bf.get_random_directions(seed, cfg.n_parts, cfg.part)
    v0 = None
    if spec.warm_startable and spec.get("warm_start", False):
        v0 = jnp.where(state.step > 0, state.prev_agg, 0.0)
    if cfg.aggregator_attack and cfg.aggregator_scale > 0:
        # tables must be computed against the (possibly corrupted) received
        # aggregate, so aggregation and tables split into two calls here
        agg, parts, _s, _n, iters_used = verif_mod.spec_aggregate(
            spec, G, z=None, weights=weights, v0=v0,
            use_pallas=cfg.use_pallas,
        )
        return agg, parts, z, None, None, iters_used
    if samp_idx is not None:
        # sampled-digest mode: aggregate WITHOUT the fused table epilogue,
        # then digest only the k sampled columns (one O(n*k*part) pass —
        # the scalar-prefetch rows kernel under use_pallas) and scatter
        # them into zero tables
        agg, parts, _s, _n, iters_used = verif_mod.spec_aggregate(
            spec, G, z=None, weights=weights, v0=v0,
            use_pallas=cfg.use_pallas,
        )
        s_r, n_r = verif_mod.digest_tables_rows(
            spec, parts, agg, z, samp_idx, use_pallas=cfg.use_pallas
        )
        s_tbl = _scatter_cols(s_r, samp_idx, cfg.n, cfg.n_parts)
        norm_tbl = _scatter_cols(n_r, samp_idx, cfg.n, cfg.n_parts)
        return agg, parts, z, s_tbl, norm_tbl, iters_used
    agg, parts, s_tbl, norm_tbl, iters_used = verif_mod.spec_aggregate(
        spec, G, z=z, weights=weights, v0=v0, use_pallas=cfg.use_pallas,
    )
    return agg, parts, z, s_tbl, norm_tbl, iters_used


def phase_aggregator_attack(cfg, state, agg, parts, z, byz, weights,
                            samp_idx=None):
    """Byzantine aggregators corrupt their partitions; every honest peer
    then reports tables against the corrupted value it received, and one
    colluder cancels the Verification-2 checksum (App. C). The recomputed
    tables are spec-aware: clipped residuals for butterfly_clip, plain
    contribution digests for verified:* wrapped specs. Under sampled-digest
    mode only the sampled columns exist (zero-scattered like the honest
    path), so a corrupted unsampled column goes unnoticed until its
    staleness-bounded turn — the property the coverage tests pin down."""
    honest_agg = agg
    corrupt = jnp.zeros((cfg.n_parts,), bool)
    if cfg.aggregator_attack and cfg.aggregator_scale > 0:
        att = _attacking(cfg, state.step)
        corrupt = byz & (state.active > 0) & att
        agg = attacks_mod.aggregator_shift_all(
            agg, corrupt, _phase_key(state, 3), cfg.aggregator_scale
        )
        if samp_idx is not None:
            s_r, n_r = verif_mod.digest_tables_rows(
                cfg.agg_spec(), parts, agg, z, samp_idx,
                use_pallas=cfg.use_pallas,
            )
            s_tbl = _scatter_cols(s_r, samp_idx, cfg.n, cfg.n_parts)
            norm_tbl = _scatter_cols(n_r, samp_idx, cfg.n, cfg.n_parts)
        else:
            s_tbl, norm_tbl = verif_mod.spec_tables(
                cfg.agg_spec(), parts, agg, z, use_pallas=cfg.use_pallas
            )
    else:
        s_tbl = norm_tbl = None
    return agg, honest_agg, corrupt, s_tbl, norm_tbl


def phase_misreport(cfg, s_tbl, corrupt, byz, active, weights):
    """The first active colluder cancels sum_i w_i s_i^j for each corrupted
    partition j (exactly the legacy protocol's liar selection)."""
    if not (cfg.aggregator_attack and cfg.misreport_s):
        return s_tbl
    is_liar_cand = byz & (active > 0)
    liar = jnp.argmax(is_liar_cand)  # first active byzantine row
    has_liar = is_liar_cand.any()
    w_liar = weights[liar]
    col_sums = (s_tbl * weights[:, None]).sum(0)  # (n_parts,)
    others = col_sums - w_liar * s_tbl[liar]
    lie = -others / jnp.maximum(w_liar, 1e-30)
    new_row = jnp.where(corrupt & has_liar & (w_liar > 0), lie, s_tbl[liar])
    return s_tbl.at[liar].set(new_row)


def _choose_targets(cfg, state, active_b):
    """Audit-age-weighted CHOOSETARGET: the m validators take the m distinct
    candidates with the highest age + U(0,1) score (age = steps since last
    audit), so every active peer is audited at least every ~ceil(n/m) steps
    — the uniform draw's coupon-collector tail is gone — while fresh
    per-step jitter keeps the audit ORDER unpredictable. Targets are
    publicly derivable from the revealed seed (like the paper's
    CHOOSETARGET), so every peer maintains the same last_checked ledger.

    Returns (target (n,) — validator v audits target[v], valid_audit,
    is_validator, target_hot (n, n) bool, audited (n,) bool)."""
    n = cfg.n
    cand = active_b & (state.validator <= 0)
    n_cand = cand.sum()
    u = jax.random.uniform(_phase_key(state, 5), (n,))
    age = (state.step - state.last_checked).astype(jnp.float32)
    score = jnp.where(cand, age + u, -jnp.inf)
    order = jnp.argsort(-score)  # candidate peer ids by audit priority
    is_validator = (state.validator > 0) & active_b
    val_ord = jnp.clip(jnp.cumsum(is_validator) - 1, 0, n - 1)
    target = order[val_ord]  # (n,) — validator v audits target[v]
    valid_audit = is_validator & (val_ord < n_cand)
    target_hot = jax.nn.one_hot(target, n, dtype=bool)
    audited = (target_hot & valid_audit[:, None]).any(axis=0)
    return target, valid_audit, is_validator, target_hot, audited


def phase_verify(cfg, state, G, honest_G, agg, honest_agg, parts, s_tbl,
                 true_s, norm_tbl, true_norm, byz, weights):
    """Verifications 1-3 + validator spot checks -> accusation matrices."""
    n = cfg.n
    active_b = state.active > 0
    att = _attacking(cfg, state.step)

    tol_norm = 1e-4 * (1.0 + true_norm)
    tol_s = 1e-4 * (1.0 + jnp.abs(true_s))
    mismatch_norm = jnp.abs(norm_tbl - true_norm) > tol_norm  # (peer, part)
    mismatch_s = jnp.abs(s_tbl - true_s) > tol_s

    # V1 + V2a: honest aggregator j accuses any i misreporting for col j
    agg_ok = active_b & ~byz  # byzantine aggregators stay silent
    accuse = agg_ok[:, None] & (mismatch_norm | mismatch_s).T  # (j, i)

    # V2b: global checksum per partition (system accusation on the owner).
    # The zero-sum identity only holds when the digest combines LINEARLY
    # into the aggregate (the CenteredClip fixed point / the weighted mean)
    # — for nonlinear verified:* wrapped specs (median, trimmed mean) it is
    # statically disabled, so honest runs stay accusation-free; a lying
    # aggregator is caught by the validator partition recompute below.
    if verif_mod.has_zero_checksum(cfg.agg_spec()):
        cs_tol = bf.checksum_tolerance(agg, parts)
        sums = (s_tbl * weights[:, None]).sum(0)
        sys_accuse = jnp.abs(sums) > cs_tol
    else:
        sys_accuse = jnp.zeros((n,), bool)
    checksum_violations = sys_accuse.sum().astype(jnp.int32)

    # V3: Delta_max majority vote -> CHECKAVERAGING(j)
    check_averaging = jnp.asarray(0, jnp.int32)
    if cfg.delta_max is not None:
        votes = ((true_norm > cfg.delta_max) * weights[:, None]).sum(0)
        v3 = votes > weights.sum() / 2.0
        check_averaging = v3.sum().astype(jnp.int32)
        sys_accuse = sys_accuse | v3

    # validator spot checks — audit-age-weighted CHOOSETARGET
    # (:func:`_choose_targets`, shared with the hierarchical core)
    target, valid_audit, is_validator, target_hot, audited = _choose_targets(
        cfg, state, active_b
    )

    grad_mismatch = jnp.any(G != honest_G, axis=1)  # commitment recompute
    row_tol = 1e-4 * (1.0 + jnp.abs(true_s).max(axis=1))
    s_row_mismatch = jnp.abs(s_tbl - true_s).max(axis=1) > row_tol
    # CheckComputations covers the audited peer's FULL work: its gradient,
    # its reported table row AND its partition aggregation (peer j owns
    # partition j, Alg. 2) — the recompute that catches a lying aggregator
    # even for wrapped specs whose checksum identity (V2b) does not exist.
    agg_mismatch = jnp.any(agg != honest_agg, axis=1)  # (n_parts,) == (n,)

    caught = (grad_mismatch[target] | s_row_mismatch[target]
              | agg_mismatch[target])
    val_accuse = is_validator & ~byz & caught & valid_audit
    if cfg.false_accuse:
        val_accuse = val_accuse | (is_validator & byz & att & valid_audit)
    accuse = accuse | (target_hot & val_accuse[:, None])
    last_checked = jnp.where(audited, state.step, state.last_checked)

    # accusations only flow between active peers
    accuse = accuse & active_b[:, None] & active_b[None, :]
    sys_accuse = sys_accuse & active_b
    return (accuse, sys_accuse, mismatch_s, checksum_violations,
            check_averaging, last_checked)


def phase_accuse_ban(cfg, state, accuse, sys_accuse, mismatch_s, mprng_ban,
                     G, honest_G, agg, honest_agg, s_tbl, true_s,
                     norm_tbl, true_norm):
    """ACCUSE resolution (Alg. 4): everyone recomputes the accused peer's
    work from the public seed; the guilty party is the target if the
    accusation holds (plus everyone who covered it up), else the accuser."""
    active_b = state.active > 0

    cheated = (
        jnp.any(G != honest_G, axis=1)  # gradient attack
        | jnp.any(  # s misreport
            jnp.abs(s_tbl - true_s) > 1e-5 + 1e-3 * jnp.abs(true_s), axis=1
        )
        | jnp.any(  # norm misreport
            jnp.abs(norm_tbl - true_norm) > 1e-5 + 1e-3 * jnp.abs(true_norm),
            axis=1,
        )
        | jnp.any(agg != honest_agg, axis=1)  # aggregation attack (owner j)
    )

    accused = sys_accuse | accuse.any(axis=0)
    ban_cheater = accused & cheated & active_b
    # Alg. 4 L11-13: peers whose reported s for a guilty peer's partition
    # mismatches the recomputed value covered for it -> banned too
    ban_coverup = (mismatch_s & ban_cheater[None, :]).any(axis=1) & active_b
    # Hammurabi: accusing a peer the recompute exonerates bans the accuser
    ban_false = (accuse & ~cheated[None, :]).any(axis=1) & active_b

    banned_now = ban_cheater | ban_coverup | ban_false | (mprng_ban & active_b)
    reason = jnp.where(
        ban_cheater, BAN_CHEATER,
        jnp.where(ban_coverup, BAN_COVERUP,
                  jnp.where(ban_false, BAN_FALSE_ACCUSER,
                            jnp.where(mprng_ban, BAN_MPRNG, BAN_NONE))),
    ).astype(jnp.int32)
    reason = jnp.where(banned_now, reason, BAN_NONE)

    new_active = state.active * (1.0 - banned_now)
    return new_active, banned_now, reason, cheated, accused.astype(jnp.int32)


def phase_hier(cfg, state, byz, weights, seed, G, G_cmp, honest_G_cmp,
               samp_mask, mprng_ban):
    """The hierarchical butterfly-of-butterflies verifiable core:
    aggregation + aggregator attack + misreport + verify + accuse/ban in
    the two-level topology (core.hierarchy).

    Level 1: each group of gs = n/groups peers runs the full spec over its
    own butterfly — tables are gs x gs PER GROUP, broadcast within the
    group only. Level 2: the linear leader combine with its always-on
    zero-sum checksum; a violated super-partition implicates its group's
    leader, so bans propagate through the group digests. Accusations stay
    peer x peer (n, n) — level-1 blocks scatter block-diagonally — so
    :func:`phase_accuse_ban` and the whole ban machinery run unchanged
    over the hier shapes. ``samp_mask`` (n,) composes the sampled-digest
    mode in: global cell (a, c) guards column c of group a's tables
    (cell index == owner peer id, both levels of masking agree).

    Returns the same tail tuple the flat verifiable branch produces, plus
    the global aggregate in the standard (n_parts, part) layout.
    """
    n = cfg.n
    g, gs = hier_mod.group_shape(n, cfg.groups)
    active = state.active
    active_b = active > 0
    att = _attacking(cfg, state.step)
    spec = cfg.agg_spec()

    attacking_agg = bool(cfg.aggregator_attack and cfg.aggregator_scale > 0)
    v0_flat = None
    if spec.warm_startable and spec.get("warm_start", False):
        v0_flat = jnp.where(
            state.step > 0, bf.merge_parts(state.prev_agg, cfg.d), 0.0
        )
    h = hier_mod.hier_aggregate(
        spec, G, weights, seed, cfg.groups, v0_flat=v0_flat,
        with_tables=not attacking_agg,
    )
    u, s1, norms1 = h.u, h.s1, h.norms1
    part1 = u.shape[-1]
    corrupt = jnp.zeros((n,), bool)
    if attacking_agg:
        # cell (a, r) of the level-1 aggregate is owned by peer a*gs + r,
        # so the flat (n,)-masked shift applies to the (n, part1) reshape
        corrupt = byz & active_b & att
        u = attacks_mod.aggregator_shift_all(
            u.reshape(n, part1), corrupt, _phase_key(state, 3),
            cfg.aggregator_scale,
        ).reshape(u.shape)
        s1, norms1 = hier_mod.hier_tables(spec, h.parts1, u, h.z1)

    wg = weights.reshape(g, gs)
    if samp_mask is not None:
        samp_h = samp_mask.reshape(g, gs)
        s1 = jnp.where(samp_h[:, None, :], s1, 0.0)
        norms1 = jnp.where(samp_h[:, None, :], norms1, 0.0)
    true_s1, true_norm1 = s1, norms1
    # per-group misreport: each group's first active colluder cancels its
    # group's checksum for the corrupted columns (vmapped flat phase)
    s1 = jax.vmap(
        lambda s, c, b, a, w: phase_misreport(cfg, s, c, b, a, w)
    )(s1, corrupt.reshape(g, gs), byz.reshape(g, gs),
      active.reshape(g, gs), wg)

    # level 2: combine the (possibly corrupted) group aggregates — honest
    # leaders relay faithfully, so reported == recomputed at level 2 and
    # the always-on linear checksum is the alarm that a group-level
    # corruption reached the global aggregate
    lvl2 = hier_mod.level2_combine(u, h.group_w, cfg.d, seed)
    v_flat = bf.merge_parts(lvl2.v2, cfg.d)
    agg_std = bf.split_parts(v_flat[None, :], cfg.n_parts)[0]

    # ---- verify: V1/V2/V3 per group + level-2 checksum + audits ----------
    tol_n1 = 1e-4 * (1.0 + true_norm1)
    tol_s1 = 1e-4 * (1.0 + jnp.abs(true_s1))
    mm_norm = jnp.abs(norms1 - true_norm1) > tol_n1  # (g, peer_r, col_c)
    mm_s = jnp.abs(s1 - true_s1) > tol_s1

    idx = jnp.arange(n).reshape(g, gs)
    agg_ok_g = (active_b & ~byz).reshape(g, gs)
    acc_blocks = agg_ok_g[:, :, None] & jnp.swapaxes(mm_norm | mm_s, 1, 2)
    accuse = jnp.zeros((n, n), bool).at[
        idx[:, :, None], idx[:, None, :]
    ].set(acc_blocks)
    mismatch_s = jnp.zeros((n, n), bool).at[
        idx[:, :, None], idx[:, None, :]
    ].set(mm_s)

    if verif_mod.has_zero_checksum(spec):
        cs_tol = jax.vmap(bf.checksum_tolerance)(u, h.parts1)  # (g,)
        sums1 = (s1 * wg[:, :, None]).sum(1)  # (g, gs) per group column
        sys_accuse = (jnp.abs(sums1) > cs_tol[:, None]).reshape(n)
    else:
        sys_accuse = jnp.zeros((n,), bool)
    cs2_tol = bf.checksum_tolerance(lvl2.v2, lvl2.parts2)
    sums2 = (lvl2.s2 * h.group_w[:, None]).sum(0)  # (g,)
    leader_accuse = jnp.zeros((n,), bool).at[jnp.arange(g) * gs].set(
        jnp.abs(sums2) > cs2_tol
    )
    sys_accuse = sys_accuse | leader_accuse
    checksum_violations = sys_accuse.sum().astype(jnp.int32)

    check_averaging = jnp.asarray(0, jnp.int32)
    if cfg.delta_max is not None:
        # group-majority Delta_max vote over the group's weight mass
        votes = ((true_norm1 > cfg.delta_max) * wg[:, :, None]).sum(1)
        v3 = (votes > wg.sum(axis=1, keepdims=True) / 2.0).reshape(n)
        check_averaging = v3.sum().astype(jnp.int32)
        sys_accuse = sys_accuse | v3

    # validator CHOOSETARGET audit — a FULL-peer recompute, independent of
    # digest sampling and of the topology: the backstop that keeps
    # gradient-attack time-to-ban flat under both axes
    target, valid_audit, is_validator, target_hot, audited = _choose_targets(
        cfg, state, active_b
    )
    grad_mismatch = jnp.any(G_cmp != honest_G_cmp, axis=1)
    s_h, true_s_h = s1.reshape(n, gs), true_s1.reshape(n, gs)
    row_tol = 1e-4 * (1.0 + jnp.abs(true_s_h).max(axis=1))
    s_row_mismatch = jnp.abs(s_h - true_s_h).max(axis=1) > row_tol
    u_n, honest_u_n = u.reshape(n, part1), h.u.reshape(n, part1)
    agg_mismatch = jnp.any(u_n != honest_u_n, axis=1)
    caught = (grad_mismatch[target] | s_row_mismatch[target]
              | agg_mismatch[target])
    val_accuse = is_validator & ~byz & caught & valid_audit
    if cfg.false_accuse:
        val_accuse = val_accuse | (is_validator & byz & att & valid_audit)
    accuse = accuse | (target_hot & val_accuse[:, None])
    last_checked = jnp.where(audited, state.step, state.last_checked)

    accuse = accuse & active_b[:, None] & active_b[None, :]
    sys_accuse = sys_accuse & active_b

    # ---- accuse / ban (the flat machinery over the hier shapes) ----------
    (new_active, banned_now, reason, cheated,
     accused_inc) = phase_accuse_ban(
        cfg, state, accuse, sys_accuse, mismatch_s, mprng_ban,
        G_cmp, honest_G_cmp, u_n, honest_u_n, s_h, true_s_h,
        norms1.reshape(n, gs), true_norm1.reshape(n, gs),
    )
    return (new_active, banned_now, reason, cheated, accused_inc, accuse,
            sys_accuse, checksum_violations, check_averaging, last_checked,
            agg_std, h.iters)


def _elect(cfg: EngineConfig, key, active):
    """Next step's validators: m uniform draws without replacement over the
    active peers, never all of them (Alg. 1 L19 keeps >= 1 contributor)."""
    score = jnp.where(active > 0, jax.random.uniform(key, (cfg.n,)), -jnp.inf)
    rank = jnp.argsort(jnp.argsort(-score))
    m_eff = jnp.minimum(cfg.m_validators, jnp.maximum(active.sum() - 1, 0))
    return ((rank < m_eff) & (active > 0)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# One full protocol step (jit-compilable, scan-compatible)
# ---------------------------------------------------------------------------
def protocol_step(cfg: EngineConfig, state: ProtocolState, byz_mask, G,
                  honest_G):
    """One BTARD-SGD aggregation round as a pure function.

    G / honest_G: (n, d) — honest_G is what a validator recomputing from the
    public seed obtains (equals G except for label-flipped rows). Banned
    rows are zeroed internally, so their supplied values are irrelevant.
    Returns (new_state, StepOutputs).
    """
    spec = cfg.agg_spec()
    byz = jnp.asarray(byz_mask) > 0

    # ---- membership: fire this step's join/leave events ------------------
    state = phase_membership(cfg, state)
    active = state.active
    active_b = active > 0
    prob_b = state.lifecycle == SLOT_PROBATION
    validator = state.validator * active
    if spec.verifiable:
        weights = active * (1.0 - validator)  # Alg. 1 L19: validators sit out
    else:
        # nothing to audit without the broadcast tables: no validator set-
        # aside, every active peer contributes to the aggregate
        weights = active

    # probation rows keep their payloads through the attack phase (the
    # Sybil gate must see what they actually broadcast) but NEVER reach the
    # aggregate or the accusation fabric — they are re-zeroed below.
    keep = (active_b | prob_b)[:, None]
    G = jnp.where(keep, jnp.asarray(G, jnp.float32), 0.0)
    honest_G = jnp.where(keep, jnp.asarray(honest_G, jnp.float32), 0.0)

    # ---- apply_attack ----------------------------------------------------
    G, honest_G, delay_buf = phase_attack(
        cfg, state, G, honest_G, byz, engage_b=active_b | prob_b
    )

    # ---- Sybil probation gate (core.sybil, §3.3 / App. F) ----------------
    # every probation row is spot-checked EVERY step against the public-
    # seed recompute; one mismatch bans the identity, a full clean window
    # promotes the slot. Structurally upstream of aggregation: a probation
    # payload influences nothing but this check.
    if cfg.elastic:
        prob_mismatch = sybil_mod.probation_check(G, honest_G, prob_b)
    else:
        prob_mismatch = jnp.zeros((cfg.n,), bool)
    probation_clean, promote, sybil_ban = sybil_mod.probation_step(
        prob_b, prob_mismatch, state.probation_clean, cfg.probation_steps
    )
    G = jnp.where(active_b[:, None], G, 0.0)
    honest_G = jnp.where(active_b[:, None], honest_G, 0.0)

    # ---- MPRNG (shared seed + abort bans) --------------------------------
    seed, mprng_ban = phase_mprng(cfg, state, byz)

    # ---- sampled-digest column set (public fold of the step key) ---------
    # cell index == digest column == owner peer id, flat AND hierarchical
    # (hier cell (a, c) = peer a*gs + c), so one (n,) ledger serves both
    sampling = spec.verifiable and cfg.audit_k is not None
    if sampling:
        samp_idx, samp_mask = hier_mod.sample_audit_cells(
            _phase_key(state, 6), state.step, state.col_checked,
            cfg.m_validators, cfg.audit_k, cfg.n,
        )
        col_checked = jnp.where(samp_mask, state.step, state.col_checked)
    else:
        samp_idx, samp_mask = None, None
        col_checked = jnp.full((cfg.n,), state.step, jnp.int32)

    if spec.verifiable and cfg.hierarchical:
        # ---- hierarchical butterfly-of-butterflies core ------------------
        if comp_mod.is_wrapped(spec):
            # wire partitions follow the level-1 butterfly: gs per group
            codec = comp_mod.codec_of(spec)
            gs = cfg.n // cfg.groups
            G_cmp = comp_mod.wire_grads(G, codec, gs)
            honest_G_cmp = comp_mod.wire_grads(honest_G, codec, gs)
        else:
            G_cmp, honest_G_cmp = G, honest_G
        (new_active, banned_now, reason, cheated, accused_inc, accuse,
         sys_accuse, cs_viol, chk_avg, last_checked, agg,
         iters_used) = phase_hier(
            cfg, state, byz, weights, seed, G, G_cmp, honest_G_cmp,
            samp_mask, mprng_ban,
        )
    elif spec.verifiable:
        agg, parts, z, s_tbl, norm_tbl, iters_used = phase_aggregation(
            cfg, state, G, weights, seed, samp_idx
        )
        # compressed:* specs: every peer commits to (and validators
        # recompute) the WIRE payload, not the raw f32 gradient — so the
        # commitment comparisons in verify/accuse must run over the wire
        # projection of both sides. A perturbation below the quantization
        # step neither enters the aggregate nor trips a ban (the wire
        # representation IS the protocol-visible contribution); anything
        # that survives quantization differs on the wire and is caught
        # exactly as before. Honest rows are raw-equal, hence wire-equal:
        # zero honest accusations is structural, not a tolerance.
        if comp_mod.is_wrapped(spec):
            codec = comp_mod.codec_of(spec)
            G_cmp = comp_mod.wire_grads(G, codec, cfg.n_parts)
            honest_G_cmp = comp_mod.wire_grads(honest_G, codec, cfg.n_parts)
        else:
            G_cmp, honest_G_cmp = G, honest_G
        agg, honest_agg, corrupt, s2, n2 = phase_aggregator_attack(
            cfg, state, agg, parts, z, byz, weights, samp_idx
        )
        if s_tbl is None:
            s_tbl, norm_tbl = s2, n2
        true_s, true_norm = s_tbl, norm_tbl
        s_tbl = phase_misreport(cfg, s_tbl, corrupt, byz, active, weights)

        # ---- verify ------------------------------------------------------
        (accuse, sys_accuse, mismatch_s, cs_viol, chk_avg,
         last_checked) = phase_verify(
            cfg, state, G_cmp, honest_G_cmp, agg, honest_agg, parts, s_tbl,
            true_s, norm_tbl, true_norm, byz, weights,
        )

        # ---- accuse / ban ------------------------------------------------
        (new_active, banned_now, reason, cheated,
         accused_inc) = phase_accuse_ban(
            cfg, state, accuse, sys_accuse, mismatch_s, mprng_ban,
            G_cmp, honest_G_cmp, agg, honest_agg, s_tbl, true_s, norm_tbl,
            true_norm,
        )
    else:
        agg, parts, z, s_tbl, norm_tbl, iters_used = phase_aggregation(
            cfg, state, G, weights, seed
        )
        # non-verifiable aggregator: no tables -> no verification, no
        # accusations, no bans (incl. the MPRNG abort rule, which is part
        # of the same commit/reveal machinery). The attack still lands in
        # the aggregate; only the DEFENSE's detection arm is absent.
        n = cfg.n
        accuse = jnp.zeros((n, n), bool)
        sys_accuse = jnp.zeros((n,), bool)
        cheated = jnp.zeros((n,), bool)
        cs_viol = jnp.asarray(0, jnp.int32)
        chk_avg = jnp.asarray(0, jnp.int32)
        last_checked = state.last_checked
        banned_now = jnp.zeros((n,), bool)
        reason = jnp.zeros((n,), jnp.int32)
        accused_inc = jnp.zeros((n,), jnp.int32)
        new_active = active

    # ---- lifecycle transitions (bans + probation promotions) -------------
    # protocol bans (active rows) and sybil bans (probation rows) are
    # disjoint by construction; promote is clean-probation only. In the
    # fixed-membership case promote/sybil_ban are identically False and
    # (new_lifecycle == ACTIVE) reproduces active * (1 - banned_now) bitwise.
    banned_now = banned_now | sybil_ban
    reason = jnp.where(sybil_ban, BAN_SYBIL, reason).astype(jnp.int32)
    new_lifecycle = jnp.where(
        banned_now, SLOT_BANNED,
        jnp.where(promote, SLOT_ACTIVE, state.lifecycle),
    ).astype(jnp.int32)
    new_active = (new_lifecycle == SLOT_ACTIVE).astype(jnp.float32)

    # ---- identity ledgers (persist across leave/rejoin) ------------------
    ident = state.slot_identity
    idc = jnp.clip(ident, 0, cfg.n_ids - 1)
    first_ban = banned_now & (ident >= 0) & (state.id_ban_step[idc] < 0)
    sid = jnp.where(first_ban, idc, cfg.n_ids)  # out of range -> drop
    id_ban_step = state.id_ban_step.at[sid].set(state.step, mode="drop")
    id_ban_reason = state.id_ban_reason.at[sid].set(reason, mode="drop")
    aid = jnp.where(ident >= 0, idc, cfg.n_ids)
    id_accused = state.id_accused.at[aid].add(accused_inc, mode="drop")

    # ---- elect next validators ------------------------------------------
    next_validator = _elect(cfg, _phase_key(state, 4), new_active)

    g_hat = bf.merge_parts(agg, cfg.d)
    # warm-start hygiene: only carry the aggregate forward as v0 when this
    # step's PUBLIC misbehaviour signals were clean — after a ban or a
    # Delta_max vote the aggregate may be corrupted, so the next step
    # cold-starts rather than seeding from it. (The raw checksum is NOT the
    # gate: far from convergence — exactly the small-clip_iters regime warm
    # start enables — its residual legitimately exceeds tolerance. A
    # colluder who cancels the checksum evades this gate; the carried bias
    # stays bounded by the per-step corruption scale — DESIGN.md.)
    clean = ~banned_now.any() & (chk_avg == 0)
    new_state = ProtocolState(
        step=state.step + 1,
        key=state.key,
        active=new_active,
        validator=next_validator,
        prev_agg=jnp.where(clean, agg.astype(jnp.float32), 0.0),
        ban_step=jnp.where(banned_now, state.step, state.ban_step),
        ban_reason=jnp.where(banned_now, reason, state.ban_reason),
        accused_count=state.accused_count + accused_inc,
        last_checked=last_checked,
        col_checked=col_checked,
        delay_buf=delay_buf,
        lifecycle=new_lifecycle,
        slot_identity=state.slot_identity,
        probation_clean=probation_clean,
        events=state.events,
        id_ban_step=id_ban_step,
        id_ban_reason=id_ban_reason,
        id_accused=id_accused,
    )
    out = StepOutputs(
        g_hat=g_hat,
        seed=seed,
        banned_now=banned_now,
        ban_reason_now=reason,
        accuse_mat=accuse,
        sys_accuse=sys_accuse,
        cheated=cheated,
        checksum_violations=cs_viol,
        check_averaging=chk_avg,
        n_active=active.sum().astype(jnp.int32),
        validators=validator,
        clip_iters_used=iters_used,
        sampled_parts=(samp_mask if sampling
                       else jnp.ones((cfg.n,), bool)),
        lifecycle=new_lifecycle,
    )
    return new_state, out


@functools.lru_cache(maxsize=32)
def jit_protocol_step(cfg: EngineConfig):
    """Jitted single step for the given (static) config."""
    return jax.jit(functools.partial(protocol_step, cfg))


# ---------------------------------------------------------------------------
# Device-resident data phase
# ---------------------------------------------------------------------------
def device_data_grads_fn(n: int, batch_fn: Callable, grad_fn: Callable,
                         label_flip: bool = False):
    """Build a scan-compatible ``grads_fn`` whose DATA PHASE runs inside the
    step function: per-peer public-seed batches are generated ON DEVICE
    (vmapped over peers), so a scanned run moves zero batch bytes host->
    device per step.

    batch_fn(peer, step, flipped) -> batch pytree — pure and traceable in
    (peer, step) (e.g. ``TokenPipeline.device_batch`` or
    ``classification_batch`` over ``peer_key``); the public-seed property
    means a validator recomputing peer i's batch gets the same bits on any
    path. grad_fn(params, batch) -> (d,) flat gradient.

    Returns grads_fn(params, t, flips) -> (G, honest_G), the signature
    :func:`scan_protocol` consumes. When ``label_flip``, flipped rows carry
    the flipped-label gradient in G while honest_G keeps the recompute
    (exactly what a validator obtains from the public seed).
    """

    def per_peer(params, i, t, flip):
        g_honest = grad_fn(params, batch_fn(i, t, False))
        if label_flip:
            g = jnp.where(flip, grad_fn(params, batch_fn(i, t, True)),
                          g_honest)
        else:
            g = g_honest
        return g, g_honest

    def grads_fn(params, t, flips):
        return jax.vmap(lambda i, f: per_peer(params, i, t, f))(
            jnp.arange(n), flips
        )

    return grads_fn


# ---------------------------------------------------------------------------
# Scanned multi-step runner
# ---------------------------------------------------------------------------
def scan_protocol(cfg: EngineConfig, state: ProtocolState, byz_mask, params,
                  grads_fn: Callable, n_steps: int, update_fn=None):
    """Run ``n_steps`` protocol rounds under one ``lax.scan`` (no host sync).

    grads_fn(params, t, flip_mask) -> (G, honest_G): pure per-step gradient
    computation over ALL n peers (banned rows are masked internally). Build
    it with :func:`device_data_grads_fn` to fold batch generation into the
    scan (the fully device-resident loop: data -> grads -> attack ->
    butterfly -> verify -> ban, one compiled program, zero per-step host
    traffic). update_fn(params, g_hat, t) -> params: optional optimizer
    inner step. Returns (final_state, final_params, stacked StepOutputs).
    """
    byz = jnp.asarray(byz_mask) > 0

    def body(carry, _):
        st, p = carry
        flips = flip_mask(cfg, st, byz)
        G, honest_G = grads_fn(p, st.step, flips)
        st, out = protocol_step(cfg, st, byz, G, honest_G)
        if update_fn is not None:
            p = update_fn(p, out.g_hat, st.step - 1)
        return (st, p), out

    (state, params), outs = jax.lax.scan(
        body, (state, params), None, length=n_steps
    )
    return state, params, outs


def make_scan_runner(cfg: EngineConfig, grads_fn, n_steps: int,
                     update_fn=None):
    """Jitted closure over scan_protocol: fn(state, byz_mask, params)."""
    return jax.jit(
        lambda state, byz_mask, params: scan_protocol(
            cfg, state, byz_mask, params, grads_fn, n_steps, update_fn
        )
    )


# ---------------------------------------------------------------------------
# Static-analysis hooks (tools.analysis / btard-lint)
# ---------------------------------------------------------------------------
def abstract_state(cfg: EngineConfig) -> ProtocolState:
    """:class:`ProtocolState` as a pytree of ``ShapeDtypeStruct`` leaves —
    the abstract scan carry btard-lint traces the step with (no arrays are
    materialized, no devices are touched)."""
    return jax.eval_shape(lambda: init_state(cfg))


def traceable_phases(cfg: EngineConfig) -> dict:
    """name -> (fn, abstract_args) for every phase this config exercises,
    with argument avals wired exactly as :func:`protocol_step` passes them
    (intermediate shapes derived via ``jax.eval_shape`` chaining, never
    hand-written). btard-lint traces each entry with ``jax.make_jaxpr``
    and asserts purity — no host callbacks, no effects, no PRNG outside
    the :func:`_phase_key` fold-in chain — so a violation is pinned to the
    phase that introduced it rather than to the fused step."""
    n, d = cfg.n, cfg.d
    state = abstract_state(cfg)
    aval = jax.ShapeDtypeStruct
    G = aval((n, d), jnp.float32)
    byz = aval((n,), jnp.bool_)
    weights = aval((n,), jnp.float32)
    seed = aval((), jnp.int32)
    spec = cfg.agg_spec()

    phases = {
        "phase_membership": (
            functools.partial(phase_membership, cfg), (state,)),
        "phase_attack": (
            functools.partial(phase_attack, cfg), (state, G, G, byz)),
        "phase_mprng": (
            functools.partial(phase_mprng, cfg), (state, byz)),
    }

    if spec.verifiable and cfg.hierarchical:
        samp_mask = aval((n,), jnp.bool_) if cfg.audit_k is not None else None
        if comp_mod.is_wrapped(spec):
            codec = comp_mod.codec_of(spec)
            gs = n // cfg.groups
            G_cmp = jax.eval_shape(
                lambda g: comp_mod.wire_grads(g, codec, gs), G)
        else:
            G_cmp = G
        phases["phase_hier"] = (
            functools.partial(phase_hier, cfg),
            (state, byz, weights, seed, G, G_cmp, G_cmp, samp_mask, byz))
        return phases

    samp_idx = None
    if spec.verifiable and cfg.audit_k is not None:
        samp_idx, _ = jax.eval_shape(
            lambda s: hier_mod.sample_audit_cells(
                _phase_key(s, 6), s.step, s.col_checked,
                cfg.m_validators, cfg.audit_k, cfg.n), state)
    agg_fn = functools.partial(phase_aggregation, cfg)
    phases["phase_aggregation"] = (
        agg_fn, (state, G, weights, seed, samp_idx))
    if not spec.verifiable:
        # mean/median/krum baselines: no tables, verify/accuse degrade to
        # no-ops in protocol_step, so aggregation is the last traced phase
        return phases

    agg, parts, z, s_tbl, norm_tbl, _ = jax.eval_shape(
        agg_fn, state, G, weights, seed, samp_idx)
    att_fn = functools.partial(phase_aggregator_attack, cfg)
    phases["phase_aggregator_attack"] = (
        att_fn, (state, agg, parts, z, byz, weights, samp_idx))
    if s_tbl is None:  # aggregator-attack configs compute tables post-shift
        _, _, _, s_tbl, norm_tbl = jax.eval_shape(
            att_fn, state, agg, parts, z, byz, weights, samp_idx)
    corrupt = aval((cfg.n_parts,), jnp.bool_)
    active = aval((n,), jnp.float32)
    phases["phase_misreport"] = (
        functools.partial(phase_misreport, cfg),
        (s_tbl, corrupt, byz, active, weights))
    if comp_mod.is_wrapped(spec):
        G_cmp = jax.eval_shape(
            lambda g: comp_mod.wire_grads(
                g, comp_mod.codec_of(spec), cfg.n_parts), G)
    else:
        G_cmp = G
    ver_fn = functools.partial(phase_verify, cfg)
    ver_args = (state, G_cmp, G_cmp, agg, agg, parts, s_tbl, s_tbl,
                norm_tbl, norm_tbl, byz, weights)
    phases["phase_verify"] = (ver_fn, ver_args)
    accuse, sys_accuse, mismatch_s, _, _, _ = jax.eval_shape(
        ver_fn, *ver_args)
    phases["phase_accuse_ban"] = (
        functools.partial(phase_accuse_ban, cfg),
        (state, accuse, sys_accuse, mismatch_s, byz, G_cmp, G_cmp,
         agg, agg, s_tbl, s_tbl, norm_tbl, norm_tbl))
    return phases
