"""CenteredClip (Karimireddy et al. 2020) — the robust mean at BTARD's heart.

Fixed-point iteration (paper eq. (CenteredClip)):
    v_{l+1} = v_l + (1/n) sum_i (x_i - v_l) * min(1, tau_l / ||x_i - v_l||)

with the paper's tau schedule eq. (5):
    tau_l = 4 * sqrt((1 - delta) * (B_l^2/3 + sigma^2) / (sqrt(3) * delta))
    B_{l+1}^2 = 6.45 * delta * B_l^2 + 5 * sigma^2

tau -> inf recovers the mean; tau -> 0 approaches the geometric median
(paper App. D.2). ``weights`` masks banned peers (Alg. 7 bans).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tau_schedule(delta: float, sigma: float, n_iters: int, b0: float = 0.0):
    """Paper eq. (5). delta=0 => tau = inf (plain mean)."""
    taus = []
    b2 = float(b0) ** 2
    for _ in range(n_iters):
        if delta <= 0.0:
            taus.append(np.inf)
        else:
            taus.append(
                4.0
                * np.sqrt(
                    (1.0 - delta) * (b2 / 3.0 + sigma**2) / (np.sqrt(3.0) * delta)
                )
            )
        b2 = 6.45 * delta * b2 + 5.0 * sigma**2
    return np.asarray(taus, np.float32)


def _clip_weights(diff_norm, tau):
    """min(1, tau/||.||), safe at 0; tau=inf -> 1."""
    w = jnp.minimum(1.0, tau / jnp.maximum(diff_norm, 1e-30))
    return jnp.where(jnp.isinf(tau), 1.0, w)


def _stacked_update(xs, v, tau, weights, wsum):
    """One CenteredClip iteration over stacked partitions.

    xs: (P, n, part) f32; v: (P, part) f32 -> the update (P, part) f32.
    The SINGLE update rule shared by the fixed-budget (fori_loop) and
    adaptive (while_loop) paths — sharing it is what makes ``adaptive with
    tol=0`` reproduce the fixed-iteration aggregate bitwise (tested in
    tests/test_centered_clip.py).
    """
    diff = xs - v[:, None, :]
    norms = jnp.linalg.norm(diff, axis=2)  # (P, n)
    cw = _clip_weights(norms, tau) * weights[None, :]
    return (cw[..., None] * diff).sum(1) / wsum


def _stacked_args(stacked, weights, v0):
    P, n, part = stacked.shape
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    weights = weights.astype(jnp.float32)
    wsum = jnp.maximum(weights.sum(), 1e-30)
    v = (
        jnp.zeros((P, part), jnp.float32)
        if v0 is None
        else v0.astype(jnp.float32)
    )
    return stacked.astype(jnp.float32), weights, wsum, v


def centered_clip_stacked(stacked, tau, n_iters: int = 20, weights=None,
                          v0=None):
    """Batched CenteredClip over stacked partitions: (P, n, part) -> (P, part).

    The butterfly aggregation's inner loop — every partition advances one
    iteration per pass (identical ops to ``vmap(centered_clip)``, shared
    with the adaptive variant below). tau: scalar or (n_iters,) schedule.
    """
    xs_f, weights, wsum, v = _stacked_args(stacked, weights, v0)
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (n_iters,))

    def body(l, v):
        return v + _stacked_update(xs_f, v, taus[l], weights, wsum)

    return jax.lax.fori_loop(0, n_iters, body, v)


def centered_clip_adaptive_stacked(stacked, tau, tol, max_iters: int,
                                   weights=None, v0=None):
    """Adaptive-budget CenteredClip over stacked partitions: iterate until
    ``||v_{l+1} - v_l|| <= tol`` PER PARTITION (with a static ``max_iters``
    cap), under one ``lax.while_loop``.

    A partition whose update dropped below tol is frozen (its carry no
    longer changes) while the others keep iterating — exactly the batching
    rule of ``vmap(while_loop)``, so per-partition results equal independent
    adaptive loops. With ``tol=0`` every partition runs the full cap through
    the SAME update rule as :func:`centered_clip_stacked`, reproducing the
    fixed-budget aggregate bitwise. Warm starting (``v0`` = previous
    aggregate) composes: it shortens the trajectory, never moves the fixed
    point (unique for tau > 0).

    Returns (v (P, part) f32, iters (P,) i32 — iterations each partition ran).
    """
    xs_f, weights, wsum, v = _stacked_args(stacked, weights, v0)
    P = xs_f.shape[0]
    tau_f = jnp.asarray(tau, jnp.float32)
    tol2 = jnp.float32(tol) ** 2

    def cond(carry):
        _, d2, it, _ = carry
        return jnp.logical_and((d2 > tol2).any(), it < max_iters)

    def body(carry):
        v, d2, it, iters = carry
        upd = _stacked_update(xs_f, v, tau_f, weights, wsum)
        active = d2 > tol2  # (P,) — converged partitions are frozen
        v = jnp.where(active[:, None], v + upd, v)
        d2 = jnp.where(active, (upd * upd).sum(-1), d2)
        return v, d2, it + 1, iters + active.astype(jnp.int32)

    v, _, _, iters = jax.lax.while_loop(
        cond,
        body,
        (v, jnp.full((P,), jnp.inf, jnp.float32), jnp.int32(0),
         jnp.zeros((P,), jnp.int32)),
    )
    return v, iters


def centered_clip_adaptive(xs, tau, tol, max_iters: int, weights=None,
                           v0=None):
    """Single-partition adaptive CenteredClip: (n, d) -> ((d,) f32, () i32).

    ``lax.while_loop`` with the shared update rule — stops at
    ``||v_{l+1}-v_l|| <= tol`` or after ``max_iters``; see
    :func:`centered_clip_adaptive_stacked`.
    """
    v, iters = centered_clip_adaptive_stacked(
        jnp.asarray(xs)[None], tau, tol, max_iters, weights=weights,
        v0=None if v0 is None else jnp.asarray(v0)[None],
    )
    return v[0], iters[0]


def centered_clip(xs, tau, n_iters: int = 20, weights=None, v0=None):
    """Robust aggregate of ``xs``: (n, d) -> (d,).

    tau: scalar or per-iteration (n_iters,) schedule.
    weights: optional (n,) peer mask (0 = banned). Result is the CenteredClip
    fixed point over the active peers.
    """
    xs = jnp.asarray(xs)
    n, d = xs.shape
    if weights is None:
        weights = jnp.ones((n,), xs.dtype)
    wsum = jnp.maximum(weights.sum(), 1e-30)
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (n_iters,))
    # v0 = 0 (or the caller's warm start, e.g. last step's aggregate): with a
    # mean init, amplified attacks (|g_byz| >> tau) put v0 so far out that the
    # <= tau-per-iteration pull can never escape — matching Karimireddy's
    # implementation, which warm-starts from the previous aggregate.
    # Iteration runs in f32 regardless of the (possibly bf16) input dtype.
    v = jnp.zeros((d,), jnp.float32) if v0 is None else v0.astype(jnp.float32)
    xs_f = xs.astype(jnp.float32)
    weights = weights.astype(jnp.float32)

    def body(l, v):
        diff = xs_f - v[None, :]
        norms = jnp.linalg.norm(diff, axis=1)
        cw = _clip_weights(norms, taus[l]) * weights
        return v + (cw[:, None] * diff).sum(0) / wsum

    return jax.lax.fori_loop(0, n_iters, body, v)


def centered_clip_to_tol(
    xs, tau, eps: float = 1e-6, max_iters: int = 200, weights=None, v0=None
):
    """Run CenteredClip to convergence ||v_{l+1}-v_l|| <= eps (paper §4.1
    runs 'iterative algorithms to convergence with eps=1e-6').

    v0: optional warm start (e.g. last step's aggregate). The fixed point is
    unique for tau > 0 over a fixed peer set, so warm starting changes the
    iteration count, never the limit — returned ``iters`` lets callers
    measure the saving (Fig. 9 / warm-start analysis in kernels/DESIGN.md).
    """
    xs = jnp.asarray(xs)
    n, d = xs.shape
    if weights is None:
        weights = jnp.ones((n,), xs.dtype)
    wsum = jnp.maximum(weights.sum(), 1e-30)
    v = jnp.zeros((d,), xs.dtype) if v0 is None else v0.astype(xs.dtype)

    def cond(state):
        v, delta, it = state
        return jnp.logical_and(delta > eps, it < max_iters)

    def body(state):
        v, _, it = state
        diff = xs - v[None, :]
        norms = jnp.linalg.norm(diff.astype(jnp.float32), axis=1)
        cw = _clip_weights(norms, jnp.float32(tau)) * weights
        step = (cw[:, None] * diff).sum(0) / wsum
        return v + step, jnp.linalg.norm(step.astype(jnp.float32)), it + 1

    v, _, iters = jax.lax.while_loop(cond, body, (v, jnp.float32(jnp.inf), 0))
    return v, iters


def clip_residuals(xs, v, tau):
    """Delta_i = (x_i - v) * min(1, tau/||x_i - v||)  (paper Alg. 1 L7).

    At the exact fixed point sum_i Delta_i = 0 — the basis of Verification 2.
    """
    diff = xs - v[None, :]
    norms = jnp.linalg.norm(diff.astype(jnp.float32), axis=1)
    return diff * _clip_weights(norms, jnp.float32(tau))[:, None]
