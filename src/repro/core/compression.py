"""Wire compression for the butterfly all-to-all — quantized payloads with
EXACT verification (``compressed:<verifiable>`` AggregatorSpec wrappers).

Communication efficiency is the paper's pitch, yet the butterfly all-to-all
of Alg. 2 ships every payload as f32: 4 bytes per coordinate where 1-2 do.
The ``compressed:`` wrapper quantizes each (peer, partition) payload before
the exchange:

* ``codec=int8`` — per-partition symmetric scale: one f32 sidecar scalar
  ``scale = max|x| / 127`` per payload, wire value
  ``q = clip(round(x / scale), -127, 127)`` as int8 (≈4× fewer wire bytes);
* ``codec=bf16`` — dtype truncation, no sidecar (scale ≡ 1; ≈2×).

The soundness problem compression creates is ROUNDING vs the accuse/ban
protocol: if the sender digests its f32 gradient but the verifier digests
what arrived on the wire, every honest peer is eventually accused over
rounding error. The wrapper's contract dissolves this: **every Alg. 6
quantity — the aggregate v_j, the digests s[i,j] / norm[i,j], and the V2
zero-sum checksum where it applies — is computed over the dequantized-from-
wire values**, never the raw gradients. Dequantization
(``q.astype(f32) * scale``) is a pure deterministic function of the wire
bits, so owner, sender and validator recompute bit-identical digests from
the same payload; honest rows can NEVER trip a commitment or table check
(zero honest accusations is structural, not a tolerance). A cheater's
perturbation either survives quantization — then its wire row, and hence
its recomputed digest pair, differs and the existing verify/accuse/ban
phases fire unchanged — or it vanishes below the quantization step, in
which case it also never entered the aggregate: the wire representation IS
the protocol-visible contribution.

V2 (`Σ_i w_i s_i^j ≈ 0`) survives compression for the same reason it exists
at all (core.verification): the identity is over whatever values the
aggregation consumed. Since the aggregate is computed FROM the wire values,
linear digests over wire values still telescope — exactly for
``compressed:verified:mean``, to fixed-point tolerance for
``compressed:butterfly_clip``; :func:`verification.has_zero_checksum`
therefore answers for the inner spec.

Layering (mirrors ``verified:``): the wrapper registers
``compressed:<name>`` for every verifiable spec; digest/aggregation
dispatch lives in :func:`compressed_aggregate` (called from
``verification.spec_aggregate``); the int8-resident fused Pallas kernels
(dequantize+clip+digest / dequantize+mean+digest, kernels/centered_clip.py)
keep the HBM pass count at n_iters + 2 over 1-byte data; the distributed
all_to_all + scale-sidecar exchange is ``launch.steps``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import aggregators as agg_mod
from repro.core import butterfly as bf

PREFIX = "compressed:"
DEFAULT_CODEC = "int8"
CODECS = ("int8", "bf16")
# wire bytes per coordinate (f32 baseline: 4)
CODEC_BYTES = {"int8": 1, "bf16": 2}


def _check_codec(codec: str) -> str:
    if codec not in CODECS:
        raise ValueError(
            f"unknown wire codec {codec!r} (supported: {', '.join(CODECS)})"
        )
    return codec


# ---------------------------------------------------------------------------
# The codecs: quantize / dequantize over the LAST axis
# ---------------------------------------------------------------------------
def quantize(x, codec: str):
    """Project ``x`` (..., part) onto its wire representation.

    Returns ``(wire, scales)`` with ``scales`` of shape ``x.shape[:-1]``
    (one f32 sidecar scalar per payload — the per-partition symmetric
    scale for int8, identically 1 for bf16 so one dequantize serves both).
    Deterministic: same input bits -> same wire bits on every peer, the
    property the exact-verification contract rests on. All-zero payloads
    quantize to scale 0 / wire 0 and dequantize to exact zeros.
    """
    _check_codec(codec)
    x = jnp.asarray(x, jnp.float32)
    if codec == "bf16":
        return x.astype(jnp.bfloat16), jnp.ones(x.shape[:-1], jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = (amax / 127.0).astype(jnp.float32)
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[..., None]), -127.0, 127.0)
    return q.astype(jnp.int8), scale


def dequantize(wire, scales):
    """Wire bits -> the f32 values EVERY digest is computed over.

    One formula for both codecs (bf16 ships scale ≡ 1): upcast then one
    f32 multiply — the same two ops the fused Pallas kernels apply
    in-register, so the kernel and jnp paths see bit-identical values.
    """
    return wire.astype(jnp.float32) * scales[..., None]


def roundtrip(x, codec: str):
    """quantize∘dequantize — the wire projection of ``x`` (f32, same shape)."""
    return dequantize(*quantize(x, codec))


def wire_grads(grads, codec: str, n_parts: int):
    """Project stacked gradients (n, d) through the per-(peer, partition)
    wire codec — what the engine's commitment comparisons and the generic
    aggregation path consume. The butterfly layout fixes the payload
    boundaries: peer i's contribution to partition j is one payload with
    its own sidecar scale (padding coordinates are zero and never raise a
    payload's amax)."""
    n, d = grads.shape
    parts = bf.split_parts(grads, n_parts)  # (n, n_parts, part)
    wire = roundtrip(jnp.swapaxes(parts, 0, 1), codec)
    return jnp.swapaxes(wire, 0, 1).reshape(n, -1)[:, :d]


# ---------------------------------------------------------------------------
# Spec naming: compressed:<verifiable> wrappers
# ---------------------------------------------------------------------------
def is_wrapped(spec_or_name) -> bool:
    """True for ``compressed:<base>`` wrapper specs/names."""
    name = (
        spec_or_name
        if isinstance(spec_or_name, str)
        else agg_mod.resolve_spec(spec_or_name).name
    )
    return name.startswith(PREFIX)


def inner_spec(spec) -> "agg_mod.AggregatorSpec":
    """The wrapped verifiable spec (same params, ``codec`` stripped)."""
    spec = agg_mod.resolve_spec(spec)
    if not is_wrapped(spec):
        raise ValueError(f"not a {PREFIX}* wrapped spec: {spec.name!r}")
    params = tuple((k, v) for k, v in spec.params if k != "codec")
    return agg_mod.AggregatorSpec(spec.name[len(PREFIX):], params)


def codec_of(spec) -> str:
    return _check_codec(agg_mod.resolve_spec(spec).get("codec", DEFAULT_CODEC))


def compressed(spec, codec: str | None = None) -> "agg_mod.AggregatorSpec":
    """Registry combinator: wire-compress a verifiable spec's butterfly
    payloads.

    * already-compressed specs come back unchanged (codec overridden when
      given);
    * verifiable specs (butterfly_clip, verified:*) map to
      ``compressed:<name>`` with the same params plus ``codec``;
    * non-verifiable coordinatewise specs are lifted through ``verified:``
      first — ``compressed(mean)`` is ``compressed:verified:mean`` (wire
      compression rides the butterfly exchange, which is exactly the
      verifiable topology);
    * full-vector specs (krum, geometric_median, centered_clip) raise, as
      for ``verified:``.
    """
    if codec is not None:
        _check_codec(codec)
    spec = agg_mod.resolve_spec(spec)
    if is_wrapped(spec):
        return spec if codec is None else spec.override(codec=codec)
    if not spec.verifiable:
        from repro.core import verification as vf

        spec = vf.verified(spec)
    params = dict(spec.params)
    if codec is not None:
        params["codec"] = codec
    wrapped = agg_mod.AggregatorSpec(
        PREFIX + spec.name, tuple(sorted(params.items()))
    )
    wrapped.definition  # eager validation (wrapper must be registered)
    return wrapped


def parse_spec_text(text: str) -> "agg_mod.AggregatorSpec":
    """Parse the tail of ``compressed:INNER[:k=v,...]`` (the
    ``AggregatorSpec.parse`` hook). The trailing segment is a param list
    iff it contains ``=``; ``codec`` binds to the wrapper, every other
    param to the inner spec — so ``compressed:verified:mean:codec=bf16``
    and ``compressed:butterfly_clip:n_iters=20,codec=bf16`` both parse."""
    head, sep, tail = text.strip().rpartition(":")
    if not (sep and "=" in tail):
        return compressed(agg_mod.AggregatorSpec.parse(text))
    params = {}
    for item in tail.split(","):
        k, s2, v = item.partition("=")
        if not s2:
            raise ValueError(
                f"bad aggregator param {item!r} in {PREFIX}{text!r} "
                "(expected k=v)"
            )
        params[k.strip()] = agg_mod._coerce(v.strip())
    codec = params.pop("codec", None)
    inner = agg_mod.AggregatorSpec.parse(head)
    if params:
        inner = inner.override(**params)
    return compressed(inner, codec=codec)


# ---------------------------------------------------------------------------
# The verifiable aggregation contract over wire values
# ---------------------------------------------------------------------------
def compressed_aggregate(spec, grads, z=None, weights=None, v0=None,
                         use_pallas: bool = False):
    """``verification.spec_aggregate`` for a compressed spec: quantize the
    butterfly payloads, then run the INNER spec's aggregation + digests over
    the dequantized-from-wire values.

    Returns the uniform (agg, parts, s, norms, iters) contract; ``parts``
    are the WIRE values (what every peer actually received), so downstream
    table recomputes (``spec_tables``) and checksum tolerances see the same
    representation the digests were built from.

    With ``use_pallas`` the wire payloads stay in their 1-2 byte dtype in
    HBM: the fused dequantize+clip+digest kernel (butterfly_clip, fixed
    budget) / dequantize+mean+digest kernel (verified:mean) read int8/bf16
    and dequantize in-register — n_iters + 2 (resp. 2) HBM passes over
    quarter-width data. Every other inner spec materializes the f32 wire
    values once and delegates.
    """
    from repro.core import verification as vf

    spec = agg_mod.resolve_spec(spec)
    inner = inner_spec(spec)
    codec = codec_of(spec)
    n, d = grads.shape

    if use_pallas and z is not None:
        stacked = jnp.swapaxes(bf.split_parts(grads, n), 0, 1)
        q, scales = quantize(stacked, codec)  # (n_parts, n, part), (n_parts, n)
        if inner.name == "butterfly_clip":
            p = inner.param_dict()
            if p["adaptive_tol"] is None:
                from repro.kernels.ops import butterfly_clip_fused_dequant_op

                if not p.get("warm_start"):
                    v0 = None
                agg, s, norms = butterfly_clip_fused_dequant_op(
                    q, scales, p["tau"], z, weights, n_iters=p["n_iters"],
                    v0=v0,
                )
                parts = jnp.swapaxes(dequantize(q, scales), 0, 1)
                return agg, parts, s, norms, jnp.asarray(
                    p["n_iters"], jnp.int32
                )
        elif vf.base_spec(inner).name == "mean":
            from repro.kernels.ops import mean_digest_fused_dequant_op

            agg, s, norms = mean_digest_fused_dequant_op(
                q, scales, z, weights
            )
            parts = jnp.swapaxes(dequantize(q, scales), 0, 1)
            return agg, parts, s, norms, jnp.asarray(1, jnp.int32)

    # generic path: materialize the f32 wire values once, delegate to the
    # inner spec (identical digests — dequantize is one deterministic
    # formula everywhere)
    return vf.spec_aggregate(
        inner, wire_grads(grads, codec, n), z=z, weights=weights, v0=v0,
        use_pallas=use_pallas,
    )


# ---------------------------------------------------------------------------
# Registration: one compressed:<name> wrapper per verifiable spec
# ---------------------------------------------------------------------------
def _make_compressed(base_def: "agg_mod.AggregatorDef"):
    def make(n, d, use_pallas, codec=DEFAULT_CODEC, **params):
        _check_codec(codec)
        base_fn = base_def.make(n, d, use_pallas, **params)

        def fn(xs, weights=None, v0=None, key=None):
            return base_fn(wire_grads(xs, codec, n), weights, v0, key)

        return fn

    return make


def register_compressed_wrappers():
    """Register ``compressed:<name>`` for every VERIFIABLE spec in the
    registry (the wire exchange being compressed is the butterfly
    all-to-all, which only verifiable specs ride). Declared params are the
    inner spec's plus ``codec``; capability flags are inherited — the
    wrapper changes the wire representation, not the aggregation contract.
    The flat maker projects through the codec then runs the base fn; the
    verified path with tables is :func:`compressed_aggregate`. Idempotent.
    Runs after ``verification.register_verified_wrappers`` (import chain:
    aggregators -> verification -> this module), so the verified:* wrappers
    are always in the registry by the time this loop sees it."""
    for name, base_def in list(agg_mod.REGISTRY.items()):
        if name.startswith(PREFIX) or not base_def.verifiable:
            continue
        wrapped = PREFIX + name
        if wrapped in agg_mod.REGISTRY:
            continue
        agg_mod.register(agg_mod.AggregatorDef(
            wrapped,
            _make_compressed(base_def),
            defaults=base_def.defaults + (("codec", DEFAULT_CODEC),),
            verifiable=True,
            weighted=base_def.weighted,
            warm_startable=base_def.warm_startable,
            adaptive=base_def.adaptive,
            # NOT inherited: the quantization scale of each wire payload is
            # a max over the whole partition, so a coordinate slice
            # quantizes with different scales than the full vector —
            # split/concat is no longer bitwise (btard-lint C5)
            coordinatewise=False,
        ))


register_compressed_wrappers()
