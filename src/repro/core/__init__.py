"""BTARD — the paper's primary contribution as a composable JAX module."""
from repro.core.aggregators import (  # noqa: F401
    AggInfo,
    AggregatorSpec,
    aggregate,
    registered_aggregators,
    resolve_spec,
    verified,
    verified_aggregate,
)
from repro.core.centered_clip import (  # noqa: F401
    centered_clip,
    centered_clip_to_tol,
    clip_residuals,
    tau_schedule,
)
from repro.core.butterfly import butterfly_clip, merge_parts, split_parts  # noqa: F401
from repro.core.engine import (  # noqa: F401
    EngineConfig,
    ProtocolState,
    StepOutputs,
    init_state,
    protocol_step,
    scan_protocol,
)
from repro.core.flatten import FlatBoundary, flat_boundary_for  # noqa: F401
from repro.core.protocol import AttackConfig, BTARDProtocol  # noqa: F401
from repro.core.btard_sgd import BTARDTrainer, TrainerConfig  # noqa: F401
