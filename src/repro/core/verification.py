"""Generalized verification wrapper — make ANY coordinatewise aggregator
bannable (paper Alg. 4-6, lifted off the CenteredClip residuals).

The paper's core contribution is not CenteredClip itself but the
CheckComputations accuse/ban protocol that makes aggregation *verifiable*
without a trusted server. Before this module only the ButterflyClip
flagship carried ``verifiable=True``; every §4.1 baseline silently degraded
to the trusted-parameter-server model. The ``verified:`` wrapper closes
that gap for the coordinatewise baselines (mean, trimmed_mean,
coordinate_median) by generalizing the O(n²)-scalar broadcast tables from
CenteredClip residuals to **recomputable per-peer contribution digests**:

    s[i, j]    = <z_j, x_i^j - v_j>          (residual projection)
    norm[i, j] = ||x_i^j - v_j||             (residual norm, drives Δ_max)

where x_i^j is peer i's contribution to partition j (the all_to_all'd
butterfly layout of Alg. 2 — partition j is aggregated by peer j), v_j the
broadcast partition aggregate, and z_j the public unit direction derived
from the MPRNG seed after all contributions are committed.

Soundness (the digest argument, also in kernels/DESIGN.md):

* **recomputable** — x_i^j is a slice of peer i's gradient, itself a pure
  function of the PUBLIC minibatch seed; v_j and z_j are broadcast. So any
  validator (and, for V1, the partition owner j who holds all x_i^j after
  the all_to_all) recomputes a challenged peer's digests bit-for-bit and
  accuses on mismatch — exactly the CheckComputations arm, with the
  engine's existing verify/accuse/ban phases unchanged.
* **binding** — a perturbed contribution x_i^j + δe_c shifts s[i, j] by
  δ·z_j[c] ≠ 0 (z has no exact-zero coordinate a.s.), so a peer cannot
  change what enters the aggregation while reporting the honest digests;
  property-tested in tests/test_verification_grid.py.
* **checksum (V2)** — the zero-sum identity Σ_i w_i s_i^j ≈ 0 is NOT a
  CenteredClip accident generalized by fiat: it holds exactly when the
  digest combines linearly into the aggregate. That is the CenteredClip
  fixed point (butterfly_clip) and the weighted mean
  (Σ w_i <z, x_i - v> = <z, Σ w_i x_i - W v> = 0). For nonlinear
  coordinatewise aggregators (median, trimmed mean) no such identity
  exists, so V2 is statically disabled (:func:`has_zero_checksum`) and a
  lying *aggregator* is instead caught by the validator audit, which the
  engine extends to recompute the audited peer's PARTITION aggregation
  (agg row mismatch) — CheckComputations covers the full work of a peer,
  not just its gradient.

Unlike ButterflyClip there is no clip weight in the digest (no tau), so the
wrapper needs no aggregator-specific kernel state: the standalone digest
pass (kernels.ops.digest_tables_all_op) serves every wrapped spec, and
verified:mean additionally gets a fused aggregation+digest kernel
(kernels.ops.mean_digest_fused_op) because its aggregation is a single
streaming reduction — the fused-epilogue treatment the flagship already
enjoys.

Non-coordinatewise baselines (krum, geometric_median, centered_clip) need
full-vector geometry, so their per-partition contributions are not
independent work units that a partition owner can aggregate — the butterfly
topology (and hence this wrapper) does not apply; :func:`verified` rejects
them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg_mod
from repro.core import butterfly as bf

PREFIX = "verified:"


# ---------------------------------------------------------------------------
# Spec naming: verified:<base> wrappers
# ---------------------------------------------------------------------------
def is_wrapped(spec_or_name) -> bool:
    """True for ``verified:<base>`` wrapper specs/names."""
    name = (
        spec_or_name
        if isinstance(spec_or_name, str)
        else agg_mod.resolve_spec(spec_or_name).name
    )
    return name.startswith(PREFIX)


def base_spec(spec) -> "agg_mod.AggregatorSpec":
    """The underlying coordinatewise spec of a wrapped one (same params)."""
    spec = agg_mod.resolve_spec(spec)
    if not is_wrapped(spec):
        raise ValueError(f"not a {PREFIX}* wrapped spec: {spec.name!r}")
    return agg_mod.AggregatorSpec(spec.name[len(PREFIX):], spec.params)


def verified(spec) -> "agg_mod.AggregatorSpec":
    """Registry combinator: lift a spec into its verifiable form.

    * already-verifiable specs (butterfly_clip, verified:*) come back
      unchanged — ButterflyClip IS its own verified form via the existing
      CenteredClip-residual tables;
    * coordinatewise specs map to ``verified:<name>`` with the same params
      (capability flags recomputed at registration — see
      :func:`register_verified_wrappers`);
    * norm/distance-based specs (krum, geometric_median, centered_clip)
      raise — their partition contributions are not independently
      aggregatable, so the butterfly digest protocol does not apply.
    """
    spec = agg_mod.resolve_spec(spec)
    if spec.verifiable:
        return spec
    if not spec.coordinatewise:
        raise ValueError(
            f"aggregator {spec.name!r} is not coordinatewise: it needs the "
            "full gradient vector, so per-partition contributions are not "
            "independently recomputable work units and the verified: digest "
            "wrapper does not apply (the verifiable full-vector option is "
            "butterfly_clip)"
        )
    wrapped = agg_mod.AggregatorSpec(PREFIX + spec.name, spec.params)
    wrapped.definition  # eager validation (wrapper must be registered)
    return wrapped


def has_zero_checksum(spec) -> bool:
    """Whether Verification 2's zero-sum identity Σ_i w_i s_i^j ≈ 0 holds.

    True exactly when the digest combines linearly into the aggregate: the
    CenteredClip fixed point (butterfly_clip) and the weighted mean. For
    nonlinear wrapped specs the engine statically disables V2 — an honest
    run must produce ZERO accusations, and their aggregator-side detection
    arm is the validator audit's partition recompute instead.

    ``compressed:*`` specs answer for their INNER spec: every digest (and
    the aggregate itself) is computed over the dequantized-from-wire
    values, so the linearity argument is unchanged — it just runs over the
    wire representation (core.compression).
    """
    spec = agg_mod.resolve_spec(spec)
    if spec.name.startswith("compressed:"):
        from repro.core import compression as _compression

        spec = _compression.inner_spec(spec)
    return spec.name in ("butterfly_clip", PREFIX + "mean")


# ---------------------------------------------------------------------------
# Generalized digest tables
# ---------------------------------------------------------------------------
def digest_tables(parts, agg, z, use_pallas: bool = False):
    """Per-peer contribution digests for every partition (Alg. 6 layout).

    parts: (n, n_parts, part); agg, z: (n_parts, part).
    Returns (s (n, n_parts), norms (n, n_parts)):
    s[i, j] = <z_j, x_i^j - v_j>, norm[i, j] = ||x_i^j - v_j|| — the
    unclipped generalization of ``butterfly.verification_tables`` (no tau;
    the wrapped aggregators have no clip radius).
    use_pallas: single-HBM-pass batched digest kernel.
    """
    if use_pallas:
        from repro.kernels.ops import digest_tables_all_op

        return digest_tables_all_op(jnp.swapaxes(parts, 0, 1), agg, z)

    def per_part(xs_j, v_j, z_j):
        diff = (xs_j - v_j[None]).astype(jnp.float32)
        return diff @ z_j.astype(jnp.float32), jnp.linalg.norm(diff, axis=1)

    s, norms = jax.vmap(per_part, in_axes=(1, 0, 0), out_axes=1)(parts, agg, z)
    return s, norms  # both (n, n_parts)


def digest_tables_rows(spec, parts, agg, z, rows, use_pallas: bool = False):
    """SAMPLED-column digests: compute (s, norm) for only the ``rows``
    sampled partition columns — the sampled-digest audit mode's table pass
    (O(n * k) work and broadcast instead of O(n^2); core.hierarchy).

    parts: (n, n_parts, part); agg, z: (n_parts, part); rows: (k,) i32
    sampled partition ids. Returns (s (n, k), norms (n, k)), column j of
    the output = partition rows[j]. Spec-aware like :func:`spec_tables`:
    butterfly_clip applies its tau clip weight, verified:* wrappers take
    the plain digest, compressed:* recurses to its inner spec (parts must
    already be the dequantized-from-wire payloads). ``use_pallas`` routes
    through the scalar-prefetch rows kernel (one HBM pass of the k sampled
    partitions only).
    """
    spec = agg_mod.resolve_spec(spec)
    if spec.name.startswith("compressed:"):
        from repro.core import compression as _compression

        return digest_tables_rows(
            _compression.inner_spec(spec), parts, agg, z, rows,
            use_pallas=use_pallas,
        )
    if spec.name == "butterfly_clip":
        tau = float(spec.get("tau", 1.0))
    elif is_wrapped(spec):
        tau = 0.0
    else:
        raise ValueError(
            f"aggregator {spec.name!r} is not verifiable — it has no "
            "digest tables to sample"
        )
    rows = jnp.asarray(rows, jnp.int32)
    if use_pallas:
        from repro.kernels.ops import digest_tables_rows_op

        return digest_tables_rows_op(
            jnp.swapaxes(parts, 0, 1), agg, z, rows, tau
        )

    parts_r = jnp.take(parts, rows, axis=1)  # (n, k, part)
    agg_r = jnp.take(agg, rows, axis=0)
    z_r = jnp.take(z, rows, axis=0)

    def per_part(xs_j, v_j, z_j):
        diff = (xs_j - v_j[None]).astype(jnp.float32)
        nrm = jnp.linalg.norm(diff, axis=1)
        sj = diff @ z_j.astype(jnp.float32)
        if tau > 0:
            sj = jnp.minimum(1.0, tau / jnp.maximum(nrm, 1e-30)) * sj
        return sj, nrm

    s, norms = jax.vmap(per_part, in_axes=(1, 0, 0), out_axes=1)(
        parts_r, agg_r, z_r
    )
    return s, norms


def spec_tables(spec, parts, agg, z, use_pallas: bool = False):
    """Recompute a verifiable spec's broadcast tables against a GIVEN
    aggregate (the standalone path when agg changed after aggregation, e.g.
    tables against a corrupted aggregator's broadcast value).

    butterfly_clip -> tau-clipped residual tables; verified:* -> the plain
    digests. Raises for non-verifiable specs (no tables exist).

    compressed:* -> the INNER spec's tables over the given parts, which
    must already be the dequantized-from-wire payloads (exactly what
    ``spec_aggregate`` returns for a compressed spec) — tables are always
    digests over the wire representation, never the raw gradients.
    """
    spec = agg_mod.resolve_spec(spec)
    if spec.name.startswith("compressed:"):
        from repro.core import compression as _compression

        return spec_tables(
            _compression.inner_spec(spec), parts, agg, z,
            use_pallas=use_pallas,
        )
    if spec.name == "butterfly_clip":
        return bf.verification_tables(
            parts, agg, z, spec.get("tau", 1.0), use_pallas=use_pallas
        )
    if not is_wrapped(spec):
        raise ValueError(
            f"aggregator {spec.name!r} is not verifiable — it has no "
            "broadcast tables"
        )
    return digest_tables(parts, agg, z, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# The verifiable aggregation contract (engine aggregation phase)
# ---------------------------------------------------------------------------
def spec_aggregate(spec, grads, z=None, weights=None, v0=None,
                   use_pallas: bool = False):
    """Aggregate by ANY verifiable spec in the butterfly partition layout,
    with (``z`` given) or without the broadcast tables.

    grads: (n, d); z: optional (n_parts, part) unit directions (MPRNG seed);
    v0: optional (n_parts, part) warm start (butterfly_clip only — wrapped
    specs are not warm-startable). Returns (agg (n_parts, part),
    parts (n, n_parts, part), s, norms, iters () i32); s/norms are None
    when z is None. Raises for non-verifiable specs — callers degrade
    verification to a no-op instead (core.engine).

    For wrapped specs the base coordinatewise fn applied to the FULL
    stacked matrix equals its per-partition application (coordinate
    decomposition; property-tested in tests/test_verification_grid.py), so
    the simulated path aggregates once and splits. verified:mean with
    ``use_pallas`` routes through the fused aggregation+digest kernel; the
    other wrapped specs aggregate in jnp (sort-based — no kernel win) and
    take the standalone single-pass digest kernel.
    """
    spec = agg_mod.resolve_spec(spec)
    n, d = grads.shape
    if spec.name.startswith("compressed:"):
        # quantize the butterfly payloads, then run the inner spec over the
        # dequantized-from-wire values (core.compression) — returned parts
        # are the wire values every downstream digest/table sees
        from repro.core import compression as _compression

        return _compression.compressed_aggregate(
            spec, grads, z=z, weights=weights, v0=v0, use_pallas=use_pallas,
        )
    if spec.name == "butterfly_clip":
        p = spec.param_dict()
        if not p.get("warm_start"):
            v0 = None
        return bf.clip_aggregate(
            grads, p["tau"], p["n_iters"], z=z,
            adaptive_tol=p["adaptive_tol"], weights=weights,
            use_pallas=use_pallas, v0=v0,
        )
    if not is_wrapped(spec):
        raise ValueError(
            f"aggregator {spec.name!r} is not verifiable — it produces no "
            "broadcast tables; run it through aggregate() and skip the "
            "verification phases"
        )
    base = base_spec(spec)
    parts = bf.split_parts(grads, n)
    if use_pallas and base.name == "mean" and z is not None:
        from repro.kernels.ops import mean_digest_fused_op

        agg, s, norms = mean_digest_fused_op(
            jnp.swapaxes(parts, 0, 1), z, weights
        )
        return agg, parts, s, norms, jnp.asarray(1, jnp.int32)
    base_fn = base.build(n, d, use_pallas=use_pallas)
    flat, info = base_fn(
        grads, weights if base.weighted else None, None, None
    )
    agg = bf.split_parts(flat.astype(jnp.float32)[None, :], n)[0]
    iters = jnp.asarray(info.iters, jnp.int32)
    if z is None:
        return agg, parts, None, None, iters
    s, norms = digest_tables(parts, agg, z, use_pallas=use_pallas)
    return agg, parts, s, norms, iters


def owner_aggregate(spec, stack, z, weights=None, use_pallas: bool = False,
                    key=None, wire=None):
    """ONE partition owner's work on the distributed path: aggregate the
    all_to_all'd (n, part) stack with the BASE fn and digest against the
    result — the single-partition sibling of :func:`spec_aggregate`'s
    batched path, so the fused-vs-standalone kernel dispatch lives here and
    only here (launch.steps.aggregation_stage calls this).

    Returns (agg (part,), s (n,), norms (n,), iters () i32).

    For compressed:* specs ``stack`` must already be the dequantized-from-
    wire payloads (the launch stage dequantizes right after the all_to_all
    — launch.steps), so the owner's aggregation and digests run over the
    wire representation and match every validator's recompute bitwise.
    ``wire`` optionally carries the received wire payloads themselves as
    ``(qs (n, part) int8/bf16, scales (n,) f32)``; with ``use_pallas`` the
    mean path then reads the 1-2 byte wire dtype straight from HBM through
    the fused dequantize+mean+digest kernel instead of the materialized f32
    ``stack`` (identical values — one dequantize formula everywhere).
    """
    spec = agg_mod.resolve_spec(spec)
    if spec.name.startswith("compressed:"):
        from repro.core import compression as _compression

        return owner_aggregate(
            _compression.inner_spec(spec), stack, z, weights=weights,
            use_pallas=use_pallas, key=key, wire=wire,
        )
    base = base_spec(spec)
    n, part = stack.shape
    stack = stack.astype(jnp.float32)
    z = z.astype(jnp.float32)
    if use_pallas and base.name == "mean":
        if wire is not None:
            from repro.kernels.ops import mean_digest_fused_dequant_op

            qs, scales = wire
            agg_b, s_b, n_b = mean_digest_fused_dequant_op(
                qs[None], scales[None], z[None], weights
            )
            return agg_b[0], s_b[:, 0], n_b[:, 0], jnp.asarray(1, jnp.int32)
        from repro.kernels.ops import mean_digest_fused_op

        agg_b, s_b, n_b = mean_digest_fused_op(stack[None], z[None], weights)
        return agg_b[0], s_b[:, 0], n_b[:, 0], jnp.asarray(1, jnp.int32)
    base_fn = base.build(n, part, use_pallas=use_pallas)
    agg, info = base_fn(
        stack, weights if base.weighted else None, None, key
    )
    agg = agg.astype(jnp.float32)
    s, norms = digest_tables(
        stack[:, None, :], agg[None], z[None], use_pallas=use_pallas
    )
    return agg, s[:, 0], norms[:, 0], jnp.asarray(info.iters, jnp.int32)


# ---------------------------------------------------------------------------
# Registration: one verified:<base> wrapper per coordinatewise baseline
# ---------------------------------------------------------------------------
def register_verified_wrappers():
    """Register ``verified:<name>`` for every coordinatewise baseline in the
    registry, with the capability flags recomputed: verifiable=True (the
    point of the wrapper), warm_startable=False (no iterate to seed),
    everything else inherited. The maker is the base maker unchanged — the
    FLAT fn (no tables) is exactly the base aggregator; the verified path
    with tables is spec_aggregate/spec_tables above. Idempotent."""
    for name, base_def in list(agg_mod.REGISTRY.items()):
        if base_def.verifiable or not base_def.coordinatewise:
            continue
        wrapped = PREFIX + name
        if wrapped in agg_mod.REGISTRY:
            continue
        agg_mod.register(agg_mod.AggregatorDef(
            wrapped,
            base_def.make,
            defaults=base_def.defaults,
            verifiable=True,
            weighted=base_def.weighted,
            warm_startable=False,
            adaptive=base_def.adaptive,
            coordinatewise=True,
        ))


register_verified_wrappers()

# the compressed:<verifiable> wire-codec wrappers register themselves on
# import (core.compression.register_compressed_wrappers). The import lives
# HERE, after register_verified_wrappers(), so the compressed: loop always
# sees the verified:* wrappers whichever of the three modules is imported
# first.
import repro.core.compression  # noqa: E402,F401  (registration side effect)
