"""BTARD-SGD / BTARD-Clipped-SGD training loops (paper Alg. 7 / 9) plus the
restarted strongly-convex variants (Alg. 8) and PS-baseline defenses.

The trainer simulates n peers on one host: per-peer gradients from PUBLIC
minibatch seeds (the paper's homogeneous-data assumption), the full BTARD
protocol (core.protocol) between SGD steps, and any optimizer from
repro.optim applied to the robust aggregate.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flatten import FlatBoundary
from repro.core.aggregators import (
    AGGREGATORS,
    REGISTRY,
    AggregatorSpec,
    resolve_spec,
    with_byzantine_default,
)
from repro.core import attacks as attacks_mod
from repro.core import engine as eng
from repro.core.protocol import AttackConfig, BTARDProtocol
from repro.optim import sgd
from repro.optim.optimizers import apply_updates


@dataclass
class TrainerConfig:
    n_peers: int = 16
    byzantine: tuple = ()
    attack: AttackConfig = field(default_factory=AttackConfig)
    defense: str = "btard"  # btard | any registered AggregatorSpec name,
    # incl. the verified:<base> wrapped coordinatewise specs (bannable)
    tau: float = 1.0
    clip_iters: int = 60
    m_validators: int = 1
    delta_max: float | None = None
    clip_lambda: float | None = None  # enables BTARD-Clipped-SGD
    seed: int = 0
    use_pallas: bool = False  # fused aggregation+tables kernel (DESIGN.md)
    warm_start: bool = False  # CenteredClip v0 = last aggregate (DESIGN.md)
    # stop CenteredClip at ||v_{l+1}-v_l|| <= adaptive_tol (clip_iters is
    # then the static cap); None = fixed budget. Composes with warm_start —
    # together they convert the ~2x iters-to-tol saving into wall clock.
    adaptive_tol: float | None = None
    # explicit AggregatorSpec (or "name[:k=v,...]") for the engine paths
    # (protocol / run_scan). None resolves from `defense`: "btard" -> the
    # flagship ButterflyClip; any other registered name -> that spec, with
    # krum's n_byzantine defaulting to len(byzantine). Non-verifiable specs
    # run without the accusation/ban machinery (core.aggregators).
    aggregator: object = None


class BTARDTrainer:
    """loss_fn(params, batch) -> scalar;  batch_fn(peer, step, flipped) -> batch."""

    def __init__(self, loss_fn, params0, batch_fn, cfg: TrainerConfig, optimizer=None):
        self.cfg = cfg
        self.batch_fn = batch_fn
        # THE ravel boundary (core.flatten): flat f32 master params / flat
        # f32 gradient rows on the engine side, original leaf dtypes (bf16
        # for mixed-precision models) on the model side.
        self.boundary = FlatBoundary(params0)
        self._unravel = self.boundary.unflatten
        self.params = np.asarray(self.boundary.flatten(params0), np.float32)
        self.d = self.params.size
        self.opt = optimizer or sgd(0.05, momentum=0.9, nesterov=True)
        self._opt_state = self.opt.init(jnp.asarray(self.params))
        self._loss = loss_fn
        boundary = self.boundary
        self._grad = jax.jit(
            lambda flat, batch: boundary.flatten(
                jax.grad(lambda p: loss_fn(p, batch))(boundary.unflatten(flat))
            )
        )
        agg = cfg.aggregator
        if agg is None and cfg.defense != "btard" and cfg.defense in REGISTRY:
            agg = with_byzantine_default(
                AggregatorSpec(cfg.defense), len(cfg.byzantine)
            )
        self._engine_aggregator = agg
        # verifiable defenses (the flagship AND the verified:* wrapped
        # coordinatewise specs) run the full accuse/ban protocol in BOTH
        # entry points; only non-verifiable baselines take the legacy
        # trusted-PS _baseline_step on the host path.
        self._protocol_defense = cfg.defense == "btard" or (
            agg is not None and resolve_spec(agg).verifiable
        )
        self.protocol = BTARDProtocol(
            n_peers=cfg.n_peers,
            d=self.d,
            grad_fn=self._peer_grad,
            byzantine=set(cfg.byzantine),
            attack=cfg.attack,
            tau=cfg.tau,
            clip_iters=cfg.clip_iters,
            m_validators=cfg.m_validators,
            delta_max=cfg.delta_max,
            clip_lambda=cfg.clip_lambda,
            seed=cfg.seed,
            use_pallas=cfg.use_pallas,
            warm_start=cfg.warm_start,
            adaptive_tol=cfg.adaptive_tol,
            aggregator=agg,
        )
        self.history: list = []
        self._step = 0
        self._scan_runners: dict = {}  # n_steps -> jitted scan runner

    # ------------------------------------------------------------------
    def _peer_grad(self, peer, step, params_flat, flipped=False):
        batch = self.batch_fn(peer, step, flipped)
        return self._grad(jnp.asarray(params_flat), batch)

    def _baseline_step(self, t):
        """PS-style defense baselines: stacked grads -> robust aggregate."""
        cfg = self.cfg
        active = list(range(cfg.n_peers))
        byz_mask = np.array([i in set(cfg.byzantine) for i in active])
        flip = (
            cfg.attack.kind == "label_flip"
            and cfg.attack.start_step <= t < cfg.attack.end_step
        )
        G = np.stack(
            [
                np.asarray(
                    self._peer_grad(i, t, self.params, flipped=flip and byz_mask[idx])
                )
                for idx, i in enumerate(active)
            ]
        )
        if (
            cfg.attack.kind not in ("none", "label_flip")
            and cfg.attack.start_step <= t < cfg.attack.end_step
        ):
            fn = attacks_mod.GRADIENT_ATTACKS[cfg.attack.kind]
            G = np.asarray(
                fn(
                    jnp.asarray(G),
                    jnp.asarray(byz_mask),
                    key=jax.random.key(t),
                    lam=cfg.attack.lam,
                )
            )
        agg_fn = AGGREGATORS[cfg.defense]
        if cfg.defense == "krum":
            g = agg_fn(jnp.asarray(G), n_byzantine=int(byz_mask.sum()))
        elif cfg.defense == "centered_clip":
            g = agg_fn(jnp.asarray(G), tau=cfg.tau)
        else:
            g = agg_fn(jnp.asarray(G))
        return np.asarray(g), None

    # ------------------------------------------------------------------
    def train_step(self):
        t = self._step
        if self._protocol_defense:
            g, info = self.protocol.step(self.params, t)
        else:
            g, info = self._baseline_step(t)
        updates, self._opt_state = self.opt.update(
            jnp.asarray(g), self._opt_state, jnp.asarray(self.params), t
        )
        self.params = np.asarray(
            apply_updates(jnp.asarray(self.params), updates), np.float32
        )
        self._step += 1
        return g, info

    def run(self, n_steps, eval_fn=None, eval_every=10, log=None):
        for _ in range(n_steps):
            g, info = self.train_step()
            rec = {
                "step": self._step - 1,
                "grad_norm": float(np.linalg.norm(g)),
                "n_banned": len(self.protocol.banned),
            }
            if info is not None:
                rec["banned_now"] = info.banned_now
            if eval_fn is not None and (self._step - 1) % eval_every == 0:
                rec["eval"] = float(eval_fn(self.unraveled_params()))
            self.history.append(rec)
            if log:
                log(rec)
        return self.history

    # ------------------------------------------------------------------
    # Scan fast path: the whole loop (grads -> protocol -> optimizer) as
    # ONE jitted lax.scan over the ProtocolState pytree (core.engine)
    # ------------------------------------------------------------------
    def _pure_grads_fn(self):
        """grads_fn(flat_params, t, flips) -> (G, honest_G) for the engine —
        the engine's device-resident data phase (eng.device_data_grads_fn):
        per-peer public-seed batches are generated INSIDE the scanned step.
        Requires batch_fn to be jax-traceable in (peer, step) — true of the
        public-seed pipelines; arbitrary host batch_fns must use run()."""
        boundary, loss_fn, batch_fn = self.boundary, self._loss, self.batch_fn

        def grad_fn(flat, batch):
            return boundary.flatten(
                jax.grad(lambda p: loss_fn(p, batch))(boundary.unflatten(flat))
            )

        return eng.device_data_grads_fn(
            self.cfg.n_peers,
            lambda i, t, flipped: batch_fn(i, t, flipped),
            grad_fn,
            label_flip=self.cfg.attack.kind == "label_flip",
        )

    def _get_scan_runner(self, n_steps):
        """Jitted (state, flat_params, opt_state) -> scanned n_steps rounds;
        cached per length. Pure — callers may invoke it directly to warm the
        compile cache without advancing the trainer."""
        runner = self._scan_runners.get(n_steps)
        if runner is not None:
            return runner
        proto = self.protocol
        ecfg = proto.engine_config
        grads_fn = self._pure_grads_fn()
        opt = self.opt

        def body(carry, _):
            st, flat, opt_state = carry
            flips = eng.flip_mask(ecfg, st, proto.byz_mask)
            G, honest_G = grads_fn(flat, st.step, flips)
            st, out = eng.protocol_step(ecfg, st, proto.byz_mask, G, honest_G)
            updates, opt_state = opt.update(
                out.g_hat, opt_state, flat, st.step - 1
            )
            flat = apply_updates(flat, updates)
            return (st, flat, opt_state), out

        runner = jax.jit(
            lambda s, f, o: jax.lax.scan(body, (s, f, o), None, length=n_steps)
        )
        self._scan_runners[n_steps] = runner
        return runner

    def run_scan(self, n_steps, log=None):
        """Run ``n_steps`` full BTARD rounds under one jitted ``lax.scan`` —
        zero host sync between steps (the legacy loop pays per-phase device
        round-trips). Bit-matches run() up to XLA fusion-order f32 noise;
        bans/accusations are mirrored back into the host bookkeeping.

        Any registered aggregator runs here — "btard" maps to the flagship
        ButterflyClip spec; baseline defenses (mean, krum, ...) run through
        the same scanned engine with verification degraded to a no-op."""
        if self.cfg.defense != "btard" and self._engine_aggregator is None:
            raise ValueError(
                f"run_scan: defense {self.cfg.defense!r} is not a registered "
                "aggregator (see repro.core.aggregators.registered_aggregators)"
            )
        proto = self.protocol
        runner = self._get_scan_runner(n_steps)
        (state, flat, opt_state), outs = runner(
            proto.state, jnp.asarray(self.params), self._opt_state
        )
        proto.state = state
        self.params = np.asarray(flat, np.float32)
        self._opt_state = opt_state
        # mirror the stacked outputs into the legacy history/ban sets
        banned_now = np.asarray(outs.banned_now)
        reasons = np.asarray(outs.ban_reason_now)
        g_norms = np.linalg.norm(np.asarray(outs.g_hat), axis=1)
        iters_used = np.asarray(outs.clip_iters_used)
        # accusation targets per step (peer accusations + checksum/Delta_max
        # system accusations) — the "zero honest accusations" property is
        # asserted on these, not just on the ban set
        accused = np.asarray(outs.accuse_mat).any(axis=1) | np.asarray(
            outs.sys_accuse
        )
        for k in range(n_steps):
            new = [
                (int(i), eng.BAN_REASON_NAMES[int(reasons[k, i])])
                for i in np.nonzero(banned_now[k])[0]
            ]
            proto.banned.update(p for p, _ in new)
            rec = {
                "step": self._step,
                "grad_norm": float(g_norms[k]),
                "n_banned": len(proto.banned),
                "banned_now": new,
                "accused_peers": [int(i) for i in np.nonzero(accused[k])[0]],
                "clip_iters_used": int(iters_used[k]),
            }
            self.history.append(rec)
            if log:
                log(rec)
            self._step += 1
        proto.validators = proto._mask_to_list(state.validator)
        return self.history

    def unraveled_params(self):
        return self._unravel(jnp.asarray(self.params))

    @property
    def banned(self):
        return set(self.protocol.banned)


# ---------------------------------------------------------------------------
# Restarted variants (paper Alg. 8): re-launch with halved radius schedule.
# ---------------------------------------------------------------------------
def restarted_btard_sgd(
    make_trainer, n_restarts: int, steps_fn, lr_fn,
):
    """make_trainer(lr, params0) -> BTARDTrainer; steps_fn(t)/lr_fn(t) give
    per-restart budgets (paper eq. (44)-(45): gamma_t ~ 2^{-t/2}, K_t ~ 2^t).
    Returns (final params pytree, history)."""
    params = None
    history = []
    for r in range(n_restarts):
        tr = make_trainer(lr_fn(r), params)
        tr.run(steps_fn(r))
        params = tr.unraveled_params()
        history.extend([{**h, "restart": r} for h in tr.history])
    return params, history
