"""The paper's attack zoo (§4.1): what Byzantine peers send instead of
their honest gradients.

All gradient attacks transform the stacked (n, d) gradient matrix given the
Byzantine mask. LABEL FLIP is applied at gradient-computation time (it needs
the loss), so the trainer handles it via ``needs_flipped_labels``.

Two call surfaces share the math:

* ``GRADIENT_ATTACKS`` — the legacy name -> fn dict (host loops pick a fn
  once, outside jit);
* the **registry** (``ATTACK_NAMES`` / ``attack_index`` / ``apply_attack``)
  — every attack as a statically-shaped pure function of the SAME signature
  ``(grads, byz_mask, key, lam, delayed, hon_mask)``, selectable by integer
  index via ``lax.switch``, so the attack choice composes under jit/scan
  (the ProtocolState engine threads the index through ``lax.scan`` without
  retracing per attack).

``hon_mask`` marks the rows whose statistics collusion attacks (IPM, ALIE)
may use — the engine passes ``active & ~byzantine`` so banned peers drop out
of the honest mean/variance exactly as they do in the host protocol, where
banned rows never enter the stacked matrix at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri


def _hon(byz_mask, hon_mask):
    return ~byz_mask if hon_mask is None else hon_mask


def sign_flip(grads, byz_mask, *, lam=1000.0, **_):
    """Each attacker sends -lam * its true gradient (paper amplifies by 1000)."""
    return jnp.where(byz_mask[:, None], -lam * grads, grads)


def random_direction(grads, byz_mask, *, key, lam=1000.0, hon_mask=None, **_):
    """All attackers send a large common random vector."""
    v = jax.random.normal(key, (grads.shape[1],), grads.dtype)
    v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)
    scale = lam * jnp.linalg.norm(grads, axis=1).mean()
    return jnp.where(byz_mask[:, None], (scale * v)[None, :], grads)


def delayed_gradient(grads, byz_mask, *, delayed, **_):
    """Attackers send their real gradients delayed by D steps (trainer keeps
    the history buffer and passes the delayed rows)."""
    return jnp.where(byz_mask[:, None], delayed, grads)


def ipm(grads, byz_mask, *, epsilon=0.6, hon_mask=None, **_):
    """Inner-product manipulation (Xie et al. 2020): attackers send
    -epsilon * mean(honest gradients)."""
    hon = _hon(byz_mask, hon_mask)
    denom = jnp.maximum(hon.sum(), 1)
    mu = (grads * hon[:, None]).sum(0) / denom
    return jnp.where(byz_mask[:, None], (-epsilon * mu)[None, :], grads)


def alie(grads, byz_mask, *, hon_mask=None, **_):
    """A Little Is Enough (Baruch et al. 2019): collude to shift the
    coordinate-wise statistics while staying inside the population variance.

    z_max = Phi^{-1}((n - b - s) / (n - b)),  s = floor(n/2) + 1 - b.
    Attackers send mu - z_max * sigma (coordinate-wise over honest peers).
    """
    n = grads.shape[0]
    b = byz_mask.sum()
    hon = _hon(byz_mask, hon_mask)
    denom = jnp.maximum(hon.sum(), 1)
    mu = (grads * hon[:, None]).sum(0) / denom
    var = ((grads - mu[None]) ** 2 * hon[:, None]).sum(0) / jnp.maximum(denom - 1, 1)
    sigma = jnp.sqrt(var)
    s = jnp.floor_divide(n, 2) + 1 - b
    q = jnp.clip((n - b - s) / jnp.maximum(n - b, 1), 1e-4, 1 - 1e-4)
    z_max = ndtri(q.astype(jnp.float64) if False else q.astype(jnp.float32))
    mal = mu - z_max * sigma
    return jnp.where(byz_mask[:, None], mal[None, :], grads)


def label_flip(grads, byz_mask, **_):
    """Marker: handled at gradient computation (loss with flipped labels)."""
    return grads


GRADIENT_ATTACKS = {
    "none": lambda g, m, **kw: g,
    "sign_flip": sign_flip,
    "random_direction": random_direction,
    "label_flip": label_flip,
    "delayed_gradient": delayed_gradient,
    "ipm_01": lambda g, m, **kw: ipm(g, m, epsilon=0.1, hon_mask=kw.get("hon_mask")),
    "ipm_06": lambda g, m, **kw: ipm(g, m, epsilon=0.6, hon_mask=kw.get("hon_mask")),
    "alie": alie,
}

NEEDS_FLIPPED_LABELS = {"label_flip"}
NEEDS_DELAY_BUFFER = {"delayed_gradient"}


# ---------------------------------------------------------------------------
# Jit-composable registry: one uniform statically-shaped signature per
# attack, dispatched by integer index (lax.switch) inside the engine.
# ---------------------------------------------------------------------------
ATTACK_NAMES = (
    "none",
    "sign_flip",
    "random_direction",
    "label_flip",
    "delayed_gradient",
    "ipm_01",
    "ipm_06",
    "alie",
)
ATTACK_INDEX = {name: i for i, name in enumerate(ATTACK_NAMES)}


def attack_index(kind: str) -> int:
    """Registry index for an attack name (raises KeyError on unknown)."""
    return ATTACK_INDEX[kind]


def rejoin_under_new_key(slot, leave_step, rejoin_step, identity=None):
    """The churn adversary: a (typically already banned) peer vacates its
    slot and rejoins it, continuing whatever gradient attack its slot's
    ``byz_mask`` entry encodes. ``identity=None`` is the NEW-KEY variant —
    ``engine.encode_events`` mints a fresh identity, so the ban ledger does
    not refuse it at admission and the probation spot-check (core.sybil)
    must catch it; pass the original identity for the SAME-KEY variant,
    refused directly from the identity ban ledger. Returns an event
    schedule for ``EngineConfig``/``init_state`` (or ``--churn`` via the
    equivalent ``leave@S:P,join@S:P`` string)."""
    join = ((rejoin_step, "join", slot) if identity is None
            else (rejoin_step, "join", slot, identity))
    return [(leave_step, "leave", slot), join]


def _uniform(fn, **fixed):
    def wrapped(grads, byz_mask, key, lam, delayed, hon_mask):
        return fn(
            grads, byz_mask,
            key=key, lam=lam, delayed=delayed, hon_mask=hon_mask, **fixed,
        )

    return wrapped


_REGISTRY = (
    _uniform(lambda g, m, **_: g),  # none
    _uniform(sign_flip),
    _uniform(random_direction),
    _uniform(label_flip),
    _uniform(delayed_gradient),
    _uniform(ipm, epsilon=0.1),
    _uniform(ipm, epsilon=0.6),
    _uniform(alie),
)


def apply_attack(idx, grads, byz_mask, *, key, lam=1000.0, delayed=None,
                 hon_mask=None):
    """Apply registry attack ``idx`` (int or traced int32) to the stacked
    gradients. All branches share static shapes, so a traced ``idx`` stays
    inside the compiled graph (no host dispatch, scan-safe).

    byz_mask: rows the attack REPLACES (the engine passes active & byz).
    hon_mask: rows collusion statistics may read (active & ~byz).
    delayed:  (n, d) rows for delayed_gradient; zeros otherwise.
    """
    if delayed is None:
        delayed = jnp.zeros_like(grads)
    lam = jnp.asarray(lam, grads.dtype)
    return jax.lax.switch(
        jnp.asarray(idx, jnp.int32),
        _REGISTRY,
        grads, byz_mask, key, lam, delayed, hon_mask,
    )


# ---------------------------------------------------------------------------
# Aggregator-side attacks (a Byzantine peer aggregating a partition lies)
# ---------------------------------------------------------------------------
def aggregator_shift(agg_part, key, scale):
    """Malicious aggregator adds a bounded random shift to its partition
    (bounded because Verification 3 / Delta_max votes catch large ones)."""
    noise = jax.random.normal(key, agg_part.shape, agg_part.dtype)
    noise = noise / jnp.maximum(jnp.linalg.norm(noise), 1e-30)
    return agg_part + scale * noise


def aggregator_shift_all(agg, corrupt_mask, key, scale):
    """Vectorized aggregator attack over the stacked partitions: rows of
    ``agg`` (n_parts, part) where ``corrupt_mask`` is set receive a unit
    random shift scaled by ``scale`` (one independent direction per
    partition). Pure + statically shaped for the jit/scan engine."""
    noise = jax.random.normal(key, agg.shape, jnp.float32)
    noise = noise / jnp.maximum(
        jnp.linalg.norm(noise, axis=1, keepdims=True), 1e-30
    )
    return jnp.where(corrupt_mask[:, None], agg + scale * noise, agg)
