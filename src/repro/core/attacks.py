"""The paper's attack zoo (§4.1): what Byzantine peers send instead of
their honest gradients.

All gradient attacks transform the stacked (n, d) gradient matrix given the
Byzantine mask. LABEL FLIP is applied at gradient-computation time (it needs
the loss), so the trainer handles it via ``needs_flipped_labels``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri


def sign_flip(grads, byz_mask, *, lam=1000.0, **_):
    """Each attacker sends -lam * its true gradient (paper amplifies by 1000)."""
    return jnp.where(byz_mask[:, None], -lam * grads, grads)


def random_direction(grads, byz_mask, *, key, lam=1000.0, **_):
    """All attackers send a large common random vector."""
    v = jax.random.normal(key, (grads.shape[1],), grads.dtype)
    v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)
    scale = lam * jnp.linalg.norm(grads, axis=1).mean()
    return jnp.where(byz_mask[:, None], (scale * v)[None, :], grads)


def delayed_gradient(grads, byz_mask, *, delayed, **_):
    """Attackers send their real gradients delayed by D steps (trainer keeps
    the history buffer and passes the delayed rows)."""
    return jnp.where(byz_mask[:, None], delayed, grads)


def ipm(grads, byz_mask, *, epsilon=0.6, **_):
    """Inner-product manipulation (Xie et al. 2020): attackers send
    -epsilon * mean(honest gradients)."""
    hon = ~byz_mask
    denom = jnp.maximum(hon.sum(), 1)
    mu = (grads * hon[:, None]).sum(0) / denom
    return jnp.where(byz_mask[:, None], (-epsilon * mu)[None, :], grads)


def alie(grads, byz_mask, **_):
    """A Little Is Enough (Baruch et al. 2019): collude to shift the
    coordinate-wise statistics while staying inside the population variance.

    z_max = Phi^{-1}((n - b - s) / (n - b)),  s = floor(n/2) + 1 - b.
    Attackers send mu - z_max * sigma (coordinate-wise over honest peers).
    """
    n = grads.shape[0]
    b = byz_mask.sum()
    hon = ~byz_mask
    denom = jnp.maximum(hon.sum(), 1)
    mu = (grads * hon[:, None]).sum(0) / denom
    var = ((grads - mu[None]) ** 2 * hon[:, None]).sum(0) / jnp.maximum(denom - 1, 1)
    sigma = jnp.sqrt(var)
    s = jnp.floor_divide(n, 2) + 1 - b
    q = jnp.clip((n - b - s) / jnp.maximum(n - b, 1), 1e-4, 1 - 1e-4)
    z_max = ndtri(q.astype(jnp.float64) if False else q.astype(jnp.float32))
    mal = mu - z_max * sigma
    return jnp.where(byz_mask[:, None], mal[None, :], grads)


def label_flip(grads, byz_mask, **_):
    """Marker: handled at gradient computation (loss with flipped labels)."""
    return grads


GRADIENT_ATTACKS = {
    "none": lambda g, m, **kw: g,
    "sign_flip": sign_flip,
    "random_direction": random_direction,
    "label_flip": label_flip,
    "delayed_gradient": delayed_gradient,
    "ipm_01": lambda g, m, **kw: ipm(g, m, epsilon=0.1),
    "ipm_06": lambda g, m, **kw: ipm(g, m, epsilon=0.6),
    "alie": alie,
}

NEEDS_FLIPPED_LABELS = {"label_flip"}
NEEDS_DELAY_BUFFER = {"delayed_gradient"}


# ---------------------------------------------------------------------------
# Aggregator-side attacks (a Byzantine peer aggregating a partition lies)
# ---------------------------------------------------------------------------
def aggregator_shift(agg_part, key, scale):
    """Malicious aggregator adds a bounded random shift to its partition
    (bounded because Verification 3 / Delta_max votes catch large ones)."""
    noise = jax.random.normal(key, agg_part.shape, agg_part.dtype)
    noise = noise / jnp.maximum(jnp.linalg.norm(noise), 1e-30)
    return agg_part + scale * noise
