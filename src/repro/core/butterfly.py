"""ButterflyClip numerics (paper Alg. 2/5) + the O(n^2)-scalar verification
tables (Alg. 6): pure-jnp, shape (n_peers, d) -> robust average (d,).

Two call modes share this math:
  * simulated — stacked peer axis on one device (tests, controlled §4.1 runs);
  * distributed — launch/train.py wraps the same per-partition CenteredClip
    in a shard_map all_to_all/all_gather over the mesh peer axes.

Partitioning pads d to a multiple of n (the paper's SPLIT uses uneven parts;
padding with zeros is numerically identical for aggregation and keeps XLA
shapes static — recorded in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.centered_clip import (
    centered_clip_adaptive_stacked,
    centered_clip_stacked,
    clip_residuals,
)


def pad_to_parts(d: int, n: int) -> int:
    return -(-d // n) * n


def split_parts(grads, n_parts):
    """(n, d) -> (n, n_parts, part) with zero padding."""
    n, d = grads.shape
    dp = pad_to_parts(d, n_parts)
    if dp != d:
        grads = jnp.pad(grads, ((0, 0), (0, dp - d)))
    return grads.reshape(n, n_parts, dp // n_parts)


def merge_parts(agg, d):
    """(n_parts, part) -> (d,)."""
    return agg.reshape(-1)[:d]


def butterfly_clip(
    grads, tau, n_iters: int = 50, weights=None, use_pallas=False, v0=None
):
    """Robust butterfly all-reduce: partition j is CenteredClip-aggregated
    across peers (by peer j in the real topology). Returns (agg_parts, parts).

    grads: (n, d). agg_parts: (n_parts, part). parts: (n, n_parts, part).
    use_pallas: run the aggregation through the fused all-partition TPU
    kernel (kernels/centered_clip.butterfly_clip_pallas).
    v0: optional (n_parts, part) warm start — the previous step's aggregate
    (cuts the iteration budget; see kernels/DESIGN.md warm-start section).
    """
    n = grads.shape[0]
    parts = split_parts(grads, n)

    if use_pallas:
        from repro.kernels.ops import butterfly_clip_op

        agg = butterfly_clip_op(
            jnp.swapaxes(parts, 0, 1), tau, weights, n_iters=n_iters, v0=v0
        )
        return agg, parts

    stacked = jnp.swapaxes(parts, 0, 1)  # (n_parts, n, part)
    agg = centered_clip_stacked(
        stacked, tau, n_iters=n_iters, weights=weights, v0=v0
    )
    return agg, parts


def butterfly_clip_adaptive(
    grads, tau, tol, max_iters: int, weights=None, use_pallas=False, v0=None
):
    """Adaptive-budget ButterflyClip aggregation: each partition's
    CenteredClip runs until ``||v_{l+1}-v_l|| <= tol`` (static ``max_iters``
    cap) under a ``lax.while_loop`` — the fixed point is unchanged, only the
    iteration budget adapts (warm starts via ``v0`` compound the saving).

    Returns (agg_parts (n_parts, part), parts (n, n_parts, part),
    iters (n_parts,) i32). use_pallas routes through the early-exit
    one-pass-per-iteration kernel driver (kernels/ops).
    """
    n = grads.shape[0]
    parts = split_parts(grads, n)
    stacked = jnp.swapaxes(parts, 0, 1)

    if use_pallas:
        from repro.kernels.ops import butterfly_clip_adaptive_op

        agg, iters = butterfly_clip_adaptive_op(
            stacked, tau, tol, weights, v0=v0, max_iters=max_iters
        )
        return agg, parts, iters

    agg, iters = centered_clip_adaptive_stacked(
        stacked, tau, tol, max_iters, weights=weights, v0=v0
    )
    return agg, parts, iters


def butterfly_clip_verified_adaptive(
    grads, tau, z, tol, max_iters: int, weights=None, use_pallas=False,
    v0=None,
):
    """Adaptive aggregation PLUS the Alg. 6 broadcast tables.

    The tables are a deterministic function of (parts, agg, z): however many
    iterations the early exit took, the verification epilogue runs EXACTLY
    once against the final iterate, so every peer recomputing the tables
    from the broadcast aggregate gets identical values (the accusation
    semantics never see the iteration count — kernels/DESIGN.md).

    Returns (agg_parts, parts, s (n, n_parts), norms (n, n_parts),
    iters (n_parts,) i32).
    """
    if use_pallas:
        from repro.kernels.ops import butterfly_clip_fused_adaptive_op

        n = grads.shape[0]
        parts = split_parts(grads, n)
        agg, s, norms, iters = butterfly_clip_fused_adaptive_op(
            jnp.swapaxes(parts, 0, 1), tau, z, tol, weights, v0=v0,
            max_iters=max_iters,
        )
        return agg, parts, s, norms, iters
    agg, parts, iters = butterfly_clip_adaptive(
        grads, tau, tol, max_iters, weights=weights, v0=v0
    )
    s, norms = verification_tables(parts, agg, z, tau)
    return agg, parts, s, norms, iters


def _clip_verified_fixed(
    grads, tau, z, n_iters: int = 50, weights=None, use_pallas=False, v0=None
):
    """Fixed-budget ButterflyClip aggregation AND the Alg. 6 broadcast
    tables together (the :func:`clip_aggregate` fixed/verified branch).

    grads: (n, d); z: (n_parts, part) unit directions (from the MPRNG seed).
    Returns (agg_parts (n_parts, part), parts (n, n_parts, part),
    s (n, n_parts), norms (n, n_parts)).

    use_pallas routes through the fused one-pass-per-iteration kernel
    (kernels/centered_clip.butterfly_clip_fused_pallas): the whole robust
    aggregation plus tables costs n_iters + 2 HBM passes of the stacked
    partitions instead of 2*n_iters + 1 (see kernels/DESIGN.md).
    v0: optional (n_parts, part) warm start (previous aggregate).
    """
    n = grads.shape[0]
    parts = split_parts(grads, n)
    stacked = jnp.swapaxes(parts, 0, 1)  # (n_parts, n, part)

    if use_pallas:
        from repro.kernels.ops import butterfly_clip_fused_op

        agg, s, norms = butterfly_clip_fused_op(
            stacked, tau, z, weights, n_iters=n_iters, v0=v0
        )
        return agg, parts, s, norms

    agg = centered_clip_stacked(
        stacked, tau, n_iters=n_iters, weights=weights, v0=v0
    )
    s, norms = verification_tables(parts, agg, z, tau)
    return agg, parts, s, norms


def clip_aggregate(
    grads, tau, n_iters: int, *, z=None, adaptive_tol=None, weights=None,
    use_pallas=False, v0=None,
):
    """Unified ButterflyClip driver — the single entry the AggregatorSpec
    registry resolves to (``core.aggregators``): fixed (``adaptive_tol is
    None``) or adaptive early-exit budget, with (``z`` given) or without the
    Alg. 6 verification tables.

    Returns (agg (n_parts, part), parts (n, n_parts, part), s, norms,
    iters () i32); s/norms are None when z is None; iters is the max
    CenteredClip budget any partition ran (== n_iters on the fixed path).
    """
    if z is None:
        if adaptive_tol is not None:
            agg, parts, it = butterfly_clip_adaptive(
                grads, tau, adaptive_tol, n_iters, weights=weights,
                use_pallas=use_pallas, v0=v0,
            )
            return agg, parts, None, None, it.max().astype(jnp.int32)
        agg, parts = butterfly_clip(
            grads, tau=tau, n_iters=n_iters, weights=weights,
            use_pallas=use_pallas, v0=v0,
        )
        return agg, parts, None, None, jnp.asarray(n_iters, jnp.int32)
    if adaptive_tol is not None:
        agg, parts, s, norms, it = butterfly_clip_verified_adaptive(
            grads, tau, z, adaptive_tol, n_iters, weights=weights,
            use_pallas=use_pallas, v0=v0,
        )
        return agg, parts, s, norms, it.max().astype(jnp.int32)
    agg, parts, s, norms = _clip_verified_fixed(
        grads, tau, z, n_iters=n_iters, weights=weights,
        use_pallas=use_pallas, v0=v0,
    )
    return agg, parts, s, norms, jnp.asarray(n_iters, jnp.int32)


def butterfly_clip_verified(
    grads, tau, z, n_iters: int = 50, weights=None, use_pallas=False, v0=None
):
    """DEPRECATED shim — resolve an :class:`~repro.core.aggregators.
    AggregatorSpec` instead (``verified_aggregate``); kept so pre-spec call
    sites keep working. Same contract as :func:`_clip_verified_fixed`."""
    import warnings

    warnings.warn(
        "butterfly_clip_verified is deprecated; select the aggregation via "
        "an AggregatorSpec (repro.core.aggregators.verified_aggregate / "
        "EngineConfig.aggregator) instead",
        DeprecationWarning, stacklevel=2,
    )
    from repro.core.aggregators import AggregatorSpec, verified_aggregate

    spec = AggregatorSpec(
        "butterfly_clip",
        (("adaptive_tol", None), ("n_iters", int(n_iters)),
         ("tau", float(tau)), ("warm_start", v0 is not None)),
    )
    agg, parts, s, norms, _iters = verified_aggregate(
        spec, grads, z, weights=weights, v0=v0, use_pallas=use_pallas
    )
    return agg, parts, s, norms


def get_random_directions(seed, n_parts: int, part: int):
    """z[j] — unit vector per partition from the MPRNG seed (Alg. 1 L5).

    Every peer derives the same z from the shared scalar seed, AFTER all
    aggregation hashes are committed.
    """
    key = jax.random.key(seed) if jnp.ndim(seed) == 0 else seed
    z = jax.random.normal(key, (n_parts, part), jnp.float32)
    return z / jnp.maximum(jnp.linalg.norm(z, axis=1, keepdims=True), 1e-30)


def verification_tables(parts, agg, z, tau, use_pallas=False):
    """Broadcast tables of Alg. 6: s[i, j] = <z[j], Delta_i^j>, norm[i, j].

    parts: (n, n_parts, part); agg: (n_parts, part); z: (n_parts, part).
    use_pallas: single-HBM-pass batched kernel instead of the vmapped jnp
    path (used standalone when agg changed after the fused aggregation,
    e.g. recomputing tables against a corrupted aggregate).
    """
    if use_pallas:
        from repro.kernels.ops import verify_tables_all_op

        return verify_tables_all_op(jnp.swapaxes(parts, 0, 1), agg, z, tau)

    def per_part(xs_j, v_j, z_j):
        deltas = clip_residuals(xs_j, v_j, tau)  # (n, part)
        s_j = deltas.astype(jnp.float32) @ z_j.astype(jnp.float32)
        norms_j = jnp.linalg.norm((xs_j - v_j[None]).astype(jnp.float32), axis=1)
        return s_j, norms_j

    s, norms = jax.vmap(per_part, in_axes=(1, 0, 0), out_axes=1)(parts, agg, z)
    return s, norms  # both (n, n_parts)


def checksum_violations(s, weights, tol):
    """Verification 2 checksum: |sum_i s_i^j| per partition (Alg. 1 L14).

    Returns (sums (n_parts,), violated (n_parts,) bool).
    """
    w = s if weights is None else s * weights[:, None]
    sums = w.sum(0)
    return sums, jnp.abs(sums) > tol


def delta_max_votes(norms, weights, delta_max):
    """Verification 3: fraction of active peers whose partition residual
    exceeds Delta_max; a majority vote triggers CHECKAVERAGING(j)."""
    active = norms.shape[0] if weights is None else jnp.maximum(weights.sum(), 1.0)
    check = norms > delta_max  # (n, n_parts)
    if weights is not None:
        check = check & (weights[:, None] > 0)
    votes = check.sum(0)
    return votes, votes > active / 2.0


def checksum_offender_peers(checksums, rel: float = 1e-2):
    """Map violated Verification-2 checksums to aggregator peer ids.

    Partition j is aggregated by peer j in the butterfly topology (Alg. 2),
    so |sum_i s_i^j| above tolerance implicates peer j. The tolerance scales
    with the mean checksum magnitude (the fixed point is solved to finite
    precision). Returns a np.ndarray of offending peer indices.
    """
    cs = np.abs(np.asarray(checksums, np.float32))
    return np.nonzero(cs > rel * (1.0 + cs.mean()))[0]


def checksum_tolerance(agg, parts, rel=1e-3):
    """Numerical tolerance for the zero checksum: the fixed point is solved
    to finite precision, so scale by the residual magnitude."""
    scale = jnp.linalg.norm(parts.astype(jnp.float32), axis=-1).mean()
    return rel * jnp.maximum(scale, 1e-6)
