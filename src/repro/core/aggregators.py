"""Robust aggregation as a pluggable, declarative API.

Two layers live here:

1. The **baseline aggregator zoo** the paper compares against (§4.1):
   plain mean (All-Reduce), coordinate-wise median, geometric median
   (Weiszfeld run to eps), trimmed mean, Krum, and trusted-parameter-server
   CenteredClip. All take (n, d) stacked peer vectors -> (d,).

2. The **AggregatorSpec registry** — one declarative contract from the
   kernels to the CLI. A spec is ``name + static params + capability
   flags``; the registry resolves it to a jit/scan-safe callable of the
   uniform signature

       agg_fn(xs (n, d), weights (n,), v0 (d,) | None, key)
           -> (agg (d,), AggInfo)

   so the protocol engine (``core.engine``), the distributed launch stage
   (``launch.steps.aggregation_stage``), the trainer, the benchmarks and
   the ``--aggregator`` CLI flag all select an aggregator the same way —
   mirroring the ``lax.switch`` attack registry from ``core.attacks``.
   Unlike attacks, the spec is *static* configuration (one jit cache entry
   per spec, like ``EngineConfig``), so dispatch is resolved at trace time
   rather than via ``lax.switch``; every registered fn is pure and
   statically shaped, which is what makes the choice scan-safe.

   Capability flags drive how the rest of the stack degrades:

   * ``verifiable``  — supports the Alg. 6 broadcast tables, so the
     engine's verification/accusation/ban phases run: the ButterflyClip
     flagship (CenteredClip-residual tables) and every ``verified:<base>``
     wrapper over a coordinatewise baseline (generalized contribution
     digests — ``core.verification``); non-verifiable specs degrade those
     phases to no-ops.
   * ``weighted``    — honours the (n,) ban mask (all registered specs).
   * ``warm_startable`` — accepts ``v0`` (the previous aggregate).
   * ``adaptive``    — iteration count is data-dependent (reported via
     ``AggInfo.iters``).
   * ``coordinatewise`` — decomposes over coordinates, so the distributed
     stage may apply it per model shard; norm/distance-based fns (Krum,
     geometric median, CenteredClip) need the FULL vector and the launch
     stage joins the model shards first (``launch.steps``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.centered_clip import centered_clip_to_tol

_BIG = 1e30  # "infinite" pairwise distance for masked rows


class AggInfo(NamedTuple):
    """Uniform per-call aggregator observables (scan-stackable)."""

    iters: jnp.ndarray  # () i32 — iterations the aggregator actually ran


# ---------------------------------------------------------------------------
# Baseline aggregators (paper §4.1)
# ---------------------------------------------------------------------------
def mean_agg(xs, weights=None):
    if weights is None:
        return xs.mean(0)
    w = weights / jnp.maximum(weights.sum(), 1e-30)
    return (w[:, None] * xs).sum(0)


def coordinate_median(xs, weights=None):
    if weights is not None:
        # replace banned rows by the median of active ones via +inf trick:
        # simpler — select active rows assuming static mask in tests
        big = jnp.where(weights[:, None] > 0, xs, jnp.nan)
        return jnp.nanmedian(big, axis=0)
    return jnp.median(xs, axis=0)


def trimmed_mean(xs, trim_ratio=0.2, weights=None):
    """Coordinate-wise trimmed mean over the ACTIVE rows only.

    Banned rows (weight 0) are keyed to +inf before the sort, so they land
    past the active block and never enter the trim window — previously a
    banned Byzantine row could survive into the mean because the window was
    computed over all n rows. The trim count ``k = floor(m * trim_ratio)``
    follows the dynamic active count m, keeping the fn jit/scan-safe.
    """
    n = xs.shape[0]
    if weights is None:
        k = int(n * trim_ratio)
        s = jnp.sort(xs, axis=0)
        if k:
            s = s[k : n - k]
        return s.mean(0)
    active = weights > 0
    m = active.sum()
    k = jnp.floor(m * trim_ratio).astype(jnp.int32)
    s = jnp.sort(jnp.where(active[:, None], xs, jnp.inf), axis=0)
    idx = jnp.arange(n)[:, None]
    keep = (idx >= k) & (idx < m - k)  # only positions < m are active rows
    cnt = jnp.maximum(m - 2 * k, 1)
    return jnp.where(keep, s, 0.0).sum(0) / cnt


def geometric_median(xs, eps=1e-6, max_iters=200, weights=None,
                     return_iters=False):
    """Weiszfeld iterations to convergence."""
    n, d = xs.shape
    w0 = jnp.ones((n,)) if weights is None else weights
    v = (w0[:, None] * xs).sum(0) / jnp.maximum(w0.sum(), 1e-30)

    def cond(state):
        v, delta, it = state
        return jnp.logical_and(delta > eps, it < max_iters)

    def body(state):
        v, _, it = state
        dist = jnp.linalg.norm(xs - v[None], axis=1)
        inv = w0 / jnp.maximum(dist, 1e-12)
        v_new = (inv[:, None] * xs).sum(0) / jnp.maximum(inv.sum(), 1e-30)
        return v_new, jnp.linalg.norm(v_new - v), it + 1

    v, _, iters = jax.lax.while_loop(cond, body, (v, jnp.float32(jnp.inf), 0))
    if return_iters:
        return v, iters
    return v


def krum(xs, n_byzantine: int, weights=None):
    """Krum (Blanchard et al. 2017): pick the vector with the smallest sum of
    distances to its n - b - 2 nearest neighbours.

    Banned rows (weight 0) are masked out of the PAIRWISE distance matrix,
    not just the final scores — previously a banned colluder still served
    as a cheap nearest neighbour for its active accomplices, deflating
    their scores. Masked pairs sit at an "infinite" distance, which every
    active row pays equally when fewer than k active neighbours remain.
    """
    n = xs.shape[0]
    d2 = jnp.sum((xs[:, None, :] - xs[None, :, :]) ** 2, axis=-1)  # (n, n)
    d2 = d2 + jnp.eye(n) * _BIG
    if weights is not None:
        banned = weights <= 0
        d2 = jnp.where(banned[None, :] | banned[:, None], _BIG, d2)
    k = max(1, n - n_byzantine - 2)
    nearest = jnp.sort(d2, axis=1)[:, :k]
    scores = nearest.sum(1)
    if weights is not None:
        scores = jnp.where(weights > 0, scores, jnp.inf)
    return xs[jnp.argmin(scores)]


def ps_centered_clip(xs, tau, eps=1e-6, max_iters=200, weights=None, v0=None,
                     return_iters=False):
    """The original (trusted-parameter-server) CenteredClip baseline."""
    v, iters = centered_clip_to_tol(
        xs, tau, eps=eps, max_iters=max_iters, weights=weights, v0=v0
    )
    if return_iters:
        return v, iters
    return v


# Legacy name -> fn map (host call sites that predate the spec registry).
AGGREGATORS = {
    "mean": mean_agg,
    "coordinate_median": coordinate_median,
    "trimmed_mean": trimmed_mean,
    "geometric_median": geometric_median,
    "krum": krum,
    "centered_clip": ps_centered_clip,
}


# ---------------------------------------------------------------------------
# The AggregatorSpec registry
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AggregatorDef:
    """One registered aggregator: maker + declared static params + flags.

    ``make(n, d, use_pallas, **params) -> agg_fn`` with the uniform
    signature documented at module top. ``defaults`` declares the accepted
    static param names with their default values — ``with_defaults`` and
    the CLI only ever fill/override declared params.
    """

    name: str
    make: Callable[..., Callable]
    defaults: tuple = ()  # ((name, default), ...)
    verifiable: bool = False
    weighted: bool = True
    warm_startable: bool = False
    adaptive: bool = False
    coordinatewise: bool = False

    @property
    def param_names(self):
        return tuple(k for k, _ in self.defaults)


REGISTRY: dict[str, AggregatorDef] = {}


def register(defn: AggregatorDef):
    REGISTRY[defn.name] = defn
    return defn


def registered_aggregators():
    """Registered spec names, flagship (verifiable) first."""
    return tuple(sorted(REGISTRY, key=lambda k: (not REGISTRY[k].verifiable, k)))


def _coerce(text: str):
    """Parse a CLI param value: bool | int | float | 'none' | str."""
    low = text.lower()
    if low in ("true", "false"):
        return low == "true"
    if low in ("none", "null"):
        return None
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


@dataclass(frozen=True)
class AggregatorSpec:
    """Declarative aggregator choice: registry name + static params.

    Hashable (params are a sorted tuple of (name, value) pairs), so a spec
    can sit inside ``EngineConfig`` / jit static args — one compiled
    program per distinct spec, exactly like the rest of the config.
    """

    name: str = "butterfly_clip"
    params: tuple = ()  # ((name, value), ...)

    # -- registry resolution ------------------------------------------------
    @property
    def definition(self) -> AggregatorDef:
        try:
            return REGISTRY[self.name]
        except KeyError:
            raise ValueError(
                f"unknown aggregator {self.name!r}; registered: "
                f"{', '.join(registered_aggregators())}"
            ) from None

    @property
    def verifiable(self) -> bool:
        return self.definition.verifiable

    @property
    def weighted(self) -> bool:
        return self.definition.weighted

    @property
    def warm_startable(self) -> bool:
        return self.definition.warm_startable

    @property
    def adaptive(self) -> bool:
        return self.definition.adaptive

    @property
    def coordinatewise(self) -> bool:
        return self.definition.coordinatewise

    # -- params -------------------------------------------------------------
    def param_dict(self) -> dict:
        """Declared defaults overlaid with this spec's explicit params."""
        d = dict(self.definition.defaults)
        for k, v in self.params:
            if k not in d:
                raise ValueError(
                    f"aggregator {self.name!r} takes no param {k!r} "
                    f"(declared: {self.definition.param_names})"
                )
            d[k] = v
        return d

    def get(self, key: str, default=None):
        return self.param_dict().get(key, default)

    def _replace_params(self, updates: dict) -> "AggregatorSpec":
        merged = dict(self.params)
        merged.update(updates)
        return AggregatorSpec(self.name, tuple(sorted(merged.items())))

    def with_defaults(self, **kw) -> "AggregatorSpec":
        """Fill declared params NOT already set on this spec (engine-level
        knobs like tau/n_iters act as defaults; explicit spec params win).
        Undeclared keys are silently ignored — e.g. ``tau`` for ``mean``."""
        have = dict(self.params)
        accepted = set(self.definition.param_names)
        fill = {
            k: v for k, v in kw.items()
            if k in accepted and k not in have
        }
        return self._replace_params(fill) if fill else self

    def override(self, **kw) -> "AggregatorSpec":
        """Set declared params, overriding existing values (CLI shims)."""
        accepted = set(self.definition.param_names)
        bad = [k for k in kw if k not in accepted]
        if bad:
            raise ValueError(
                f"aggregator {self.name!r} takes no param(s) {bad} "
                f"(declared: {self.definition.param_names})"
            )
        return self._replace_params(kw)

    # -- construction / display ---------------------------------------------
    @classmethod
    def parse(cls, text: str) -> "AggregatorSpec":
        """Parse ``NAME[:k=v,...]`` (the ``--aggregator`` CLI syntax).

        ``verified:BASE[:k=v,...]`` parses the base spec and lifts it via
        the :func:`verified` combinator, so the wrapped registry names
        (``verified:mean``, ``verified:trimmed_mean``, ...) round-trip
        through ``canonical()`` like any other spec.
        ``compressed:INNER[:k=v,...]`` likewise lifts via :func:`compressed`
        (``codec=int8|bf16`` binds to the wrapper, every other param to the
        inner spec — ``core.compression``)."""
        text = text.strip()
        if text.startswith("verified:"):
            return verified(cls.parse(text[len("verified:"):]))
        if text.startswith("compressed:"):
            from repro.core import compression as _compression

            return _compression.parse_spec_text(text[len("compressed:"):])
        name, _, tail = text.partition(":")
        name = name.strip()
        spec = cls(name)
        spec.definition  # eager name validation
        params = {}
        if tail.strip():
            for item in tail.split(","):
                k, sep, v = item.partition("=")
                if not sep:
                    raise ValueError(
                        f"bad aggregator param {item!r} in {text!r} "
                        "(expected k=v)"
                    )
                params[k.strip()] = _coerce(v.strip())
        return spec.override(**params) if params else spec

    def canonical(self) -> str:
        if not self.params:
            return self.name
        tail = ",".join(f"{k}={v}" for k, v in self.params)
        return f"{self.name}:{tail}"

    def build(self, n: int, d: int, use_pallas: bool = False) -> Callable:
        """Resolve to the uniform callable
        ``agg_fn(xs, weights, v0, key) -> (agg, AggInfo)``."""
        return self.definition.make(n, d, use_pallas, **self.param_dict())


def resolve_spec(spec) -> AggregatorSpec:
    """Accept an AggregatorSpec, a ``NAME[:k=v,...]`` string, or None
    (-> the flagship ButterflyClip spec)."""
    if spec is None:
        return AggregatorSpec("butterfly_clip")
    if isinstance(spec, AggregatorSpec):
        spec.definition  # validate
        return spec
    if isinstance(spec, str):
        return AggregatorSpec.parse(spec)
    raise TypeError(f"not an aggregator spec: {spec!r}")


def verified(spec) -> AggregatorSpec:
    """Registry combinator: lift a spec into its verifiable form.

    Coordinatewise baselines (mean, trimmed_mean, coordinate_median) map to
    the ``verified:<name>`` wrapper (same params, capability flags
    recomputed: verifiable=True, warm_startable=False); already-verifiable
    specs come back unchanged; full-vector specs (krum, geometric_median,
    centered_clip) raise. Implementation: :mod:`repro.core.verification`.
    """
    from repro.core import verification as _verification

    return _verification.verified(spec)


def compressed(spec, codec: str | None = None) -> AggregatorSpec:
    """Registry combinator: wire-compress a verifiable spec's butterfly
    all-to-all payloads (``codec='int8'`` — per-partition symmetric scale,
    one f32 sidecar scalar, ≈4× fewer wire bytes — or ``'bf16'``). All
    Alg. 6 digests are computed over the dequantized-from-wire values, so
    verification stays exact (zero honest accusations is structural).
    Non-verifiable coordinatewise specs are lifted through ``verified:``
    first; full-vector specs raise. Implementation:
    :mod:`repro.core.compression`.
    """
    from repro.core import compression as _compression

    return _compression.compressed(spec, codec=codec)


def with_byzantine_default(spec: AggregatorSpec,
                           n_byzantine: int) -> AggregatorSpec:
    """Fill Krum's ``n_byzantine`` from the caller's known Byzantine count
    when the spec left it unset — the ONE place this defaulting lives
    (trainer, CLI). A spec reaching the maker with it still unset falls
    back to the max tolerable ``(n - 3) // 2``, the assumption-free bound
    for callers with no attacker count at all."""
    if spec.name == "krum" and spec.get("n_byzantine") is None:
        return spec.override(n_byzantine=int(n_byzantine))
    return spec


# ---------------------------------------------------------------------------
# Registered makers (uniform signature; static params partialed in here)
# ---------------------------------------------------------------------------
def _info(iters) -> AggInfo:
    return AggInfo(iters=jnp.asarray(iters, jnp.int32))


def _make_mean(n, d, use_pallas):
    def fn(xs, weights=None, v0=None, key=None):
        return mean_agg(xs, weights), _info(1)

    return fn


def _make_coordinate_median(n, d, use_pallas):
    def fn(xs, weights=None, v0=None, key=None):
        return coordinate_median(xs, weights), _info(1)

    return fn


def _make_trimmed_mean(n, d, use_pallas, trim_ratio=0.2):
    def fn(xs, weights=None, v0=None, key=None):
        return trimmed_mean(xs, trim_ratio=trim_ratio, weights=weights), _info(1)

    return fn


def _make_geometric_median(n, d, use_pallas, eps=1e-6, max_iters=200):
    def fn(xs, weights=None, v0=None, key=None):
        v, iters = geometric_median(
            xs, eps=eps, max_iters=max_iters, weights=weights,
            return_iters=True,
        )
        return v, _info(iters)

    return fn


def _make_krum(n, d, use_pallas, n_byzantine=None):
    if n_byzantine is None:
        # Krum's guarantee needs n >= 2b + 3; default to the max tolerable b
        n_byzantine = max(0, (n - 3) // 2)
    k_static = int(n_byzantine)

    def fn(xs, weights=None, v0=None, key=None):
        return krum(xs, n_byzantine=k_static, weights=weights), _info(1)

    return fn


def _make_ps_centered_clip(n, d, use_pallas, tau=1.0, eps=1e-6,
                           max_iters=200, warm_start=False):
    def fn(xs, weights=None, v0=None, key=None):
        v, iters = ps_centered_clip(
            xs, tau, eps=eps, max_iters=max_iters, weights=weights,
            v0=v0 if warm_start else None, return_iters=True,
        )
        return v, _info(iters)

    return fn


def _make_butterfly(n, d, use_pallas, tau=1.0, n_iters=60,
                    adaptive_tol=None, warm_start=False):
    """Flagship ButterflyClip as a FLAT aggregator (no tables): partition,
    per-partition CenteredClip (fused/adaptive Pallas kernels when
    ``use_pallas``), merge. The verifiable path with the Alg. 6 tables is
    :func:`verified_aggregate` — same spec, same params."""
    from repro.core import butterfly as bf

    def fn(xs, weights=None, v0=None, key=None):
        v0p = None
        if warm_start and v0 is not None:
            v0p = bf.split_parts(v0[None, :], n)[0]
        agg, _parts, _s, _norms, iters = bf.clip_aggregate(
            xs, tau, n_iters, adaptive_tol=adaptive_tol, weights=weights,
            use_pallas=use_pallas, v0=v0p,
        )
        return bf.merge_parts(agg, d), _info(iters)

    return fn


register(AggregatorDef(
    "mean", _make_mean,
    coordinatewise=True,
))
register(AggregatorDef(
    "coordinate_median", _make_coordinate_median,
    coordinatewise=True,
))
register(AggregatorDef(
    "trimmed_mean", _make_trimmed_mean,
    defaults=(("trim_ratio", 0.2),),
    coordinatewise=True,
))
register(AggregatorDef(
    "geometric_median", _make_geometric_median,
    defaults=(("eps", 1e-6), ("max_iters", 200)),
    adaptive=True,
))
register(AggregatorDef(
    "krum", _make_krum,
    defaults=(("n_byzantine", None),),
))
register(AggregatorDef(
    "centered_clip", _make_ps_centered_clip,
    defaults=(("tau", 1.0), ("eps", 1e-6), ("max_iters", 200),
              ("warm_start", False)),
    warm_startable=True,
    adaptive=True,
))
register(AggregatorDef(
    "butterfly_clip", _make_butterfly,
    defaults=(("tau", 1.0), ("n_iters", 60), ("adaptive_tol", None),
              ("warm_start", False)),
    verifiable=True,
    warm_startable=True,
    adaptive=True,
))

# the verified:<base> wrappers over the coordinatewise baselines register
# themselves on import (core.verification.register_verified_wrappers)
import repro.core.verification  # noqa: E402,F401  (registration side effect)


# ---------------------------------------------------------------------------
# Spec-level entry points
# ---------------------------------------------------------------------------
def aggregate(spec, xs, weights=None, v0=None, key=None, use_pallas=False):
    """Run any registered aggregator by spec: (n, d) -> ((d,), AggInfo)."""
    spec = resolve_spec(spec)
    n, d = xs.shape
    return spec.build(n, d, use_pallas=use_pallas)(xs, weights, v0, key)


def verified_aggregate(spec, grads, z, weights=None, v0=None,
                       use_pallas=False):
    """The VERIFIABLE aggregation contract: aggregation plus the Alg. 6
    broadcast tables, in the butterfly partition layout.

    grads: (n, d); z: (n_parts, part) unit directions (MPRNG seed);
    v0: optional (n_parts, part) warm start (previous aggregate;
    butterfly_clip only). Returns (agg (n_parts, part), parts
    (n, n_parts, part), s (n, n_parts), norms (n, n_parts), iters () i32).
    butterfly_clip reports the tau-clipped residual tables; ``verified:*``
    wrapped specs report the generalized contribution digests
    (``core.verification``). Raises for non-verifiable specs — callers
    degrade verification to a no-op instead (core.engine).
    """
    from repro.core import verification as _verification

    return _verification.spec_aggregate(
        resolve_spec(spec), grads, z=z, weights=weights, v0=v0,
        use_pallas=use_pallas,
    )
