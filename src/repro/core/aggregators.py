"""Baseline robust aggregators the paper compares against (§4.1):

plain mean (All-Reduce), coordinate-wise median, geometric median
(Weiszfeld run to eps), trimmed mean, Krum, and parameter-server
CenteredClip. All take (n, d) stacked peer vectors -> (d,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.centered_clip import centered_clip, centered_clip_to_tol


def mean_agg(xs, weights=None):
    if weights is None:
        return xs.mean(0)
    w = weights / jnp.maximum(weights.sum(), 1e-30)
    return (w[:, None] * xs).sum(0)


def coordinate_median(xs, weights=None):
    if weights is not None:
        # replace banned rows by the median of active ones via +inf trick:
        # simpler — select active rows assuming static mask in tests
        big = jnp.where(weights[:, None] > 0, xs, jnp.nan)
        return jnp.nanmedian(big, axis=0)
    return jnp.median(xs, axis=0)


def trimmed_mean(xs, trim_ratio=0.2, weights=None):
    n = xs.shape[0]
    k = int(n * trim_ratio)
    s = jnp.sort(xs, axis=0)
    if k:
        s = s[k : n - k]
    return s.mean(0)


def geometric_median(xs, eps=1e-6, max_iters=200, weights=None):
    """Weiszfeld iterations to convergence."""
    n, d = xs.shape
    w0 = jnp.ones((n,)) if weights is None else weights
    v = (w0[:, None] * xs).sum(0) / jnp.maximum(w0.sum(), 1e-30)

    def cond(state):
        v, delta, it = state
        return jnp.logical_and(delta > eps, it < max_iters)

    def body(state):
        v, _, it = state
        dist = jnp.linalg.norm(xs - v[None], axis=1)
        inv = w0 / jnp.maximum(dist, 1e-12)
        v_new = (inv[:, None] * xs).sum(0) / jnp.maximum(inv.sum(), 1e-30)
        return v_new, jnp.linalg.norm(v_new - v), it + 1

    v, _, _ = jax.lax.while_loop(cond, body, (v, jnp.float32(jnp.inf), 0))
    return v


def krum(xs, n_byzantine: int, weights=None):
    """Krum (Blanchard et al. 2017): pick the vector with the smallest sum of
    distances to its n - b - 2 nearest neighbours."""
    n = xs.shape[0]
    d2 = jnp.sum((xs[:, None, :] - xs[None, :, :]) ** 2, axis=-1)  # (n, n)
    d2 = d2 + jnp.eye(n) * 1e30
    k = max(1, n - n_byzantine - 2)
    nearest = jnp.sort(d2, axis=1)[:, :k]
    scores = nearest.sum(1)
    if weights is not None:
        scores = jnp.where(weights > 0, scores, jnp.inf)
    return xs[jnp.argmin(scores)]


def ps_centered_clip(xs, tau, eps=1e-6, weights=None):
    """The original (trusted-parameter-server) CenteredClip baseline."""
    v, _ = centered_clip_to_tol(xs, tau, eps=eps, weights=weights)
    return v


AGGREGATORS = {
    "mean": mean_agg,
    "coordinate_median": coordinate_median,
    "trimmed_mean": trimmed_mean,
    "geometric_median": geometric_median,
    "krum": krum,
    "centered_clip": ps_centered_clip,
}
