"""Multi-party random number generator (paper App. A.2, Blum 1983).

Commit–reveal over a simulated broadcast channel:
  1. each peer draws k random bits x_i and salt s_i,
  2. broadcasts commitment h(i || x_i || s_i)          (sha256),
  3. after ALL commitments arrive, broadcasts (x_i, s_i),
  4. everyone verifies reveals against commitments,
  5. output = XOR of all x_i.

A peer that aborts or reveals a mismatch is banned and the protocol restarts
without it — eliminating the 'learn-early-and-abort' bias (App. A.2, last
paragraph). Communication: O(1) scalars per peer per round, i.e. O(n) data —
independent of the model size d.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass


def _h(i: int, x: int, salt: bytes) -> bytes:
    return hashlib.sha256(f"{i}|{x}|".encode() + salt).digest()


@dataclass
class MPRNGPeer:
    """Honest behaviour; subclass hooks model Byzantine deviations."""

    peer_id: int
    bits: int = 63

    def draw(self, rng):
        self._x = int(rng.integers(0, 2**self.bits))
        self._salt = rng.bytes(32)

    def commit(self) -> bytes:
        return _h(self.peer_id, self._x, self._salt)

    def reveal(self, seen_reveals):
        """seen_reveals: reveals broadcast so far (rushing adversary sees
        them). Honest peers ignore them. Return None to abort."""
        return (self._x, self._salt)


@dataclass
class AbortingPeer(MPRNGPeer):
    """Byzantine: learns the XOR of everyone else first (rushing), aborts if
    the resulting output is not to its liking (here: if output would be odd).
    The protocol response is ban + restart, killing the bias."""

    def reveal(self, seen_reveals):
        others = 0
        for x, _ in seen_reveals.values():
            others ^= x
        candidate = others ^ self._x
        if candidate % 2 == 1:
            return None  # abort to force a re-roll
        return (self._x, self._salt)


@dataclass
class LyingPeer(MPRNGPeer):
    """Byzantine: reveals a different x than committed."""

    def reveal(self, seen_reveals):
        return (self._x ^ 1, self._salt)


def run_mprng(peers, rng, max_rounds: int = 10):
    """Returns (value, banned_ids, rounds). Peers are banned on abort or
    commitment mismatch; protocol restarts without them."""
    active = list(peers)
    banned = []
    for rnd in range(max_rounds):
        for p in active:
            p.draw(rng)
        commitments = {p.peer_id: p.commit() for p in active}
        reveals = {}
        bad = []
        # rushing order: byzantine peers reveal LAST and see honest reveals
        ordered = sorted(active, key=lambda p: isinstance(p, (AbortingPeer, LyingPeer)))
        for p in ordered:
            r = p.reveal(dict(reveals))
            if r is None:
                bad.append(p.peer_id)
                continue
            x, salt = r
            if _h(p.peer_id, x, salt) != commitments[p.peer_id]:
                bad.append(p.peer_id)
                continue
            reveals[p.peer_id] = (x, salt)
        if bad:
            banned.extend(bad)
            active = [p for p in active if p.peer_id not in bad]
            continue  # restart without the banned peers
        out = 0
        for x, _ in reveals.values():
            out ^= x
        return out, banned, rnd + 1
    raise RuntimeError("MPRNG failed to converge (too many byzantine aborts)")
