"""Flat-cost verification at n >~ 1000 — sampled-digest audits + the
hierarchical butterfly-of-butterflies.

Alg. 6 broadcasts O(n^2) digest scalars per step: every peer reports an
n-column (s, norm) row and receives everyone else's. Fine at n=16, but the
tables dominate wire bytes long before the internet-scale membership the
paper targets. Two composable axes shrink them, both engine- and
launch-path backed:

* **Sampled-digest auditing** (``EngineConfig.audit_k`` / ``--audit-k``):
  the m validators jointly audit only ``k_tot = m * audit_k`` digest
  COLUMNS (partitions) per step instead of all n. The sampled set is drawn
  from the step's MPRNG key with the same age + U(0,1) priority rule
  CHOOSETARGET uses for peers, so it is

  - *unpredictable* before the seed reveal — a cheater cannot steer its
    misreport into a column it knows is unsampled this step;
  - *recomputable* by every peer after the reveal — the sampled mask is a
    pure function of (key, step, col ages), so the shrunken tables stay a
    shared public object and accusations resolve exactly as before;
  - *coverage-bounded* — the top-k_tot-by-age rule guarantees every
    column's audit age stays below :func:`staleness_bound` (property-
    tested in tests/test_sampled_hier.py), so a misreport in an unsampled
    column is caught within that window, never lost.

  Broadcast rows shrink from n to k_tot scalars per table; the per-column
  zero-sum checksums (V2) run over the sampled columns, and the validator
  CHOOSETARGET audit — which targets a PEER and recomputes its full work
  from the public seed — is untouched, so time-to-ban for *gradient*
  attacks does not depend on the digest sampling at all.

* **Hierarchical butterfly-of-butterflies** (``EngineConfig.groups`` /
  ``--groups``): n peers split into g groups of gs = n/g. Level 1 runs
  the standard butterfly all-to-all INSIDE each group — payloads stay
  O(d)/peer, tables shrink to gs x gs per group. Level 2 combines the
  per-group aggregates u_a by their active-weight mean and exchanges a
  g x g digest table between group leaders. The level-2 combine is linear
  for ANY level-1 aggregator, so its zero-sum checksum is exact and
  always-on — a group whose (possibly corrupted) aggregate breaks the
  identity is flagged through its leader: bans propagate through the
  group digests. Per-peer table traffic drops O(n^2) -> O((n/g)^2 + g^2).

Both axes compose: sampling then applies within the gs-column level-1
tables. The shared analytic wire model lives in :func:`table_scalars` —
bench_overhead, bench_roofline and check_regression all price tables
through this one function.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg_mod
from repro.core import butterfly as bf
from repro.core import verification as verif_mod


# ---------------------------------------------------------------------------
# Shapes and the sampling coverage rule
# ---------------------------------------------------------------------------
def group_shape(n: int, groups: int | None) -> tuple[int, int]:
    """(g, gs) for the hierarchical topology; (1, n) when flat."""
    if groups is None or groups <= 1:
        return 1, n
    if n % groups:
        raise ValueError(
            f"groups={groups} must divide the peer count n={n} evenly"
        )
    gs = n // groups
    if gs < 2:
        raise ValueError(
            f"groups={groups} leaves group size {gs} < 2: nothing to "
            "aggregate inside a group"
        )
    return groups, gs


def sampled_k(n_cells: int, m_validators: int, audit_k: int) -> int:
    """Digest columns audited per step: m validators x k columns each,
    capped at the column count (full tables when the budget covers them)."""
    return int(min(max(1, m_validators) * max(1, audit_k), n_cells))


def staleness_bound(n_cells: int, m_validators: int, audit_k: int) -> int:
    """Upper bound on any digest column's audit age under the
    top-k_tot-by-(age + U(0,1)) rule.

    A column of age a outranks every column of age <= a - 2 (scores are
    age + U(0,1) with U < 1), so while a column waits, each step's k_tot
    samples go to columns that were last audited no later than one step
    after it — effectively distinct columns. Pigeonhole over the other
    n_cells - 1 columns bounds the wait at ceil(n_cells / k_tot) + 2
    steps; the property test (tests/test_sampled_hier.py) exercises the
    realized ages against this bound over long runs.
    """
    k_tot = sampled_k(n_cells, m_validators, audit_k)
    return math.ceil(n_cells / k_tot) + 2


def sample_audit_cells(key, step, col_checked, m_validators: int,
                       audit_k: int, n_cells: int):
    """The step's public sampled digest-column set.

    Same priority rule as the engine's CHOOSETARGET: score every column by
    audit age (steps since last sampled, from the ``col_checked`` ledger)
    plus fresh U(0,1) jitter from the step key, take the top k_tot. Age
    dominance gives the bounded-staleness guarantee; the jitter keeps the
    within-bound order unpredictable before the seed reveal.

    Returns (idx (k_tot,) i32 sampled column ids, mask (n_cells,) bool).
    """
    k_tot = sampled_k(n_cells, m_validators, audit_k)
    u = jax.random.uniform(key, (n_cells,))
    age = (step - col_checked).astype(jnp.float32)
    order = jnp.argsort(-(age + u))
    idx = order[:k_tot].astype(jnp.int32)
    mask = jnp.zeros((n_cells,), bool).at[idx].set(True)
    return idx, mask


# ---------------------------------------------------------------------------
# The analytic per-peer table wire model (single source of truth)
# ---------------------------------------------------------------------------
def table_scalars(n: int, *, m_validators: int = 1,
                  audit_k: int | None = None,
                  groups: int | None = None) -> int:
    """Verification-table scalars RECEIVED per peer per step.

    Full Alg. 6: every peer receives n rows x n columns of (s, norm) pairs
    plus 3 per-owner sidecar scalars (checksum, Delta_max vote, clip
    iters) -> 2 n^2 + 3 n (exactly ``bench_overhead.comm_model``'s
    btard_extra term). Sampling shrinks the column count of each received
    row to k_tot; hierarchy shrinks the row/column space to the gs-peer
    group and adds the level-2 leader exchange (2 g^2 + 3 g, priced at the
    leader — the worst-case peer).
    """
    g, gs = group_shape(n, groups)
    k_tot = None if audit_k is None else sampled_k(n, m_validators, audit_k)
    cols = gs if k_tot is None else min(k_tot, gs)
    scalars = 2 * gs * cols + 3 * gs
    if g > 1:
        scalars += 2 * g * g + 3 * g
    return scalars


def table_bytes(n: int, *, m_validators: int = 1, audit_k: int | None = None,
                groups: int | None = None, bytes_per: int = 4) -> int:
    """Per-peer verification-table bytes per step (f32 scalars by default)."""
    return table_scalars(
        n, m_validators=m_validators, audit_k=audit_k, groups=groups
    ) * bytes_per


# ---------------------------------------------------------------------------
# Two-level aggregation (engine path)
# ---------------------------------------------------------------------------
class HierAggregate(NamedTuple):
    """Level-1 (within-group) aggregation results."""

    u: jnp.ndarray  # (g, gs, part1) per-group aggregates, butterfly layout
    parts1: jnp.ndarray  # (g, gs, gs, part1) within-group contributions
    z1: jnp.ndarray  # (gs, part1) level-1 directions (shared across groups)
    s1: jnp.ndarray | None  # (g, gs, gs) level-1 digest tables
    norms1: jnp.ndarray | None  # (g, gs, gs)
    group_w: jnp.ndarray  # (g,) level-2 combine weights (group active mass)
    iters: jnp.ndarray  # () i32 — max level-1 iterations over the groups


class Level2(NamedTuple):
    """Level-2 (leader butterfly) combine + digest exchange."""

    v2: jnp.ndarray  # (g, part2) global aggregate in the leader layout
    parts2: jnp.ndarray  # (g, g, part2) per-group contributions to level 2
    z2: jnp.ndarray  # (g, part2)
    s2: jnp.ndarray  # (g, g) level-2 digests
    norms2: jnp.ndarray  # (g, g)


def hier_aggregate(spec, grads, weights, seed, groups: int,
                   v0_flat=None, with_tables: bool = True,
                   use_pallas: bool = False) -> HierAggregate:
    """Level-1 aggregation: each group of gs peers runs the full verifiable
    spec over its own butterfly (gs partitions of the whole d).

    grads (n, d); weights (n,) — already validator/ban masked; seed the
    step's MPRNG output; v0_flat optional (d,) warm start shared by every
    group (the previous GLOBAL aggregate — groups see iid shards of the
    same distribution, so it seeds all of them). ``with_tables=False``
    skips the digest pass (the aggregator-attack path recomputes tables
    against the corrupted aggregate via :func:`hier_tables` instead).

    Per-group weights differ, so the shared-weight fused kernels do not
    apply under vmap — level-1 runs the jnp path regardless of
    ``use_pallas`` (group sizes are small by construction; the kernel win
    lives in the flat/sampled digest passes).
    """
    spec = agg_mod.resolve_spec(spec)
    n, d = grads.shape
    g, gs = group_shape(n, groups)
    part1 = bf.pad_to_parts(d, gs) // gs
    z1 = bf.get_random_directions(seed, gs, part1) if with_tables else None
    v0_1 = None
    if v0_flat is not None:
        v0_1 = bf.split_parts(v0_flat[None, :], gs)[0]  # (gs, part1)

    def per_group(G_a, w_a):
        return verif_mod.spec_aggregate(
            spec, G_a, z=z1, weights=w_a, v0=v0_1, use_pallas=False,
        )

    u, parts1, s1, norms1, iters = jax.vmap(per_group)(
        grads.reshape(g, gs, d), weights.reshape(g, gs)
    )
    if z1 is None:
        z1 = bf.get_random_directions(seed, gs, part1)
    return HierAggregate(
        u=u, parts1=parts1, z1=z1, s1=s1, norms1=norms1,
        group_w=weights.reshape(g, gs).sum(axis=1),
        iters=iters.max().astype(jnp.int32),
    )


def hier_tables(spec, parts1, u, z1, use_pallas: bool = False):
    """Level-1 tables against a GIVEN (possibly corrupted) per-group
    aggregate — the hierarchical sibling of ``verification.spec_tables``.
    parts1 (g, gs, gs, part1); u (g, gs, part1). Returns (s1, norms1),
    both (g, gs, gs)."""
    spec = agg_mod.resolve_spec(spec)
    return jax.vmap(
        lambda p, v: verif_mod.spec_tables(spec, p, v, z1, use_pallas=False)
    )(parts1, u)


def level2_combine(u, group_w, d: int, seed) -> Level2:
    """The leader butterfly: combine the g per-group aggregates by their
    active-weight mean and digest every group's contribution against the
    result.

    The combine is LINEAR whatever aggregated level 1, so the level-2
    zero-sum checksum sum_a W_a s2[a, b] ~= 0 holds exactly — it is
    always-on (even for wrapped nonlinear level-1 specs) and a corrupted
    group aggregate that survives level-1 masking breaks it at the
    violated super-partition's leader: bans propagate through the group
    digests. z2 derives from the same revealed seed as z1 (distinct fold).
    """
    g = u.shape[0]
    u_flat = jax.vmap(lambda a: bf.merge_parts(a, d))(u)  # (g, d)
    parts2 = bf.split_parts(u_flat, g)  # (g, g, part2)
    w = jnp.maximum(group_w.astype(jnp.float32), 0.0)
    v2 = (parts2 * w[:, None, None]).sum(0) / jnp.maximum(w.sum(), 1e-30)
    z2 = bf.get_random_directions(seed + 1, g, parts2.shape[-1])
    s2, norms2 = verif_mod.digest_tables(parts2, v2, z2)
    return Level2(v2=v2, parts2=parts2, z2=z2, s2=s2, norms2=norms2)
