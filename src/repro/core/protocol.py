"""BTARD host-level protocol state machine (paper Alg. 4–7).

This is the faithful protocol simulation: sha256 gradient commitments,
MPRNG commit/reveal for the shared seed, broadcast tables of s / norm
scalars, Verifications 1–3, ACCUSE (recompute & ban, Alg. 4) and ELIMINATE
(mutual ban), random validator election, and deterministic ban ordering
(sorted accusations — App. D.3).

The numeric aggregation itself (CenteredClip over butterfly partitions) runs
on device via repro.core.butterfly; everything a real deployment would do in
host-side RPC / crypto land lives here in plain Python over a simulated
consistent broadcast channel.
"""
from __future__ import annotations

import functools
import hashlib
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks as attacks_mod
from repro.core import butterfly as bf
from repro.core.centered_clip import centered_clip
from repro.core.mprng import MPRNGPeer, run_mprng


def grad_hash(g: np.ndarray) -> bytes:
    return hashlib.sha256(np.ascontiguousarray(g, np.float32).tobytes()).digest()


@dataclass
class AttackConfig:
    kind: str = "none"  # see core.attacks.GRADIENT_ATTACKS
    start_step: int = 0
    end_step: int = 10**9
    lam: float = 1000.0
    delay: int = 1000
    aggregator_attack: bool = False
    aggregator_scale: float = 0.0  # shift magnitude per corrupted partition
    misreport_s: bool = True  # colluders cancel the Verification-2 checksum
    false_accuse: bool = False  # byz validators slander honest peers
    mprng_abort: bool = False  # byz peers try the abort-bias on MPRNG


@dataclass
class StepInfo:
    step: int
    banned_now: list = field(default_factory=list)
    accusations: list = field(default_factory=list)
    checksum_violations: int = 0
    check_averaging: int = 0
    validators: list = field(default_factory=list)
    n_active: int = 0
    seed: int = 0


class BTARDProtocol:
    """One instance simulates all peers plus the broadcast channel.

    grad_fn(peer_id, step, params, flipped) -> np.ndarray (d,)
        Deterministic given (peer_id, step): the paper's public minibatch
        seed xi_i^t, so any peer can recompute any other's gradient.
    """

    def __init__(
        self,
        n_peers: int,
        d: int,
        grad_fn,
        byzantine: set,
        attack: AttackConfig | None = None,
        tau: float = 1.0,
        clip_iters: int = 60,
        m_validators: int = 1,
        delta_max: float | None = None,
        clip_lambda: float | None = None,  # BTARD-Clipped-SGD peer-side clip
        seed: int = 0,
        use_pallas: bool = False,
    ):
        self.n = n_peers
        self.d = d
        self.grad_fn = grad_fn
        self.byzantine = set(byzantine)
        self.attack = attack or AttackConfig()
        self.tau = tau
        self.clip_iters = clip_iters
        self.m = m_validators
        self.delta_max = delta_max
        self.clip_lambda = clip_lambda
        self.use_pallas = use_pallas
        self.rng = np.random.default_rng(seed)
        self.banned: set = set()
        self.validators: list = []  # C_k — chosen at the END of step k-1
        self._delay_buf: dict = {}
        self._jit_bclip = jax.jit(
            lambda g, w: bf.butterfly_clip(
                g, tau=self.tau, n_iters=self.clip_iters, weights=w
            )
        )
        self._jit_tables = jax.jit(
            functools.partial(bf.verification_tables, use_pallas=use_pallas)
        )
        # fused path: aggregation + broadcast tables in ONE kernel launch of
        # n_iters + 2 HBM passes (vs the two jitted calls above)
        self._jit_fused = jax.jit(
            lambda g, z, w: bf.butterfly_clip_verified(
                g, tau=self.tau, z=z, n_iters=self.clip_iters, weights=w,
                use_pallas=True,
            )
        )

    # ------------------------------------------------------------------
    def active_peers(self):
        return [i for i in range(self.n) if i not in self.banned]

    def _is_attacking(self, t):
        a = self.attack
        any_attack = (
            a.kind != "none" or a.aggregator_attack or a.false_accuse or a.mprng_abort
        )
        return any_attack and a.start_step <= t < a.end_step

    # ------------------------------------------------------------------
    def _compute_peer_grads(self, params, t, active):
        """Step 1–2: everyone computes gradients from public seeds; Byzantine
        peers substitute their attack vectors (and commit to THOSE — an
        inconsistent commitment would be an instant ELIMINATE)."""
        flip = self._is_attacking(t) and self.attack.kind == "label_flip"
        grads, honest = [], []
        for i in active:
            flipped = flip and i in self.byzantine
            g = np.asarray(self.grad_fn(i, t, params, flipped), np.float32)
            grads.append(g)
            # a validator recomputing from the PUBLIC seed gets true labels:
            honest.append(
                np.asarray(self.grad_fn(i, t, params, False), np.float32)
                if flipped
                else g
            )
        G = np.stack(grads)  # (n_active, d)
        honest_G = np.stack(honest)

        if self._is_attacking(t):
            byz_mask = np.array([i in self.byzantine for i in active])
            kind = self.attack.kind
            if kind in attacks_mod.NEEDS_DELAY_BUFFER:
                delayed = np.stack(
                    [
                        self._delay_buf.get(
                            (i, t - self.attack.delay),
                            np.zeros(self.d, np.float32),
                        )
                        for i in active
                    ]
                )
                G = np.asarray(
                    attacks_mod.delayed_gradient(
                        jnp.asarray(G), jnp.asarray(byz_mask), delayed=jnp.asarray(delayed)
                    )
                )
            elif kind != "label_flip":
                fn = attacks_mod.GRADIENT_ATTACKS[kind]
                G = np.asarray(
                    fn(
                        jnp.asarray(G),
                        jnp.asarray(byz_mask),
                        key=jax.random.key(t),
                        lam=self.attack.lam,
                    )
                )
        # history for the delayed attack
        for idx, i in enumerate(active):
            if i in self.byzantine:
                self._delay_buf[(i, t)] = honest_G[idx]
        # drop old history
        for key in [k for k in self._delay_buf if k[1] < t - self.attack.delay - 2]:
            del self._delay_buf[key]
        return G, honest_G

    # ------------------------------------------------------------------
    def _mprng_phase(self, t, active, info):
        """MPRNG commit/reveal for the shared seed; bans aborters."""
        peers = [MPRNGPeer(i) for i in active]
        if self.attack.mprng_abort and self._is_attacking(t):
            from repro.core.mprng import AbortingPeer

            peers = [
                AbortingPeer(i) if i in self.byzantine else MPRNGPeer(i)
                for i in active
            ]
        seed, mprng_banned, _ = run_mprng(peers, self.rng)
        for i in mprng_banned:
            self._ban(i, info, "mprng abort/mismatch")
        info.seed = seed % (2**31)

    def _aggregator_attack(self, t, active, agg):
        """Byzantine aggregators corrupt their partitions in place. Returns
        the list of corrupted partition indices."""
        corrupted_parts = []
        if self._is_attacking(t) and self.attack.aggregator_attack:
            for j_idx, j in enumerate(active):
                if j in self.byzantine and self.attack.aggregator_scale > 0:
                    noise = self.rng.normal(size=agg.shape[1]).astype(np.float32)
                    noise /= max(np.linalg.norm(noise), 1e-30)
                    agg[j_idx] = agg[j_idx] + self.attack.aggregator_scale * noise
                    corrupted_parts.append(j_idx)
        return corrupted_parts

    def _corrupt_and_hash(self, t, active, agg, parts):
        """Shared post-aggregation sequence of both paths: writable copies,
        the aggregator attack, then the broadcast hashes of the (possibly
        corrupted) aggregation results."""
        agg = np.array(agg)  # writable copy
        parts_np = np.asarray(parts)
        honest_agg = agg.copy()
        corrupted_parts = self._aggregator_attack(t, active, agg)
        agg_hashes = {active[j]: grad_hash(agg[j]) for j in range(len(active))}
        return agg, parts_np, honest_agg, corrupted_parts, agg_hashes

    # ------------------------------------------------------------------
    def step(self, params, t):
        """One BTARD-SGD aggregation round. Returns (g_hat (d,), StepInfo)."""
        info = StepInfo(step=t)
        active = self.active_peers()
        n_act = len(active)
        info.n_active = n_act
        validators = [v for v in self.validators if v not in self.banned]
        info.validators = list(validators)
        # weight 0 for this step's validators (they validate instead — Alg. 1 L19)
        weights = np.array(
            [0.0 if i in validators else 1.0 for i in active], np.float32
        )

        G, honest_G = self._compute_peer_grads(params, t, active)
        G = np.array(G)  # ensure writable (attack outputs are jax views)
        honest_G = np.array(honest_G)
        if self.clip_lambda is not None:  # BTARD-Clipped-SGD (Alg. 9, honest peers)
            for idx, i in enumerate(active):
                if i not in self.byzantine:
                    nrm = np.linalg.norm(G[idx])
                    G[idx] *= min(1.0, self.clip_lambda / max(nrm, 1e-30))
                    honest_G[idx] = G[idx]

        # ---- commitments (broadcast BEFORE any aggregation data flows) ----
        commitments = {i: grad_hash(G[idx]) for idx, i in enumerate(active)}

        if self.use_pallas:
            # Fused path (kernels/DESIGN.md): the MPRNG commit/reveal runs
            # first so z is available to the fused kernel, which then emits
            # the aggregate AND the broadcast tables from one pallas_call of
            # n_iters + 2 HBM passes. On the wire z is revealed only after
            # the aggregate hashes are committed; the simulated attackers are
            # scripted and never adapt to z, and the MPRNG output does not
            # depend on the aggregate, so the reorder is behaviorally
            # identical (the host rng draw order differs from the two-call
            # path only when aggregator_attack also draws from it).
            self._mprng_phase(t, active, info)
            part = bf.pad_to_parts(self.d, n_act) // n_act
            z = np.asarray(bf.get_random_directions(info.seed, n_act, part))
            agg, parts, s_tbl, norm_tbl = self._jit_fused(
                jnp.asarray(G), jnp.asarray(z), jnp.asarray(weights)
            )
            agg, parts_np, honest_agg, corrupted_parts, agg_hashes = (
                self._corrupt_and_hash(t, active, agg, parts)
            )
            if corrupted_parts:
                # honest peers received the CORRUPTED aggregate, so their
                # reported tables are computed against it — one standalone
                # table pass, paid only on attacked steps
                s_tbl, norm_tbl = self._jit_tables(
                    jnp.asarray(parts_np), jnp.asarray(agg), jnp.asarray(z),
                    self.tau,
                )
        else:
            # ---- butterfly exchange + per-partition CenteredClip, then the
            # hash of aggregation results, broadcast BEFORE z is known ------
            agg, parts = self._jit_bclip(jnp.asarray(G), jnp.asarray(weights))
            agg, parts_np, honest_agg, corrupted_parts, agg_hashes = (
                self._corrupt_and_hash(t, active, agg, parts)
            )

            # ---- MPRNG: shared seed (commit/reveal) ------------------------
            self._mprng_phase(t, active, info)
            z = np.asarray(
                bf.get_random_directions(info.seed, agg.shape[0], agg.shape[1])
            )

            # ---- broadcast tables s_i^j, norm_ij ---------------------------
            s_tbl, norm_tbl = self._jit_tables(
                jnp.asarray(parts_np), jnp.asarray(agg), jnp.asarray(z), self.tau
            )
        s_tbl = np.asarray(s_tbl).copy()  # (n_act, n_parts)
        norm_tbl = np.asarray(norm_tbl).copy()
        true_s = s_tbl.copy()
        true_norm = norm_tbl.copy()

        # colluders cancel the checksum for corrupted partitions (App. C:
        # "Byzantines can misreport s_i^j such that sum_i s_i^j = 0")
        misreporters = []
        if corrupted_parts and self.attack.misreport_s:
            byz_rows = [
                idx for idx, i in enumerate(active) if i in self.byzantine
            ]
            for j_idx in corrupted_parts:
                liar = byz_rows[0]
                others = (s_tbl[:, j_idx] * weights).sum() - s_tbl[liar, j_idx] * weights[liar]
                if weights[liar] > 0:
                    s_tbl[liar, j_idx] = -others / weights[liar]
                    misreporters.append((active[liar], active[j_idx]))

        # ---- Verifications --------------------------------------------------
        accusations = []  # (accuser, target, reason)

        # V1: each aggregator j can verify everyone's norm for its partition
        for j_idx, j in enumerate(active):
            if j in self.byzantine:
                continue  # byzantine aggregators stay silent
            bad = np.nonzero(
                np.abs(norm_tbl[:, j_idx] - true_norm[:, j_idx])
                > 1e-4 * (1.0 + true_norm[:, j_idx])
            )[0]
            for i_idx in bad:
                accusations.append((j, active[i_idx], "norm mismatch (V1)"))

        # V2a: each aggregator j verifies everyone's s for its partition
        for j_idx, j in enumerate(active):
            if j in self.byzantine:
                continue
            bad = np.nonzero(
                np.abs(s_tbl[:, j_idx] - true_s[:, j_idx])
                > 1e-4 * (1.0 + np.abs(true_s[:, j_idx]))
            )[0]
            for i_idx in bad:
                accusations.append((j, active[i_idx], "s mismatch (V2)"))

        # V2b: global checksum per partition
        tol = float(
            bf.checksum_tolerance(jnp.asarray(agg), jnp.asarray(parts_np))
        )
        sums = (s_tbl * weights[:, None]).sum(0)
        for j_idx in np.nonzero(np.abs(sums) > tol)[0]:
            info.checksum_violations += 1
            accusations.append((None, active[j_idx], "checksum != 0 (V2)"))

        # V3: Delta_max majority vote -> CHECKAVERAGING
        if self.delta_max is not None:
            votes = ((true_norm > self.delta_max) * weights[:, None]).sum(0)
            for j_idx in np.nonzero(votes > weights.sum() / 2.0)[0]:
                info.check_averaging += 1
                accusations.append(
                    (None, active[j_idx], "Delta_max majority (V3)")
                )

        # ---- validator checks (C_k elected by last step's MPRNG) ------------
        targets = self._choose_targets(info.seed - 1, active, validators)
        for v, u in targets.items():
            if v in self.byzantine:
                if self._is_attacking(t) and self.attack.false_accuse:
                    accusations.append((v, u, "false accusation"))
                continue  # silent byzantine validator
            u_idx = active.index(u)
            honest = honest_G[u_idx]
            if grad_hash(G[u_idx]) != grad_hash(honest) or not np.allclose(
                G[u_idx], honest
            ):
                accusations.append((v, u, "gradient hash mismatch (validator)"))
            elif np.abs(s_tbl[u_idx] - true_s[u_idx]).max() > 1e-4 * (
                1.0 + np.abs(true_s[u_idx]).max()
            ):
                accusations.append((v, u, "s mismatch (validator)"))

        # ---- ACCUSE resolution (deterministic order, App. D.3) --------------
        for accuser, target, reason in sorted(
            accusations, key=lambda a: (a[1], -1 if a[0] is None else a[0], a[2])
        ):
            if target in self.banned or (accuser is not None and accuser in self.banned):
                continue
            guilty = self._resolve_accusation(
                accuser, target, reason, active, G, honest_G,
                agg, honest_agg, s_tbl, true_s, norm_tbl, true_norm,
            )
            info.accusations.append((accuser, target, reason, guilty))
            for g in guilty:
                self._ban(g, info, reason)

        # ---- elect next validators ------------------------------------------
        self.validators = self._elect_validators(info.seed, self.active_peers())

        g_hat = bf.merge_parts(jnp.asarray(agg), self.d)
        return np.asarray(g_hat), info

    # ------------------------------------------------------------------
    def _resolve_accusation(
        self, accuser, target, reason, active, G, honest_G,
        agg, honest_agg, s_tbl, true_s, norm_tbl, true_norm,
    ):
        """ACCUSE (Alg. 4): everyone recomputes the target's work from the
        public seed. Returns the set of peers proven guilty (the target if
        the accusation holds, else the accuser). A false accusation bans the
        accuser (Hammurabi rule)."""
        t_idx = active.index(target)
        guilty = set()
        target_cheated = (
            not np.allclose(G[t_idx], honest_G[t_idx])  # gradient attack
            or not np.allclose(s_tbl[t_idx], true_s[t_idx], atol=1e-5, rtol=1e-3)
            or not np.allclose(norm_tbl[t_idx], true_norm[t_idx], atol=1e-5, rtol=1e-3)
            or not np.allclose(agg[t_idx], honest_agg[t_idx])  # aggregation attack
        )
        if target_cheated:
            guilty.add(target)
            # "and everyone who covered it up" (Alg. 4 L11-13): peers whose
            # reported s for the corrupted partition mismatches their true s
            liars = np.nonzero(
                np.abs(s_tbl[:, t_idx] - true_s[:, t_idx])
                > 1e-4 * (1.0 + np.abs(true_s[:, t_idx]))
            )[0]
            for l_idx in liars:
                guilty.add(active[l_idx])
        elif accuser is not None:
            guilty.add(accuser)
        return guilty

    def _ban(self, peer, info, reason):
        if peer not in self.banned:
            self.banned.add(peer)
            info.banned_now.append((peer, reason))

    # ------------------------------------------------------------------
    def _elect_validators(self, seed, active):
        if not active or self.m == 0:
            return []
        r = np.random.default_rng(seed & 0x7FFFFFFF)
        m = min(self.m, max(0, len(active) - 1))
        return list(r.choice(active, size=m, replace=False))

    def _choose_targets(self, seed, active, validators):
        """CHOOSETARGET(r, i): each validator checks one non-validator."""
        cands = [i for i in active if i not in validators]
        if not cands:
            return {}
        r = np.random.default_rng((seed + 12345) & 0x7FFFFFFF)
        out = {}
        for v in validators:
            out[v] = int(r.choice(cands))
        return out
