"""BTARD host-level protocol API (paper Alg. 4-7) — thin wrapper over the
jit/scan engine in :mod:`repro.core.engine`.

Historically this module WAS the protocol: a ~170-line host-side numpy loop
per step (sha256 commitments, MPRNG objects, python accusation lists) that
round-tripped device arrays every phase. The state machine now lives in
``engine.protocol_step`` as pure functions over a ``ProtocolState`` pytree;
this wrapper keeps the legacy object API on top of it:

* arbitrary host-side ``grad_fn(peer, step, params, flipped)`` support
  (the engine itself takes the stacked (n, d) gradient matrices);
* the ``StepInfo`` / ``banned`` / ``validators`` bookkeeping, mirrored from
  the state pytree after each jitted step.

Because the wrapper and ``engine.scan_protocol`` call the *same* step
function with the same PRNG chain, a scanned N-step run and N ``step()``
calls produce identical bans, accusations and aggregates — property-tested
in ``tests/test_engine.py``. The host crypto simulation (sha256 grad_hash,
commit/reveal MPRNG) remains available in :mod:`repro.core.mprng` and the
``grad_hash`` helper below; the engine models both by their numeric
outcome (see engine module docstring).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng


def grad_hash(g: np.ndarray) -> bytes:
    return hashlib.sha256(np.ascontiguousarray(g, np.float32).tobytes()).digest()


@dataclass
class AttackConfig:
    kind: str = "none"  # see core.attacks.ATTACK_NAMES
    start_step: int = 0
    end_step: int = 10**9
    lam: float = 1000.0
    delay: int = 1000
    aggregator_attack: bool = False
    aggregator_scale: float = 0.0  # shift magnitude per corrupted partition
    misreport_s: bool = True  # colluders cancel the Verification-2 checksum
    false_accuse: bool = False  # byz validators slander honest peers
    mprng_abort: bool = False  # byz peers try the abort-bias on MPRNG


@dataclass
class StepInfo:
    step: int
    banned_now: list = field(default_factory=list)
    accusations: list = field(default_factory=list)
    checksum_violations: int = 0
    check_averaging: int = 0
    validators: list = field(default_factory=list)
    n_active: int = 0
    seed: int = 0


class BTARDProtocol:
    """One instance simulates all peers plus the broadcast channel.

    grad_fn(peer_id, step, params, flipped) -> np.ndarray (d,)
        Deterministic given (peer_id, step): the paper's public minibatch
        seed xi_i^t, so any peer can recompute any other's gradient.

    All numerics run through ``engine.protocol_step`` (one jitted call per
    step); this object only computes host gradients and mirrors the state.
    """

    def __init__(
        self,
        n_peers: int,
        d: int,
        grad_fn,
        byzantine: set,
        attack: AttackConfig | None = None,
        tau: float = 1.0,
        clip_iters: int = 60,
        m_validators: int = 1,
        delta_max: float | None = None,
        clip_lambda: float | None = None,  # BTARD-Clipped-SGD peer-side clip
        seed: int = 0,
        use_pallas: bool = False,
        warm_start: bool = False,
        adaptive_tol: float | None = None,
        aggregator=None,  # AggregatorSpec | "name[:k=v,...]" | None (butterfly)
    ):
        self.n = n_peers
        self.d = d
        self.grad_fn = grad_fn
        self.byzantine = set(byzantine)
        self.attack = attack or AttackConfig()
        self.tau = tau
        self.clip_iters = clip_iters
        self.m = m_validators
        self.delta_max = delta_max
        self.clip_lambda = clip_lambda
        self.use_pallas = use_pallas

        self.engine_config = eng.config_from_attack(
            n_peers,
            d,
            self.attack,
            tau=tau,
            clip_iters=clip_iters,
            m_validators=m_validators,
            delta_max=delta_max,
            clip_lambda=clip_lambda,
            use_pallas=use_pallas,
            warm_start=warm_start,
            adaptive_tol=adaptive_tol,
            aggregator=aggregator,
        )
        self.byz_mask = jnp.asarray(
            [1.0 if i in self.byzantine else 0.0 for i in range(n_peers)],
            jnp.float32,
        )
        self.state = eng.init_state(self.engine_config, seed=seed)
        self._step_fn = eng.jit_protocol_step(self.engine_config)
        # host mirrors of the state pytree (legacy API)
        self.banned: set = set()
        self.validators: list = self._mask_to_list(self.state.validator)

    # ------------------------------------------------------------------
    @staticmethod
    def _mask_to_list(mask):
        return [int(i) for i in np.nonzero(np.asarray(mask) > 0)[0]]

    def active_peers(self):
        return [i for i in range(self.n) if i not in self.banned]

    def _is_attacking(self, t):
        a = self.attack
        any_attack = (
            a.kind != "none" or a.aggregator_attack or a.false_accuse or a.mprng_abort
        )
        return any_attack and a.start_step <= t < a.end_step

    # ------------------------------------------------------------------
    def _compute_peer_grads(self, params, t, active):
        """Step 1-2: everyone computes gradients from public seeds. The
        Byzantine substitutions happen on device (engine apply_attack) —
        only LABEL FLIP needs the loss, so it is resolved here."""
        flip = self._is_attacking(t) and self.attack.kind == "label_flip"
        G = np.zeros((self.n, self.d), np.float32)
        honest_G = np.zeros((self.n, self.d), np.float32)
        for i in active:
            flipped = flip and i in self.byzantine
            g = np.asarray(self.grad_fn(i, t, params, flipped), np.float32)
            G[i] = g
            # a validator recomputing from the PUBLIC seed gets true labels:
            honest_G[i] = (
                np.asarray(self.grad_fn(i, t, params, False), np.float32)
                if flipped
                else g
            )
        return G, honest_G

    def _mirror(self, out: eng.StepOutputs, info: StepInfo):
        """Copy the step's engine outputs into the legacy bookkeeping."""
        banned_now = np.asarray(out.banned_now)
        reasons = np.asarray(out.ban_reason_now)
        for i in np.nonzero(banned_now)[0]:
            peer = int(i)
            if peer not in self.banned:
                self.banned.add(peer)
                info.banned_now.append(
                    (peer, eng.BAN_REASON_NAMES[int(reasons[i])])
                )
        acc = np.asarray(out.accuse_mat)
        sys_acc = np.asarray(out.sys_accuse)
        cheated = np.asarray(out.cheated)
        for v, u in zip(*np.nonzero(acc)):
            guilty = [int(u)] if cheated[u] else [int(v)]
            info.accusations.append(
                (int(v), int(u), "engine accusation", guilty)
            )
        for j in np.nonzero(sys_acc)[0]:
            guilty = [int(j)] if cheated[j] else []
            info.accusations.append(
                (None, int(j), "checksum/Delta_max (V2/V3)", guilty)
            )
        info.checksum_violations = int(out.checksum_violations)
        info.check_averaging = int(out.check_averaging)
        info.seed = int(out.seed)
        info.n_active = int(out.n_active)
        info.validators = self._mask_to_list(out.validators)
        self.validators = self._mask_to_list(self.state.validator)

    # ------------------------------------------------------------------
    def step(self, params, t):
        """One BTARD-SGD aggregation round. Returns (g_hat (d,), StepInfo)."""
        info = StepInfo(step=t)
        if int(self.state.step) != t:
            # honour the caller's step index (attack windows, PRNG chain)
            self.state = self.state._replace(step=jnp.asarray(t, jnp.int32))
        active = self.active_peers()
        G, honest_G = self._compute_peer_grads(params, t, active)
        self.state, out = self._step_fn(
            self.state, self.byz_mask, jnp.asarray(G), jnp.asarray(honest_G)
        )
        self._mirror(out, info)
        return np.asarray(out.g_hat), info
