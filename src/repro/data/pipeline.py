"""Deterministic public-seed data pipeline.

BTARD's security model (paper §3, footnote 2) requires PUBLIC data: every
peer samples minibatches from the full dataset via publicly known seeds
xi_i^t, so validators can recompute anyone's gradients bit-exactly. Here the
"dataset" is a deterministic synthetic generator:

* token streams with learnable structure (noisy affine bigram process) for
  LM training — loss demonstrably decreases;
* gaussian-mixture classification batches for the §4.1-style controlled
  Byzantine experiments;
* frame/patch embedding stubs for the audio/VLM modality frontends.

``peer_seed(global_seed, step, peer)`` is the paper's xi_i^t.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def peer_seed(global_seed: int, step: int, peer: int) -> int:
    """xi_i^t — publicly derivable, collision-free peer/step seed."""
    return (global_seed * 1_000_003 + step * 4099 + peer) % (2**31 - 1)


class TokenPipeline:
    """Synthetic LM stream: x_{t+1} = (a*x_t + c) mod V with prob (1-noise),
    else uniform. A model that learns the affine map drops well below
    uniform cross-entropy."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 a: int = 5, c: int = 7, noise: float = 0.2, global_seed: int = 0):
        self.V = vocab_size
        self.S = seq_len
        self.B = batch_size
        self.a, self.c, self.noise = a, c, noise
        self.global_seed = global_seed

    def _gen(self, key, batch):
        k0, k1, k2 = jax.random.split(key, 3)
        x0 = jax.random.randint(k0, (batch,), 0, self.V)
        noise_mask = jax.random.bernoulli(k1, self.noise, (batch, self.S))
        rand_tok = jax.random.randint(k2, (batch, self.S), 0, self.V)

        def step(x, inputs):
            nz, rt = inputs
            nxt = jnp.where(nz, rt, (self.a * x + self.c) % self.V)
            return nxt, nxt

        _, toks = jax.lax.scan(
            step, x0, (noise_mask.T, rand_tok.T)
        )
        return jnp.concatenate([x0[:, None], toks.T], axis=1)  # (B, S+1)

    def batch(self, step: int, peer: int = 0, *, batch_size=None, extras=None):
        """Deterministic batch for (step, peer). extras: dict of
        (name -> (shape_tail, dtype)) modality stubs to attach."""
        b = batch_size or self.B
        key = jax.random.key(peer_seed(self.global_seed, step, peer))
        out = {"tokens": self._gen(key, b).astype(jnp.int32)}
        if extras:
            for name, (tail, dt) in extras.items():
                out[name] = (
                    jax.random.normal(jax.random.fold_in(key, hash(name) % 997), (b,) + tail) * 0.02
                ).astype(dt)
        return out


def classification_batch(seed: int, batch: int, dim: int, n_classes: int,
                         flip_labels: bool = False, margin: float = 2.0):
    """Gaussian mixture with fixed class means (deterministic in seed).
    flip_labels implements the paper's LABEL FLIPPING attack (l -> K-1-l)."""
    means_key = jax.random.key(12345)  # fixed task definition
    means = jax.random.normal(means_key, (n_classes, dim)) * margin
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    y = jax.random.randint(k1, (batch,), 0, n_classes)
    x = means[y] + jax.random.normal(k2, (batch, dim))
    if flip_labels:
        y = n_classes - 1 - y
    return {"x": x, "y": y}
