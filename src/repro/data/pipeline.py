"""Deterministic public-seed data pipeline.

BTARD's security model (paper §3, footnote 2) requires PUBLIC data: every
peer samples minibatches from the full dataset via publicly known seeds
xi_i^t, so validators can recompute anyone's gradients bit-exactly. Here the
"dataset" is a deterministic synthetic generator:

* token streams with learnable structure (noisy affine bigram process) for
  LM training — loss demonstrably decreases;
* gaussian-mixture classification batches for the §4.1-style controlled
  Byzantine experiments;
* frame/patch embedding stubs for the audio/VLM modality frontends.

``peer_seed(global_seed, step, peer)`` is the paper's xi_i^t as a host int;
``peer_key`` is the same chain as a pure ``jax.random`` fold-in, so the SAME
derivation serves the host loop and the device-resident scan loop — a traced
``device_batch(step, peer)`` is bitwise identical to a host ``batch(step,
peer)`` (property-tested in tests/test_device_data.py).
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
import numpy as np


def peer_seed(global_seed: int, step: int, peer: int) -> int:
    """xi_i^t — publicly derivable, collision-free peer/step seed (host int).

    Kept for the int-seeded consumers (classification_batch). Note the
    affine form overflows int32 for large step*peer products when evaluated
    with fixed-width arrays — traced/device callers must use ``peer_key``,
    which folds each coordinate independently and never multiplies.
    """
    return (global_seed * 1_000_003 + step * 4099 + peer) % (2**31 - 1)


def peer_key(global_seed, step, peer):
    """xi_i^t as a PRNG key: fold_in(fold_in(key(seed), step), peer).

    Pure and jit/scan-traceable (step/peer may be traced i32 scalars), no
    int64-overflow hazard, and injective per (step, peer) by construction —
    the derivation every pipeline path shares, so validators recomputing a
    peer's batch on ANY path (host or in-scan) get the same bits.
    ``global_seed`` may be an int or an already-made PRNG key (typed key
    arrays are 0-d, so detect by dtype, not ndim).
    """
    if isinstance(global_seed, (int, np.integer)):
        key = jax.random.key(global_seed)
    else:
        arr = jnp.asarray(global_seed)
        key = (
            arr
            if jnp.issubdtype(arr.dtype, jax.dtypes.prng_key)
            else jax.random.key(arr)
        )
    return jax.random.fold_in(jax.random.fold_in(key, step), peer)


def _stable_tag(name: str) -> int:
    """Process-independent tag for extras streams (``hash()`` is randomized
    per interpreter by PYTHONHASHSEED — public-seed data must not be)."""
    return zlib.crc32(name.encode()) & 0x7FFFFFFF


class TokenPipeline:
    """Synthetic LM stream: x_{t+1} = (a*x_t + c) mod V with prob (1-noise),
    else uniform. A model that learns the affine map drops well below
    uniform cross-entropy."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 a: int = 5, c: int = 7, noise: float = 0.2, global_seed: int = 0):
        self.V = int(vocab_size)
        self.S = seq_len
        self.B = batch_size
        # canonicalize the affine map mod V, then refuse parameterizations
        # whose transition a*x+c would wrap int32 on device: the wrap is
        # SILENT (jnp `%` keeps tokens in [0, V) either way) but the emitted
        # process is no longer the documented bigram, so a validator reading
        # the (a, c, V) spec could not reproduce the stream from it. Default
        # a=5, c=7 is exact for every zoo vocab (V < ~4.3e8 ≫ 2^18 vocabs).
        a, c = int(a) % self.V, int(c) % self.V
        if a * (self.V - 1) + c >= 2**31:
            raise ValueError(
                f"affine token map a*x+c overflows int32 for a={a}, c={c}, "
                f"vocab={self.V}: max transition {a * (self.V - 1) + c} >= 2^31"
            )
        self.a, self.c, self.noise = a, c, noise
        self.global_seed = global_seed

    def _gen(self, key, batch):
        k0, k1, k2 = jax.random.split(key, 3)
        x0 = jax.random.randint(k0, (batch,), 0, self.V)
        noise_mask = jax.random.bernoulli(k1, self.noise, (batch, self.S))
        rand_tok = jax.random.randint(k2, (batch, self.S), 0, self.V)

        def step(x, inputs):
            nz, rt = inputs
            nxt = jnp.where(nz, rt, (self.a * x + self.c) % self.V)
            return nxt, nxt

        _, toks = jax.lax.scan(
            step, x0, (noise_mask.T, rand_tok.T)
        )
        return jnp.concatenate([x0[:, None], toks.T], axis=1)  # (B, S+1)

    def device_batch(self, step, peer=0, *, batch_size=None, extras=None):
        """Deterministic batch for (step, peer) as a PURE function — step and
        peer may be traced i32 scalars, so this generator runs INSIDE a
        jitted ``lax.scan`` body (the device-resident training loop: no
        host->device batch transfer per step). extras: dict of
        (name -> (shape_tail, dtype)) modality stubs to attach.

        Keyed by the public ``peer_key`` chain, so the verification-critical
        integer ``tokens`` of a traced call are BITWISE identical to the
        host ``batch()`` for the same (step, peer) — validators recomputing
        a peer's gradient from the public seed are path-independent. Float
        ``extras`` agree to 1 ulp only (XLA may fuse the normal*scale chain
        differently across programs); archs with modality extras should
        compare paths to f32 tolerance, not bit-for-bit.
        """
        b = batch_size or self.B
        key = peer_key(self.global_seed, step, peer)
        out = {"tokens": self._gen(key, b).astype(jnp.int32)}
        if extras:
            for name, (tail, dt) in extras.items():
                out[name] = (
                    jax.random.normal(
                        jax.random.fold_in(key, _stable_tag(name)), (b,) + tail
                    )
                    * 0.02
                ).astype(dt)
        return out

    def batch(self, step: int, peer: int = 0, *, batch_size=None, extras=None):
        """Host-loop entry point — same bits as ``device_batch`` (it IS
        device_batch, evaluated eagerly with concrete step/peer)."""
        return self.device_batch(
            step, peer, batch_size=batch_size, extras=extras
        )


def classification_batch(seed: int, batch: int, dim: int, n_classes: int,
                         flip_labels: bool = False, margin: float = 2.0):
    """Gaussian mixture with fixed class means (deterministic in seed).
    flip_labels implements the paper's LABEL FLIPPING attack (l -> K-1-l)."""
    means_key = jax.random.key(12345)  # fixed task definition
    means = jax.random.normal(means_key, (n_classes, dim)) * margin
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    y = jax.random.randint(k1, (batch,), 0, n_classes)
    x = means[y] + jax.random.normal(k2, (batch, dim))
    if flip_labels:
        y = n_classes - 1 - y
    return {"x": x, "y": y}
