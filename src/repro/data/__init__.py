from repro.data.pipeline import (  # noqa: F401
    TokenPipeline,
    classification_batch,
    peer_key,
    peer_seed,
)
