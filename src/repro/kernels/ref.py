"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def centered_clip_ref(xs, taus, weights=None, v0=None):
    """Reference CenteredClip.

    xs: (n, d); taus: (n_iters,) per-iteration clip radii; weights: (n,).
    Returns v: (d,) f32.
    """
    xs = xs.astype(jnp.float32)
    n, d = xs.shape
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    wsum = jnp.maximum(w.sum(), 1e-30)
    v = jnp.zeros((d,), jnp.float32) if v0 is None else v0.astype(jnp.float32)
    for tau in taus:
        diff = xs - v[None, :]
        norms = jnp.linalg.norm(diff, axis=1)
        cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
        cw = jnp.where(jnp.isinf(tau), 1.0, cw) * w
        v = v + (cw[:, None] * diff).sum(0) / wsum
    return v


def verify_tables_ref(xs, v, z, tau):
    """Reference fused verification scalars.

    s_i = min(1, tau/||x_i - v||) * <z, x_i - v>;  norm_i = ||x_i - v||.
    xs: (n, d); v, z: (d,). Returns (s (n,), norms (n,)) f32.
    """
    xs = xs.astype(jnp.float32)
    v = v.astype(jnp.float32)
    z = z.astype(jnp.float32)
    diff = xs - v[None, :]
    norms = jnp.linalg.norm(diff, axis=1)
    dots = diff @ z
    cw = jnp.minimum(1.0, jnp.float32(tau) / jnp.maximum(norms, 1e-30))
    return cw * dots, norms
