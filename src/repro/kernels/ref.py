"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def centered_clip_ref(xs, taus, weights=None, v0=None):
    """Reference CenteredClip.

    xs: (n, d); taus: (n_iters,) per-iteration clip radii; weights: (n,).
    Returns v: (d,) f32.
    """
    xs = xs.astype(jnp.float32)
    n, d = xs.shape
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    wsum = jnp.maximum(w.sum(), 1e-30)
    v = jnp.zeros((d,), jnp.float32) if v0 is None else v0.astype(jnp.float32)
    for tau in taus:
        diff = xs - v[None, :]
        norms = jnp.linalg.norm(diff, axis=1)
        cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
        cw = jnp.where(jnp.isinf(tau), 1.0, cw) * w
        v = v + (cw[:, None] * diff).sum(0) / wsum
    return v


def centered_clip_fused_ref(xs, taus, z, tau_v=None, weights=None):
    """Reference for the fused kernel's incremental-norm recurrence.

    Tracks the per-peer squared norms through the EXPANDED recurrence
        sq_{l+1,i} = sq_{l,i} - 2 <x_i - v_l, upd> + ||upd||^2
    (the algebraic form of sum_b ||diff_b - upd_b||^2) instead of ever
    recomputing ||x_i - v|| from x — so it validates the recurrence with a
    different floating-point evaluation order than both the kernel (per-block
    direct sums) and the plain jnp path (full-vector norms).

    xs: (n, d); taus: (n_iters,); z: (d,). Returns (v (d,), s (n,),
    norms (n,)) f32, matching centered_clip_fused_pallas.
    """
    xs = xs.astype(jnp.float32)
    z = z.astype(jnp.float32)
    n, d = xs.shape
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    wsum = jnp.maximum(w.sum(), 1e-30)
    if tau_v is None:
        tau_v = taus[-1]
    v = jnp.zeros((d,), jnp.float32)
    sq = jnp.sum(xs * xs, axis=1)  # prologue: ||x_i - v_0||^2 with v_0 = 0
    for tau in taus:
        norms = jnp.sqrt(jnp.maximum(sq, 1e-30))
        cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
        cw = jnp.where(jnp.isinf(tau), 1.0, cw) * w
        diff = xs - v[None, :]
        upd = (cw[:, None] * diff).sum(0) / wsum
        v = v + upd
        sq = jnp.maximum(sq - 2.0 * (diff @ upd) + upd @ upd, 0.0)
    # verification epilogue: one more look at x for the z-dots; norms come
    # from the recurrence state
    norms = jnp.sqrt(sq)
    dots = (xs - v[None, :]) @ z
    cwv = jnp.minimum(1.0, jnp.float32(tau_v) / jnp.maximum(norms, 1e-30))
    cwv = jnp.where(jnp.isinf(jnp.float32(tau_v)), 1.0, cwv)
    return v, cwv * dots, norms


def adaptive_step_ref(xs, v, sq, tau, weights=None):
    """Reference for ONE adaptive-driver iteration (the step kernel).

    xs: (n, d); v: (d,); sq: (n,) = ||x_i - v||^2 (the carried recurrence
    state). Returns (v_new (d,), sq_new (n,)) f32 — clip weights come from
    the CARRIED sq, the next sq from the incremental recurrence, exactly the
    kernel's dataflow.
    """
    xs = xs.astype(jnp.float32)
    v = v.astype(jnp.float32)
    n = xs.shape[0]
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    wsum = jnp.maximum(w.sum(), 1e-30)
    norms = jnp.sqrt(jnp.maximum(sq, 1e-30))
    cw = jnp.minimum(1.0, jnp.float32(tau) / jnp.maximum(norms, 1e-30))
    cw = jnp.where(jnp.isinf(jnp.float32(tau)), 1.0, cw) * w
    diff = xs - v[None, :]
    upd = (cw[:, None] * diff).sum(0) / wsum
    nd = diff - upd[None, :]
    return v + upd, jnp.sum(nd * nd, axis=1)


def digest_tables_ref(xs, v, z):
    """Reference generalized contribution digests (core.verification).

    s_i = <z, x_i - v>;  norm_i = ||x_i - v|| — the verified:* wrapper's
    tables: no clip weight, the wrapped coordinatewise aggregators carry no
    tau. xs: (n, d); v, z: (d,). Returns (s (n,), norms (n,)) f32.
    """
    xs = xs.astype(jnp.float32)
    diff = xs - v.astype(jnp.float32)[None, :]
    return diff @ z.astype(jnp.float32), jnp.linalg.norm(diff, axis=1)


def mean_digest_fused_ref(xs, z, weights=None):
    """Reference for the fused verified:mean kernel: the weighted mean plus
    the digest tables against it, evaluated with full-vector jnp ops (a
    different accumulation order than the kernel's per-block sums).

    xs: (n, d); z: (d,); weights: (n,).
    Returns (v (d,), s (n,), norms (n,)) f32.
    """
    xs = xs.astype(jnp.float32)
    n = xs.shape[0]
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    v = (w[:, None] * xs).sum(0) / jnp.maximum(w.sum(), 1e-30)
    s, norms = digest_tables_ref(xs, v, z)
    return v, s, norms


def dequantize_ref(wire, scales):
    """Reference wire dequantize: element-for-element the formula the
    dequant kernels apply in-register (and core.compression.dequantize
    applies in jnp) — upcast to f32, one f32 multiply by the per-payload
    sidecar scale. wire: (..., d) int8/bf16; scales: (...)."""
    return wire.astype(jnp.float32) * scales[..., None]


def centered_clip_fused_dequant_ref(qs, scales, taus, z, tau_v=None,
                                    weights=None):
    """Reference for ONE partition of the fused dequantize+clip+digest
    kernel: dequantize the wire payloads, then the fused incremental-norm
    recurrence. qs: (n, d) wire dtype; scales: (n,); taus: (n_iters,);
    z: (d,). Returns (v (d,), s (n,), norms (n,)) f32."""
    return centered_clip_fused_ref(
        dequantize_ref(qs, scales), taus, z, tau_v=tau_v, weights=weights
    )


def mean_digest_fused_dequant_ref(qs, scales, z, weights=None):
    """Reference for ONE partition of the fused dequantize+mean+digest
    kernel (compressed:verified:mean). qs: (n, d) wire dtype; scales: (n,);
    z: (d,). Returns (v (d,), s (n,), norms (n,)) f32."""
    return mean_digest_fused_ref(dequantize_ref(qs, scales), z, weights)


def digest_tables_rows_ref(parts, agg, z, rows, tau=0.0):
    """Reference sampled-column digests (sampled-digest audit mode): for
    each sampled partition id j in ``rows``, the per-peer digests against
    that partition's aggregate — verify_tables_ref when tau > 0
    (ButterflyClip clip weight), digest_tables_ref when tau == 0 (the
    verified:* wrappers). parts: (n_parts, n, part); agg, z:
    (n_parts, part); rows: (k,) i32. Returns (s (k, n), norms (k, n)) f32.
    """
    xs = jnp.take(parts, rows, axis=0)
    v = jnp.take(agg, rows, axis=0)
    zr = jnp.take(z, rows, axis=0)
    if tau > 0:
        return jax.vmap(
            lambda x, vv, zz: verify_tables_ref(x, vv, zz, tau)
        )(xs, v, zr)
    return jax.vmap(digest_tables_ref)(xs, v, zr)


def verify_tables_ref(xs, v, z, tau):
    """Reference fused verification scalars.

    s_i = min(1, tau/||x_i - v||) * <z, x_i - v>;  norm_i = ||x_i - v||.
    xs: (n, d); v, z: (d,). Returns (s (n,), norms (n,)) f32.
    """
    xs = xs.astype(jnp.float32)
    v = v.astype(jnp.float32)
    z = z.astype(jnp.float32)
    diff = xs - v[None, :]
    norms = jnp.linalg.norm(diff, axis=1)
    dots = diff @ z
    cw = jnp.minimum(1.0, jnp.float32(tau) / jnp.maximum(norms, 1e-30))
    return cw * dots, norms
