"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels run with interpret=True; on a real TPU
set ``REPRO_PALLAS_COMPILE=1`` (or pass interpret=False) to lower natively.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import centered_clip as _k

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@functools.partial(jax.jit, static_argnames=("n_iters", "block"))
def centered_clip_op(xs, tau, weights=None, *, n_iters: int = 20, block: int = _k.DEFAULT_BLOCK):
    """Kernel-backed CenteredClip: xs (n, d), scalar tau -> (d,) f32."""
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (n_iters,))
    return _k.centered_clip_pallas(
        xs, taus, weights, block=block, interpret=_INTERPRET
    )


@functools.partial(jax.jit, static_argnames=("block",))
def verify_tables_op(xs, v, z, tau, *, block: int = _k.DEFAULT_BLOCK):
    """Kernel-backed fused verification tables."""
    return _k.verify_tables_pallas(xs, v, z, tau, block=block, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("n_iters", "block"))
def butterfly_clip_op(parts, tau, weights=None, *, n_iters: int = 20, block: int = _k.DEFAULT_BLOCK):
    """Kernel-backed all-partition ButterflyClip aggregation:
    parts (n_parts, n_peers, part) -> (n_parts, part)."""
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (n_iters,))
    return _k.butterfly_clip_pallas(parts, taus, weights, block=block, interpret=_INTERPRET)
