"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels run with interpret=True; on a real TPU
set ``REPRO_PALLAS_COMPILE=1`` (or pass interpret=False) to lower natively.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import centered_clip as _k

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@functools.partial(jax.jit, static_argnames=("n_iters", "block"))
def centered_clip_op(
    xs, tau, weights=None, v0=None, *, n_iters: int = 20, block: int = _k.DEFAULT_BLOCK
):
    """Kernel-backed CenteredClip: xs (n, d), scalar tau -> (d,) f32.
    v0: optional (d,) warm start (previous aggregate)."""
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (n_iters,))
    return _k.centered_clip_pallas(
        xs, taus, weights, v0, block=block, interpret=_INTERPRET
    )


@functools.partial(jax.jit, static_argnames=("block",))
def verify_tables_op(xs, v, z, tau, *, block: int = _k.DEFAULT_BLOCK):
    """Kernel-backed fused verification tables."""
    return _k.verify_tables_pallas(xs, v, z, tau, block=block, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("n_iters", "block"))
def butterfly_clip_op(
    parts, tau, weights=None, v0=None, *, n_iters: int = 20, block: int = _k.DEFAULT_BLOCK
):
    """Kernel-backed all-partition ButterflyClip aggregation:
    parts (n_parts, n_peers, part) -> (n_parts, part).
    v0: optional (n_parts, part) warm start (previous aggregate)."""
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (n_iters,))
    return _k.butterfly_clip_pallas(
        parts, taus, weights, v0, block=block, interpret=_INTERPRET
    )


# ---------------------------------------------------------------------------
# Fused one-pass-per-iteration family: aggregation + verification tables in
# n_iters + 2 HBM passes of x (vs 2*n_iters + 1 for the two-call pipeline).
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_iters", "block"))
def centered_clip_fused_op(
    xs, tau, z, weights=None, tau_v=None, v0=None, *,
    n_iters: int = 20, block: int = _k.DEFAULT_BLOCK
):
    """Fused CenteredClip + Alg. 6 tables: xs (n, d), z (d,) ->
    (agg (d,), s (n,), norms (n,)). v0: optional (d,) warm start."""
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (n_iters,))
    return _k.centered_clip_fused_pallas(
        xs, taus, z, tau_v=tau_v, weights=weights, v0=v0,
        block=block, interpret=_INTERPRET,
    )


@functools.partial(jax.jit, static_argnames=("n_iters", "block"))
def butterfly_clip_fused_op(
    parts, tau, z, weights=None, tau_v=None, v0=None, *,
    n_iters: int = 20, block: int = _k.DEFAULT_BLOCK
):
    """Fused all-partition ButterflyClip aggregation + broadcast tables:
    parts (n_parts, n_peers, part), z (n_parts, part) ->
    (agg (n_parts, part), s (n_peers, n_parts), norms (n_peers, n_parts)).

    s/norms come back transposed to the (peer, partition) layout of
    core.butterfly.verification_tables. v0: optional warm start."""
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (n_iters,))
    agg, s, norms = _k.butterfly_clip_fused_pallas(
        parts, taus, z, tau_v=tau_v, weights=weights, v0=v0,
        block=block, interpret=_INTERPRET,
    )
    return agg, s.T, norms.T


@functools.partial(jax.jit, static_argnames=("n_iters", "block"))
def butterfly_clip_fused_dequant_op(
    qs, scales, tau, z, weights=None, tau_v=None, v0=None, *,
    n_iters: int = 20, block: int = _k.DEFAULT_BLOCK
):
    """Fused dequantize + ButterflyClip + broadcast tables over WIRE
    payloads (compressed:butterfly_clip — core.compression): qs
    (n_parts, n_peers, part) int8/bf16 stays in its wire dtype for all
    n_iters + 2 HBM passes, dequantized in-register against the
    (n_parts, n_peers) f32 sidecar scales. Returns (agg (n_parts, part),
    s (n_peers, n_parts), norms (n_peers, n_parts)) — the layout of
    butterfly_clip_fused_op."""
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (n_iters,))
    agg, s, norms = _k.butterfly_clip_fused_dequant_pallas(
        qs, scales, taus, z, tau_v=tau_v, weights=weights, v0=v0,
        block=block, interpret=_INTERPRET,
    )
    return agg, s.T, norms.T


# ---------------------------------------------------------------------------
# Adaptive early-exit family: one-pass-per-iteration step kernel under a
# lax.while_loop, stopping at ||v_{l+1}-v_l|| <= tol with a static max_iters
# cap; the verification-table epilogue runs exactly ONCE against the final
# iterate. iters_run + 2 HBM passes of the stack vs n_iters + 2 fixed.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("max_iters", "block"))
def butterfly_clip_adaptive_op(
    parts, tau, tol, weights=None, v0=None, *,
    max_iters: int = 60, block: int = _k.DEFAULT_BLOCK
):
    """Kernel-backed adaptive all-partition ButterflyClip aggregation:
    parts (n_parts, n_peers, part) -> (agg (n_parts, part),
    iters (n_parts,) i32). v0: optional warm start (previous aggregate)."""
    return _k.butterfly_clip_adaptive_pallas(
        parts, tau, tol, max_iters, weights, v0,
        block=block, interpret=_INTERPRET,
    )


@functools.partial(jax.jit, static_argnames=("max_iters", "block"))
def butterfly_clip_fused_adaptive_op(
    parts, tau, z, tol, weights=None, v0=None, *,
    max_iters: int = 60, block: int = _k.DEFAULT_BLOCK
):
    """Adaptive aggregation + Alg. 6 broadcast tables: the early-exit
    iteration driver followed by ONE verification-table pass against the
    final aggregate (deterministic however many iterations ran).

    Returns (agg (n_parts, part), s (n_peers, n_parts),
    norms (n_peers, n_parts), iters (n_parts,) i32) — s/norms in the
    (peer, partition) layout of core.butterfly.verification_tables."""
    agg, iters = _k.butterfly_clip_adaptive_pallas(
        parts, tau, tol, max_iters, weights, v0,
        block=block, interpret=_INTERPRET,
    )
    s, norms = _k.verify_tables_batched_pallas(
        parts, agg, z, tau, block=block, interpret=_INTERPRET
    )
    return agg, s.T, norms.T, iters


@functools.partial(jax.jit, static_argnames=("block",))
def verify_tables_all_op(parts, agg, z, tau, *, block: int = _k.DEFAULT_BLOCK):
    """Kernel-backed all-partition verification tables (one pass of parts):
    -> (s (n_peers, n_parts), norms (n_peers, n_parts))."""
    s, norms = _k.verify_tables_batched_pallas(
        parts, agg, z, tau, block=block, interpret=_INTERPRET
    )
    return s.T, norms.T


# ---------------------------------------------------------------------------
# Generalized verification-wrapper digests (core.verification): per-peer
# contribution digests s_i = <z, x_i - v>, ||x_i - v|| — no clip weight,
# because the wrapped coordinatewise aggregators carry no tau.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("block",))
def digest_tables_all_op(parts, agg, z, *, block: int = _k.DEFAULT_BLOCK):
    """Kernel-backed all-partition contribution digests (one pass of parts):
    -> (s (n_peers, n_parts), norms (n_peers, n_parts)) — the standalone
    digest pass for verified:* specs whose aggregation runs in jnp."""
    s, norms = _k.digest_tables_batched_pallas(
        parts, agg, z, block=block, interpret=_INTERPRET
    )
    return s.T, norms.T


@functools.partial(jax.jit, static_argnames=("block",))
def digest_tables_rows_op(parts, agg, z, rows, tau=0.0, *,
                          block: int = _k.DEFAULT_BLOCK):
    """Kernel-backed SAMPLED-column digests (sampled-digest audit mode):
    parts (n_parts, n_peers, part), rows (k,) i32 sampled partition ids ->
    (s (n_peers, k), norms (n_peers, k)) — transposed to the
    (peer, column) layout of core.verification.digest_tables, column p of
    the output = partition rows[p]. tau > 0 applies the ButterflyClip clip
    weight; tau == 0 emits the plain verified:* digests. One HBM pass of
    the k sampled partitions only (scalar-prefetched row ids)."""
    s, norms = _k.digest_tables_rows_pallas(
        parts, agg, z, rows, tau, block=block, interpret=_INTERPRET
    )
    return s.T, norms.T


@functools.partial(jax.jit, static_argnames=("block",))
def mean_digest_fused_op(parts, z, weights=None, *, block: int = _k.DEFAULT_BLOCK):
    """verified:mean's fused aggregation + digest epilogue in ONE
    pallas_call (2 HBM passes of the stacked partitions, zero materialized
    temporaries): parts (n_parts, n_peers, part), z (n_parts, part) ->
    (agg (n_parts, part), s (n_peers, n_parts), norms (n_peers, n_parts)).

    s/norms come back transposed to the (peer, partition) layout of
    core.verification.digest_tables."""
    agg, s, norms = _k.mean_digest_fused_pallas(
        parts, z, weights, block=block, interpret=_INTERPRET
    )
    return agg, s.T, norms.T


@functools.partial(jax.jit, static_argnames=("block",))
def mean_digest_fused_dequant_op(
    qs, scales, z, weights=None, *, block: int = _k.DEFAULT_BLOCK
):
    """compressed:verified:mean's fused dequantize + aggregation + digest
    epilogue: qs (n_parts, n_peers, part) int8/bf16 wire payloads stay in
    their wire dtype for both HBM passes, dequantized in-register against
    the (n_parts, n_peers) f32 sidecar scales. Returns (agg, s, norms) in
    the mean_digest_fused_op layout."""
    agg, s, norms = _k.mean_digest_fused_dequant_pallas(
        qs, scales, z, weights, block=block, interpret=_INTERPRET
    )
    return agg, s.T, norms.T
