"""Pallas TPU kernels for BTARD's aggregation hot spots.

The CenteredClip fixed point is a bandwidth-bound reduction over the stacked
peer partitions (n_peers x part). The naive jnp version materializes
``diff``, ``norms`` and the weighted sum as separate HBM temporaries every
iteration (~4 passes); these kernels keep the working tile resident in VMEM
and stream x once per phase:

* ``centered_clip_kernel`` — grid (n_iters, 2, n_blocks); phase 0 accumulates
  per-peer squared norms into a VMEM scratch, phase 1 converts them to clip
  weights and updates v in place (input/output aliased). 2 HBM passes of x
  per iteration, zero temporaries.

* ``verify_tables_kernel`` — ONE pass of x producing both Verification-1/2
  tables: per-peer <z, x_i - v> and ||x_i - v|| accumulate together, the clip
  weight is applied in the epilogue on the last block.

Block geometry: peers stay un-tiled (n <= ~64 on the peer axis), the
partition dim is tiled by ``block`` (lane-aligned multiples of 128). Inputs
are zero-padded to a block multiple — zero columns where x == v == 0
contribute nothing to norms, dots, or updates, so padding is exact.
Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 512


# ===========================================================================
# CenteredClip fixed-point kernel
# ===========================================================================
def _cc_kernel(taus_ref, w_ref, xs_ref, v_ref, out_ref, sq_ref, cw_ref):
    """Grid (n_iters, 2, n_blocks).

    taus: (n_iters, 1) SMEM-ish small input; w: (n, 1) peer weights;
    xs: (n, blk) tile; v/out: (1, blk) aliased; scratch sq/cw: (n, 1) f32.
    """
    it = pl.program_id(0)
    phase = pl.program_id(1)
    blk = pl.program_id(2)

    @pl.when(phase == 0)
    def _phase_norms():
        @pl.when(it == 0)
        def _copy_in():
            # v lives in out_ref from here on (aliasing the input ref is not
            # readable-after-write in interpret mode)
            out_ref[...] = v_ref[...]

        @pl.when(blk == 0)
        def _reset():
            sq_ref[...] = jnp.zeros_like(sq_ref)

        diff = xs_ref[...].astype(jnp.float32) - out_ref[...].astype(jnp.float32)
        sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(phase == 1)
    def _phase_update():
        @pl.when(blk == 0)
        def _weights():
            tau = taus_ref[0, 0]
            norms = jnp.sqrt(jnp.maximum(sq_ref[...], 1e-30))
            cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
            cw = jnp.where(jnp.isinf(tau), 1.0, cw)
            cw_ref[...] = cw * w_ref[...].astype(jnp.float32)

        wsum = jnp.maximum(jnp.sum(w_ref[...].astype(jnp.float32)), 1e-30)
        diff = xs_ref[...].astype(jnp.float32) - out_ref[...].astype(jnp.float32)
        upd = jnp.sum(cw_ref[...] * diff, axis=0, keepdims=True) / wsum
        out_ref[...] = out_ref[...] + upd


def centered_clip_pallas(
    xs, taus, weights=None, *, block: int = DEFAULT_BLOCK, interpret: bool = True
):
    """CenteredClip via the Pallas kernel. xs: (n, d) -> v: (d,) f32."""
    n, d = xs.shape
    n_iters = int(taus.shape[0])
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        xs = jnp.pad(xs, ((0, 0), (0, dp - d)))
    n_blocks = dp // blk

    taus2 = taus.reshape(n_iters, 1).astype(jnp.float32)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    v0 = jnp.zeros((1, dp), jnp.float32)

    out = pl.pallas_call(
        _cc_kernel,
        grid=(n_iters, 2, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, p, b: (i, 0)),
            pl.BlockSpec((n, 1), lambda i, p, b: (0, 0)),
            pl.BlockSpec((n, blk), lambda i, p, b: (0, b)),
            pl.BlockSpec((1, blk), lambda i, p, b: (0, b)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i, p, b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(taus2, w2, xs, v0)
    return out[0, :d]


# ===========================================================================
# Batched multi-partition CenteredClip (the full ButterflyClip aggregation
# in ONE pallas_call: grid (n_parts, n_iters, 2, n_blocks); the partition
# index is outermost so the per-peer scratch naturally re-initializes at
# each partition's first grid step)
# ===========================================================================
def _bcc_kernel(taus_ref, w_ref, xs_ref, v_ref, out_ref, sq_ref, cw_ref):
    it = pl.program_id(1)
    phase = pl.program_id(2)
    blk = pl.program_id(3)

    @pl.when(phase == 0)
    def _phase_norms():
        @pl.when(it == 0)
        def _copy_in():
            out_ref[...] = v_ref[...]

        @pl.when(blk == 0)
        def _reset():
            sq_ref[...] = jnp.zeros_like(sq_ref)

        diff = xs_ref[0].astype(jnp.float32) - out_ref[...].astype(jnp.float32)
        sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(phase == 1)
    def _phase_update():
        @pl.when(blk == 0)
        def _weights():
            tau = taus_ref[0, 0]
            norms = jnp.sqrt(jnp.maximum(sq_ref[...], 1e-30))
            cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
            cw = jnp.where(jnp.isinf(tau), 1.0, cw)
            cw_ref[...] = cw * w_ref[...].astype(jnp.float32)

        wsum = jnp.maximum(jnp.sum(w_ref[...].astype(jnp.float32)), 1e-30)
        diff = xs_ref[0].astype(jnp.float32) - out_ref[...].astype(jnp.float32)
        upd = jnp.sum(cw_ref[...] * diff, axis=0, keepdims=True) / wsum
        out_ref[...] = out_ref[...] + upd


def butterfly_clip_pallas(
    parts, taus, weights=None, *, block: int = DEFAULT_BLOCK, interpret: bool = True
):
    """All-partition CenteredClip: parts (n_parts, n_peers, part) -> the
    robust aggregate (n_parts, part) f32 — i.e. ButterflyClip's aggregation
    stage as a single fused kernel."""
    n_parts, n, d = parts.shape
    n_iters = int(taus.shape[0])
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        parts = jnp.pad(parts, ((0, 0), (0, 0), (0, dp - d)))
    n_blocks = dp // blk

    taus2 = taus.reshape(n_iters, 1).astype(jnp.float32)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    v0 = jnp.zeros((n_parts, dp), jnp.float32)

    out = pl.pallas_call(
        _bcc_kernel,
        grid=(n_parts, n_iters, 2, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda p, i, ph, b: (i, 0)),
            pl.BlockSpec((n, 1), lambda p, i, ph, b: (0, 0)),
            pl.BlockSpec((1, n, blk), lambda p, i, ph, b: (p, 0, b)),
            pl.BlockSpec((1, blk), lambda p, i, ph, b: (p, b)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda p, i, ph, b: (p, b)),
        out_shape=jax.ShapeDtypeStruct((n_parts, dp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(taus2, w2, parts, v0)
    return out[:, :d]


# ===========================================================================
# Fused verification-tables kernel (single HBM pass)
# ===========================================================================
def _vt_kernel(tau_ref, xs_ref, v_ref, z_ref, s_ref, norm_ref, dot_ref, sq_ref):
    """Grid (n_blocks,). Accumulate per-peer dot & sqnorm; epilogue on last."""
    blk = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(blk == 0)
    def _reset():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    diff = xs_ref[...].astype(jnp.float32) - v_ref[...].astype(jnp.float32)
    zb = z_ref[...].astype(jnp.float32)
    dot_ref[...] += jnp.sum(diff * zb, axis=1, keepdims=True)
    sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(blk == nb - 1)
    def _epilogue():
        tau = tau_ref[0, 0]
        norms = jnp.sqrt(jnp.maximum(sq_ref[...], 0.0))
        cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
        s_ref[...] = cw * dot_ref[...]
        norm_ref[...] = norms


def verify_tables_pallas(
    xs, v, z, tau, *, block: int = DEFAULT_BLOCK, interpret: bool = True
):
    """Fused s_i = <z, clip(x_i - v)>, norm_i = ||x_i - v|| in one pass.

    xs: (n, d); v, z: (d,). Returns (s (n,), norms (n,)).
    """
    n, d = xs.shape
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        xs = jnp.pad(xs, ((0, 0), (0, dp - d)))
        v = jnp.pad(v, (0, dp - d))
        z = jnp.pad(z, (0, dp - d))
    n_blocks = dp // blk

    tau2 = jnp.asarray(tau, jnp.float32).reshape(1, 1)
    s, norms = pl.pallas_call(
        _vt_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b: (0, 0)),
            pl.BlockSpec((n, blk), lambda b: (0, b)),
            pl.BlockSpec((1, blk), lambda b: (0, b)),
            pl.BlockSpec((1, blk), lambda b: (0, b)),
        ],
        out_specs=[
            pl.BlockSpec((n, 1), lambda b: (0, 0)),
            pl.BlockSpec((n, 1), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(tau2, xs, v.reshape(1, dp), z.reshape(1, dp))
    return s[:, 0], norms[:, 0]
