"""Pallas TPU kernels for BTARD's aggregation hot spots.

The CenteredClip fixed point is a bandwidth-bound reduction over the stacked
peer partitions (n_peers x part). The naive jnp version materializes
``diff``, ``norms`` and the weighted sum as separate HBM temporaries every
iteration (~4 passes). The fused kernel family streams x through VMEM ONE
time per clip iteration — see DESIGN.md for the full derivation:

* ``_fused_body`` (via ``centered_clip_fused_pallas`` and the batched
  ``butterfly_clip_fused_pallas``) — grid (n_iters + 2, n_blocks):
  pass 0 is a norm prologue (||x_i - v_0||^2 into a VMEM scratch), passes
  1..n_iters update v while accumulating the NEXT iteration's per-peer
  squared norms incrementally (||x_i - v_{l+1}||^2 = sum_b ||diff_b -
  upd_b||^2 — diff and upd are already in registers, so the separate norm
  phase of the legacy kernel disappears), and pass n_iters+1 is a fused
  verification epilogue producing the Alg. 6 broadcast tables
  s_i = min(1, tau/||x_i - v||) <z, x_i - v> and ||x_i - v|| for free
  (the final squared norms are still sitting in the scratch).
  Total: n_iters + 2 HBM passes of x vs 2*n_iters + 1 for the legacy
  two-phase kernel + separate table kernel.

* ``centered_clip_kernel`` (legacy, kept as a cross-check) — grid
  (n_iters, 2, n_blocks); phase 0 accumulates per-peer squared norms,
  phase 1 converts them to clip weights and updates v in place. 2 HBM
  passes of x per iteration.

* ``verify_tables_kernel`` — ONE pass of x producing both Verification-1/2
  tables standalone (used when the aggregate was corrupted after the fused
  call and the tables must be recomputed against the corrupted v).

* ``_dg_batched_kernel`` / ``digest_tables_batched_pallas`` — the
  GENERALIZED verification wrapper's contribution digests
  s_i = <z, x_i - v>, ||x_i - v|| (no clip weight — wrapped coordinatewise
  aggregators have no tau) in one pass of the stacked partitions; the
  standalone table pass for verified:* specs whose aggregation is a jnp
  sort (trimmed mean, coordinate median — nothing to fuse into).

* ``_md_kernel`` / ``mean_digest_fused_pallas`` — verified:mean's fused
  aggregation + digest epilogue: the weighted per-partition mean is a
  single streaming reduction, so the digest tables ride the same
  pallas_call (2 HBM passes of x total, zero materialized temporaries) —
  the fused-epilogue treatment the ButterflyClip flagship already gets.

* dequant variants (``butterfly_clip_fused_dequant_pallas``,
  ``mean_digest_fused_dequant_pallas``) — the same fused bodies over WIRE
  payloads (core.compression): xs stays int8/bf16 in HBM for every pass
  and is dequantized in-register against a per-(partition, peer) f32
  sidecar scale, so ``compressed:*`` specs keep the n_iters + 2 (resp. 2)
  pass structure over 1-2 byte data — ≈4× (int8) fewer HBM bytes per pass.
  All arithmetic runs on the dequantized f32 values (the same bits the jnp
  path computes), which is what keeps compressed verification exact.

Block geometry: peers stay un-tiled (n <= ~64 on the peer axis), the
partition dim is tiled by ``block`` (lane-aligned multiples of 128). Inputs
are zero-padded to a block multiple — zero columns where x == v == z == 0
contribute nothing to norms, dots, or updates, so padding is exact.
Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 512


# ===========================================================================
# CenteredClip fixed-point kernel
# ===========================================================================
def _cc_kernel(taus_ref, w_ref, xs_ref, v_ref, out_ref, sq_ref, cw_ref):
    """Grid (n_iters, 2, n_blocks).

    taus: (n_iters, 1) in SMEM (whole schedule, indexed by the pass id —
    a (1, 1) VMEM block would violate the TPU (8, 128) tile minimum);
    w: (n, 1) peer weights; xs: (n, blk) tile; v/out: (1, blk) aliased;
    scratch sq/cw: (n, 1) f32.
    """
    it = pl.program_id(0)
    phase = pl.program_id(1)
    blk = pl.program_id(2)

    @pl.when(phase == 0)
    def _phase_norms():
        @pl.when(it == 0)
        def _copy_in():
            # v lives in out_ref from here on (aliasing the input ref is not
            # readable-after-write in interpret mode)
            out_ref[...] = v_ref[...]

        @pl.when(blk == 0)
        def _reset():
            sq_ref[...] = jnp.zeros_like(sq_ref)

        diff = xs_ref[...].astype(jnp.float32) - out_ref[...].astype(jnp.float32)
        sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(phase == 1)
    def _phase_update():
        @pl.when(blk == 0)
        def _weights():
            tau = taus_ref[it, 0]
            norms = jnp.sqrt(jnp.maximum(sq_ref[...], 1e-30))
            cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
            cw = jnp.where(jnp.isinf(tau), 1.0, cw)
            cw_ref[...] = cw * w_ref[...].astype(jnp.float32)

        wsum = jnp.maximum(jnp.sum(w_ref[...].astype(jnp.float32)), 1e-30)
        diff = xs_ref[...].astype(jnp.float32) - out_ref[...].astype(jnp.float32)
        upd = jnp.sum(cw_ref[...] * diff, axis=0, keepdims=True) / wsum
        out_ref[...] = out_ref[...] + upd


def centered_clip_pallas(
    xs, taus, weights=None, v0=None, *,
    block: int = DEFAULT_BLOCK, interpret: bool = True,
):
    """CenteredClip via the Pallas kernel. xs: (n, d) -> v: (d,) f32.

    v0: optional (d,) warm start — flows straight into the kernel's v ref
    (the iteration state), zero extra HBM traffic.
    """
    n, d = xs.shape
    n_iters = int(taus.shape[0])
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        xs = jnp.pad(xs, ((0, 0), (0, dp - d)))
        if v0 is not None:
            v0 = jnp.pad(v0, (0, dp - d))
    n_blocks = dp // blk

    taus2 = taus.reshape(n_iters, 1).astype(jnp.float32)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    v0 = (
        jnp.zeros((1, dp), jnp.float32)
        if v0 is None
        else v0.reshape(1, dp).astype(jnp.float32)
    )

    out = pl.pallas_call(
        _cc_kernel,
        grid=(n_iters, 2, n_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n, 1), lambda i, p, b: (0, 0)),
            pl.BlockSpec((n, blk), lambda i, p, b: (0, b)),
            pl.BlockSpec((1, blk), lambda i, p, b: (0, b)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i, p, b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(taus2, w2, xs, v0)
    return out[0, :d]


# ===========================================================================
# Batched multi-partition CenteredClip (the full ButterflyClip aggregation
# in ONE pallas_call: grid (n_parts, n_iters, 2, n_blocks); the partition
# index is outermost so the per-peer scratch naturally re-initializes at
# each partition's first grid step)
# ===========================================================================
def _bcc_kernel(taus_ref, w_ref, xs_ref, v_ref, out_ref, sq_ref, cw_ref):
    """Like _cc_kernel with a leading partition grid axis. v/out carry a
    singleton sublane dim — (n_parts, 1, dp) with (1, 1, blk) blocks — so
    the native TPU lowering sees a legal (1, blk) tile instead of a (1, blk)
    slice of a (n_parts, dp) array (sublane dim must divide 8 or equal the
    array dim)."""
    it = pl.program_id(1)
    phase = pl.program_id(2)
    blk = pl.program_id(3)

    @pl.when(phase == 0)
    def _phase_norms():
        @pl.when(it == 0)
        def _copy_in():
            out_ref[0] = v_ref[0]

        @pl.when(blk == 0)
        def _reset():
            sq_ref[...] = jnp.zeros_like(sq_ref)

        diff = xs_ref[0].astype(jnp.float32) - out_ref[0].astype(jnp.float32)
        sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(phase == 1)
    def _phase_update():
        @pl.when(blk == 0)
        def _weights():
            tau = taus_ref[it, 0]
            norms = jnp.sqrt(jnp.maximum(sq_ref[...], 1e-30))
            cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
            cw = jnp.where(jnp.isinf(tau), 1.0, cw)
            cw_ref[...] = cw * w_ref[...].astype(jnp.float32)

        wsum = jnp.maximum(jnp.sum(w_ref[...].astype(jnp.float32)), 1e-30)
        diff = xs_ref[0].astype(jnp.float32) - out_ref[0].astype(jnp.float32)
        upd = jnp.sum(cw_ref[...] * diff, axis=0, keepdims=True) / wsum
        out_ref[0] = out_ref[0] + upd


def butterfly_clip_pallas(
    parts, taus, weights=None, v0=None, *,
    block: int = DEFAULT_BLOCK, interpret: bool = True,
):
    """All-partition CenteredClip: parts (n_parts, n_peers, part) -> the
    robust aggregate (n_parts, part) f32 — i.e. ButterflyClip's aggregation
    stage as a single fused kernel. v0: optional (n_parts, part) warm start."""
    n_parts, n, d = parts.shape
    n_iters = int(taus.shape[0])
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        parts = jnp.pad(parts, ((0, 0), (0, 0), (0, dp - d)))
        if v0 is not None:
            v0 = jnp.pad(v0, ((0, 0), (0, dp - d)))
    n_blocks = dp // blk

    taus2 = taus.reshape(n_iters, 1).astype(jnp.float32)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    v0 = (
        jnp.zeros((n_parts, 1, dp), jnp.float32)
        if v0 is None
        else v0.astype(jnp.float32).reshape(n_parts, 1, dp)
    )

    out = pl.pallas_call(
        _bcc_kernel,
        grid=(n_parts, n_iters, 2, n_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n, 1), lambda p, i, ph, b: (0, 0)),
            pl.BlockSpec((1, n, blk), lambda p, i, ph, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, i, ph, b: (p, 0, b)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk), lambda p, i, ph, b: (p, 0, b)),
        out_shape=jax.ShapeDtypeStruct((n_parts, 1, dp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(taus2, w2, parts, v0)
    return out[:, 0, :d]


# ===========================================================================
# Fused one-pass-per-iteration CenteredClip with incremental norms and a
# verification epilogue. Grid (n_iters + 2, n_blocks) (a leading n_parts
# axis in the batched variant):
#
#   pass 0            prologue: v := v0, sq_i := ||x_i - v0||^2
#   pass 1..n_iters   at blk 0 convert sq -> clip weights, zero sq; then per
#                     block: upd = sum_i cw_i (x_i - v) / wsum, v += upd, and
#                     sq_i += ||diff_i - upd||^2 — the NEXT iteration's
#                     squared norms, accumulated from values already in
#                     registers (no second read of x).
#   pass n_iters+1    epilogue: dot_i = <z, x_i - v>; on the last block emit
#                     s_i = min(1, tau_v/||x_i - v||) dot_i and ||x_i - v||
#                     (sq still holds the final squared norms).
#
# n_iters + 2 HBM passes of x total, vs 2*n_iters + 1 for the legacy
# two-phase kernel plus the standalone table kernel.
# ===========================================================================
def _fused_body(
    batched, taus_ref, tauv_ref, w_ref, xs_ref, v_ref, z_ref,
    out_ref, s_ref, norm_ref, sq_ref, cw_ref, dot_ref, *, scales_ref=None,
):
    """taus/tauv live in SMEM (whole schedule, indexed by the pass id); in
    the batched variant v/z/out/s/norm carry a singleton sublane dim (see
    _bcc_kernel) so every VMEM block satisfies the TPU tiling rules.

    scales_ref (dequant variant): per-peer f32 sidecar scales — xs arrives
    in its WIRE dtype (int8 / bf16) and is dequantized in-register
    (``xs.astype(f32) * scale``, the exact formula of
    core.compression.dequantize), so every clip iteration and the digest
    epilogue stream 1-2 byte data through HBM while all arithmetic sees the
    same f32 wire values as the jnp path — bit-identical digests."""
    off = 1 if batched else 0
    it = pl.program_id(off + 0)
    blk = pl.program_id(off + 1)
    n_upd = pl.num_programs(off + 0) - 2
    nb = pl.num_programs(off + 1)
    xs = (xs_ref[0] if batched else xs_ref[...]).astype(jnp.float32)
    if scales_ref is not None:  # in-register dequantize of the wire payload
        xs = xs * (scales_ref[0] if batched else scales_ref[...])
    # 2D (1, blk) views of the possibly 3D-blocked refs
    vget = (lambda r: r[0]) if batched else (lambda r: r[...])

    def out_set(val):
        if batched:
            out_ref[0] = val
        else:
            out_ref[...] = val

    @pl.when(it == 0)
    def _prologue():
        out_set(vget(v_ref).astype(jnp.float32))

        @pl.when(blk == 0)
        def _reset():
            sq_ref[...] = jnp.zeros_like(sq_ref)

        diff = xs - vget(out_ref)
        sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(jnp.logical_and(it >= 1, it <= n_upd))
    def _update():
        @pl.when(blk == 0)
        def _weights():
            tau = taus_ref[it, 0]
            norms = jnp.sqrt(jnp.maximum(sq_ref[...], 1e-30))
            cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
            cw = jnp.where(jnp.isinf(tau), 1.0, cw)
            cw_ref[...] = cw * w_ref[...].astype(jnp.float32)
            sq_ref[...] = jnp.zeros_like(sq_ref)  # accumulates iter l+1 norms

        wsum = jnp.maximum(jnp.sum(w_ref[...].astype(jnp.float32)), 1e-30)
        diff = xs - vget(out_ref)
        upd = jnp.sum(cw_ref[...] * diff, axis=0, keepdims=True) / wsum
        out_set(vget(out_ref) + upd)
        nd = diff - upd  # x_i - v_{l+1} restricted to this block
        sq_ref[...] += jnp.sum(nd * nd, axis=1, keepdims=True)

    @pl.when(it == n_upd + 1)
    def _epilogue():
        @pl.when(blk == 0)
        def _reset_dot():
            dot_ref[...] = jnp.zeros_like(dot_ref)

        diff = xs - vget(out_ref)
        dot_ref[...] += jnp.sum(diff * vget(z_ref).astype(jnp.float32),
                                axis=1, keepdims=True)

        @pl.when(blk == nb - 1)
        def _tables():
            tau_v = tauv_ref[0, 0]
            norms = jnp.sqrt(jnp.maximum(sq_ref[...], 0.0))
            cwv = jnp.minimum(1.0, tau_v / jnp.maximum(norms, 1e-30))
            cwv = jnp.where(jnp.isinf(tau_v), 1.0, cwv)
            s = cwv * dot_ref[...]  # (n, 1)
            if batched:
                s_ref[0] = s.reshape(s_ref.shape[1:])
                norm_ref[0] = norms.reshape(norm_ref.shape[1:])
            else:
                s_ref[...] = s.reshape(s_ref.shape)
                norm_ref[...] = norms.reshape(norm_ref.shape)


def _fused_dequant_body(
    batched, taus_ref, tauv_ref, w_ref, scales_ref, xs_ref, v_ref, z_ref,
    out_ref, s_ref, norm_ref, sq_ref, cw_ref, dot_ref,
):
    """Positional-ref adapter for the dequant variant: the sidecar scales
    ride as one extra VMEM operand between w and the wire-dtype xs."""
    _fused_body(
        batched, taus_ref, tauv_ref, w_ref, xs_ref, v_ref, z_ref,
        out_ref, s_ref, norm_ref, sq_ref, cw_ref, dot_ref,
        scales_ref=scales_ref,
    )


def _pad_taus(taus, n_iters):
    """(n_iters,) -> (n_iters + 2, 1) so the grid's pass index maps straight
    into the schedule (rows 0 / n_iters+1 are never read)."""
    t = taus.astype(jnp.float32).reshape(n_iters, 1)
    return jnp.concatenate([t[:1], t, t[-1:]], axis=0)


def centered_clip_fused_pallas(
    xs, taus, z, tau_v=None, weights=None, v0=None, *,
    block: int = DEFAULT_BLOCK, interpret: bool = True,
):
    """Fused CenteredClip + verification tables in n_iters + 2 passes of x.

    xs: (n, d); taus: (n_iters,); z: (d,) unit direction for the epilogue.
    tau_v defaults to taus[-1] (the protocol uses a constant schedule).
    v0: optional (d,) warm start (previous aggregate).
    Returns (v (d,), s (n,), norms (n,)) f32.
    """
    n, d = xs.shape
    n_iters = int(taus.shape[0])
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if tau_v is None:
        tau_v = taus[-1]
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        xs = jnp.pad(xs, ((0, 0), (0, dp - d)))
        z = jnp.pad(z, (0, dp - d))
        if v0 is not None:
            v0 = jnp.pad(v0, (0, dp - d))
    n_blocks = dp // blk

    tauv2 = jnp.asarray(tau_v, jnp.float32).reshape(1, 1)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    v0 = (
        jnp.zeros((1, dp), jnp.float32)
        if v0 is None
        else v0.reshape(1, dp).astype(jnp.float32)
    )

    out, s, norms = pl.pallas_call(
        functools.partial(_fused_body, False),
        grid=(n_iters + 2, n_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n, 1), lambda i, b: (0, 0)),
            pl.BlockSpec((n, blk), lambda i, b: (0, b)),
            pl.BlockSpec((1, blk), lambda i, b: (0, b)),
            pl.BlockSpec((1, blk), lambda i, b: (0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk), lambda i, b: (0, b)),
            pl.BlockSpec((n, 1), lambda i, b: (0, 0)),
            pl.BlockSpec((n, 1), lambda i, b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, dp), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(_pad_taus(taus, n_iters), tauv2, w2, xs, v0, z.reshape(1, dp))
    return out[0, :d], s[:, 0], norms[:, 0]


def butterfly_clip_fused_pallas(
    parts, taus, z, tau_v=None, weights=None, v0=None, *,
    block: int = DEFAULT_BLOCK, interpret: bool = True,
):
    """All-partition fused ButterflyClip: the whole robust aggregation AND
    the Alg. 6 broadcast tables in ONE pallas_call of n_iters + 2 passes.

    parts: (n_parts, n_peers, part); z: (n_parts, part).
    v0: optional (n_parts, part) warm start (previous aggregate).
    Returns (agg (n_parts, part), s (n_parts, n), norms (n_parts, n)) f32.
    """
    n_parts, n, d = parts.shape
    n_iters = int(taus.shape[0])
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if tau_v is None:
        tau_v = taus[-1]
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        parts = jnp.pad(parts, ((0, 0), (0, 0), (0, dp - d)))
        z = jnp.pad(z, ((0, 0), (0, dp - d)))
        if v0 is not None:
            v0 = jnp.pad(v0, ((0, 0), (0, dp - d)))
    n_blocks = dp // blk

    tauv2 = jnp.asarray(tau_v, jnp.float32).reshape(1, 1)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    v0 = (
        jnp.zeros((n_parts, 1, dp), jnp.float32)
        if v0 is None
        else v0.astype(jnp.float32).reshape(n_parts, 1, dp)
    )

    out, s, norms = pl.pallas_call(
        functools.partial(_fused_body, True),
        grid=(n_parts, n_iters + 2, n_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n, 1), lambda p, i, b: (0, 0)),
            pl.BlockSpec((1, n, blk), lambda p, i, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, i, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, i, b: (p, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk), lambda p, i, b: (p, 0, b)),
            pl.BlockSpec((1, 1, n), lambda p, i, b: (p, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda p, i, b: (p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_parts, 1, dp), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(_pad_taus(taus, n_iters), tauv2, w2, parts, v0,
      z.reshape(n_parts, 1, dp))
    return out[:, 0, :d], s[:, 0], norms[:, 0]


def butterfly_clip_fused_dequant_pallas(
    qs, scales, taus, z, tau_v=None, weights=None, v0=None, *,
    block: int = DEFAULT_BLOCK, interpret: bool = True,
):
    """The fused ButterflyClip aggregation + tables over WIRE payloads: qs
    stays int8/bf16 in HBM for all n_iters + 2 passes and is dequantized
    in-register against the per-(partition, peer) sidecar scales — the
    ``compressed:butterfly_clip`` hot path (≈4× fewer HBM bytes per pass
    for int8).

    qs: (n_parts, n_peers, part) wire dtype; scales: (n_parts, n_peers)
    f32 (ship 1s for bf16); z: (n_parts, part); v0: optional (n_parts,
    part) f32 warm start (a broadcast value, not a wire payload).
    Returns (agg (n_parts, part), s (n_parts, n), norms (n_parts, n)) f32.

    Tiling: the qs block (1, n, blk) keeps the full peer axis, so the
    sublane dim equals the array dim and the wire dtype's tighter native
    tile minima are satisfied; scales use the (n_parts, n, 1) singleton-
    lane layout of the adaptive step kernel's sq operand (DESIGN.md).
    """
    n_parts, n, d = qs.shape
    n_iters = int(taus.shape[0])
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    if tau_v is None:
        tau_v = taus[-1]
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        qs = jnp.pad(qs, ((0, 0), (0, 0), (0, dp - d)))  # wire zeros: exact
        z = jnp.pad(z, ((0, 0), (0, dp - d)))
        if v0 is not None:
            v0 = jnp.pad(v0, ((0, 0), (0, dp - d)))
    n_blocks = dp // blk

    tauv2 = jnp.asarray(tau_v, jnp.float32).reshape(1, 1)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    sc3 = scales.reshape(n_parts, n, 1).astype(jnp.float32)
    v0 = (
        jnp.zeros((n_parts, 1, dp), jnp.float32)
        if v0 is None
        else v0.astype(jnp.float32).reshape(n_parts, 1, dp)
    )

    out, s, norms = pl.pallas_call(
        functools.partial(_fused_dequant_body, True),
        grid=(n_parts, n_iters + 2, n_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n, 1), lambda p, i, b: (0, 0)),
            pl.BlockSpec((1, n, 1), lambda p, i, b: (p, 0, 0)),
            pl.BlockSpec((1, n, blk), lambda p, i, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, i, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, i, b: (p, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk), lambda p, i, b: (p, 0, b)),
            pl.BlockSpec((1, 1, n), lambda p, i, b: (p, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda p, i, b: (p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_parts, 1, dp), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(_pad_taus(taus, n_iters), tauv2, w2, sc3, qs, v0,
      z.reshape(n_parts, 1, dp))
    return out[:, 0, :d], s[:, 0], norms[:, 0]


# ===========================================================================
# Adaptive early-exit driver: ONE clip iteration per kernel invocation, the
# incremental-norm recurrence carried BETWEEN invocations, a host-level (but
# fully jitted) lax.while_loop deciding whether the next iteration runs.
#
#   prologue (jnp)     sq_i := ||x_i - v_0||^2 per partition  (1 pass of x)
#   while ||dv|| > tol _adaptive_step_kernel: cw from sq, v += upd,
#     and it < cap       sq := sum_b ||diff_b - upd_b||^2     (1 pass of x)
#   epilogue           verify_tables_batched_pallas against the FINAL v,
#                      exactly once                           (1 pass of x)
#
# Total: iters_run + 2 HBM passes of the stacked partitions — the fused
# fixed-budget kernel's pass structure, but the iteration count now adapts
# to the data (warm starts routinely land it at 1-3 instead of the
# protocol-default 60). Converged partitions are frozen via select, exactly
# the vmap(while_loop) batching rule, so results match per-partition
# independent adaptive loops (and, at tol=0, the fixed-budget kernel).
# ===========================================================================
def _adaptive_step_kernel(
    tau_ref, w_ref, xs_ref, vin_ref, sqin_ref, vout_ref, sqout_ref,
    sq_ref, cw_ref,
):
    """Grid (n_parts, n_blocks): one CenteredClip iteration for every
    partition. sqin holds ||x_i - v_in||^2 (the recurrence state from the
    previous invocation); emits v_out = v_in + upd and the NEXT iteration's
    squared norms. v carries a singleton sublane dim, sq a singleton lane
    dim ((n_parts, n, 1) with (1, n, 1) blocks — the (n, 1) layout of the
    w operand, legal native tiles per DESIGN.md)."""
    blk = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(blk == 0)
    def _weights():
        tau = tau_ref[0, 0]
        norms = jnp.sqrt(jnp.maximum(sqin_ref[0], 1e-30))
        cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
        cw = jnp.where(jnp.isinf(tau), 1.0, cw)
        cw_ref[...] = cw * w_ref[...].astype(jnp.float32)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    wsum = jnp.maximum(jnp.sum(w_ref[...].astype(jnp.float32)), 1e-30)
    diff = xs_ref[0].astype(jnp.float32) - vin_ref[0].astype(jnp.float32)
    upd = jnp.sum(cw_ref[...] * diff, axis=0, keepdims=True) / wsum
    vout_ref[0] = vin_ref[0].astype(jnp.float32) + upd
    nd = diff - upd  # x_i - v_{l+1} restricted to this block
    sq_ref[...] += jnp.sum(nd * nd, axis=1, keepdims=True)

    @pl.when(blk == nb - 1)
    def _emit():
        sqout_ref[0] = sq_ref[...].reshape(sqout_ref.shape[1:])


def adaptive_clip_step_pallas(
    parts, v, sq, tau, weights=None, *,
    block: int = DEFAULT_BLOCK, interpret: bool = True,
):
    """One all-partition CenteredClip iteration (single HBM pass of parts).

    parts: (n_parts, n, part) (pre-padded to a block multiple);
    v: (n_parts, 1, part); sq: (n_parts, n, 1) = ||x_i - v||^2.
    Returns (v_new, sq_new) in the same layouts.
    """
    n_parts, n, dp = parts.shape
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    blk = min(block, max(128, dp))
    if dp % blk:
        raise ValueError(
            f"adaptive step kernel needs part dim {dp} pre-padded to a "
            f"multiple of block {blk} (the while driver pads before looping)"
        )
    n_blocks = dp // blk

    tau2 = jnp.asarray(tau, jnp.float32).reshape(1, 1)
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    return pl.pallas_call(
        _adaptive_step_kernel,
        grid=(n_parts, n_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n, 1), lambda p, b: (0, 0)),
            pl.BlockSpec((1, n, blk), lambda p, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, b: (p, 0, b)),
            pl.BlockSpec((1, n, 1), lambda p, b: (p, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk), lambda p, b: (p, 0, b)),
            pl.BlockSpec((1, n, 1), lambda p, b: (p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_parts, 1, dp), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, n, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(tau2, w2, parts, v, sq)


def butterfly_clip_adaptive_pallas(
    parts, tau, tol, max_iters: int, weights=None, v0=None, *,
    block: int = DEFAULT_BLOCK, interpret: bool = True,
):
    """Early-exit all-partition CenteredClip: iterate the one-pass step
    kernel under ``lax.while_loop`` until every partition's update norm is
    <= tol (or ``max_iters``). Converged partitions freeze (select), so
    per-partition results equal independent adaptive loops.

    parts: (n_parts, n_peers, part). Returns (agg (n_parts, part) f32,
    iters (n_parts,) i32). The verification-table epilogue is NOT included
    — callers (kernels/ops.butterfly_clip_fused_adaptive_op) run it exactly
    once against the returned aggregate.
    """
    n_parts, n, d = parts.shape
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        parts = jnp.pad(parts, ((0, 0), (0, 0), (0, dp - d)))
        if v0 is not None:
            v0 = jnp.pad(v0, ((0, 0), (0, dp - d)))
    parts = parts.astype(jnp.float32)

    v = (
        jnp.zeros((n_parts, 1, dp), jnp.float32)
        if v0 is None
        else v0.astype(jnp.float32).reshape(n_parts, 1, dp)
    )
    # prologue: the recurrence state for the starting iterate (1 pass of x)
    sq = jnp.sum((parts - v) ** 2, axis=-1, keepdims=True)  # (n_parts, n, 1)
    tol2 = jnp.float32(tol) ** 2

    def cond(carry):
        _, _, d2, it, _ = carry
        return jnp.logical_and((d2 > tol2).any(), it < max_iters)

    def body(carry):
        v, sq, d2, it, iters = carry
        v_new, sq_new = adaptive_clip_step_pallas(
            parts, v, sq, tau, weights, block=blk, interpret=interpret
        )
        active = d2 > tol2  # (n_parts,) — frozen partitions keep their carry
        upd2 = ((v_new - v) ** 2).sum(axis=(1, 2))
        v = jnp.where(active[:, None, None], v_new, v)
        sq = jnp.where(active[:, None, None], sq_new, sq)
        d2 = jnp.where(active, upd2, d2)
        return v, sq, d2, it + 1, iters + active.astype(jnp.int32)

    v, _, _, _, iters = jax.lax.while_loop(
        cond,
        body,
        (v, sq, jnp.full((n_parts,), jnp.inf, jnp.float32), jnp.int32(0),
         jnp.zeros((n_parts,), jnp.int32)),
    )
    return v[:, 0, :d], iters


# ===========================================================================
# Fused verification-tables kernel (single HBM pass)
# ===========================================================================
def _vt_kernel(tau_ref, xs_ref, v_ref, z_ref, s_ref, norm_ref, dot_ref, sq_ref):
    """Grid (n_blocks,). Accumulate per-peer dot & sqnorm; epilogue on last."""
    blk = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(blk == 0)
    def _reset():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    diff = xs_ref[...].astype(jnp.float32) - v_ref[...].astype(jnp.float32)
    zb = z_ref[...].astype(jnp.float32)
    dot_ref[...] += jnp.sum(diff * zb, axis=1, keepdims=True)
    sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(blk == nb - 1)
    def _epilogue():
        tau = tau_ref[0, 0]
        norms = jnp.sqrt(jnp.maximum(sq_ref[...], 0.0))
        cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
        s_ref[...] = cw * dot_ref[...]
        norm_ref[...] = norms


def verify_tables_pallas(
    xs, v, z, tau, *, block: int = DEFAULT_BLOCK, interpret: bool = True
):
    """Fused s_i = <z, clip(x_i - v)>, norm_i = ||x_i - v|| in one pass.

    xs: (n, d); v, z: (d,). Returns (s (n,), norms (n,)).
    """
    n, d = xs.shape
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        xs = jnp.pad(xs, ((0, 0), (0, dp - d)))
        v = jnp.pad(v, (0, dp - d))
        z = jnp.pad(z, (0, dp - d))
    n_blocks = dp // blk

    tau2 = jnp.asarray(tau, jnp.float32).reshape(1, 1)
    s, norms = pl.pallas_call(
        _vt_kernel,
        grid=(n_blocks,),
        in_specs=[
            # scalar: whole (1, 1) array in SMEM — a (1, 1) VMEM block is
            # an illegal sub-tile on real TPUs (the PR 2 bug class)
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n, blk), lambda b: (0, b)),
            pl.BlockSpec((1, blk), lambda b: (0, b)),
            pl.BlockSpec((1, blk), lambda b: (0, b)),
        ],
        out_specs=[
            pl.BlockSpec((n, 1), lambda b: (0, 0)),
            pl.BlockSpec((n, 1), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(tau2, xs, v.reshape(1, dp), z.reshape(1, dp))
    return s[:, 0], norms[:, 0]


def _vt_batched_kernel(
    tau_ref, xs_ref, v_ref, z_ref, s_ref, norm_ref, dot_ref, sq_ref
):
    """Grid (n_parts, n_blocks) — verify_tables for every partition in one
    pallas_call (the recompute path when the aggregate changed after the
    fused kernel ran, e.g. a corrupted aggregator). v/z/s/norm carry a
    singleton sublane dim for legal native TPU tiles (see _bcc_kernel)."""
    blk = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(blk == 0)
    def _reset():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    diff = xs_ref[0].astype(jnp.float32) - v_ref[0].astype(jnp.float32)
    zb = z_ref[0].astype(jnp.float32)
    dot_ref[...] += jnp.sum(diff * zb, axis=1, keepdims=True)
    sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(blk == nb - 1)
    def _epilogue():
        tau = tau_ref[0, 0]
        norms = jnp.sqrt(jnp.maximum(sq_ref[...], 0.0))
        cw = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
        s_ref[0] = (cw * dot_ref[...]).reshape(s_ref.shape[1:])
        norm_ref[0] = norms.reshape(norm_ref.shape[1:])


def _dg_batched_kernel(xs_ref, v_ref, z_ref, s_ref, norm_ref, dot_ref, sq_ref):
    """Grid (n_parts, n_blocks) — generalized contribution digests for every
    partition in one pallas_call: s_i = <z, x_i - v>, norm_i = ||x_i - v||.
    Like _vt_batched_kernel minus the clip weight (wrapped coordinatewise
    aggregators carry no tau). v/z/s/norm carry a singleton sublane dim for
    legal native TPU tiles (see _bcc_kernel)."""
    blk = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(blk == 0)
    def _reset():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    diff = xs_ref[0].astype(jnp.float32) - v_ref[0].astype(jnp.float32)
    zb = z_ref[0].astype(jnp.float32)
    dot_ref[...] += jnp.sum(diff * zb, axis=1, keepdims=True)
    sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(blk == nb - 1)
    def _epilogue():
        s_ref[0] = dot_ref[...].reshape(s_ref.shape[1:])
        norm_ref[0] = jnp.sqrt(jnp.maximum(sq_ref[...], 0.0)).reshape(
            norm_ref.shape[1:]
        )


def digest_tables_batched_pallas(
    parts, agg, z, *, block: int = DEFAULT_BLOCK, interpret: bool = True
):
    """All-partition generalized digests in one pass of the stacked parts.

    parts: (n_parts, n, part); agg, z: (n_parts, part).
    Returns (s (n_parts, n), norms (n_parts, n)).
    """
    n_parts, n, d = parts.shape
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        parts = jnp.pad(parts, ((0, 0), (0, 0), (0, dp - d)))
        agg = jnp.pad(agg, ((0, 0), (0, dp - d)))
        z = jnp.pad(z, ((0, 0), (0, dp - d)))
    n_blocks = dp // blk

    s, norms = pl.pallas_call(
        _dg_batched_kernel,
        grid=(n_parts, n_blocks),
        in_specs=[
            pl.BlockSpec((1, n, blk), lambda p, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, b: (p, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, n), lambda p, b: (p, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda p, b: (p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(parts, agg.reshape(n_parts, 1, dp), z.reshape(n_parts, 1, dp))
    return s[:, 0], norms[:, 0]


def _rows_digest_kernel(rows_ref, tau_ref, xs_ref, v_ref, z_ref, s_ref,
                        norm_ref, dot_ref, sq_ref):
    """Grid (k, n_blocks) — digests for the SAMPLED partitions rows[p] only
    (sampled-digest audit mode: k = m_validators * audit_k columns per step
    instead of all n_parts). The row ids ride the scalar-prefetch channel
    and were consumed by the BlockSpec index_maps — the body never touches
    them. tau_ref[0] > 0 applies the ButterflyClip clip weight (the sampled
    sibling of _vt_batched_kernel); 0 emits the plain contribution digests
    (_dg_batched_kernel), so one kernel serves every verifiable spec."""
    del rows_ref  # consumed by the index_maps
    blk = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(blk == 0)
    def _reset():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    diff = xs_ref[0].astype(jnp.float32) - v_ref[0].astype(jnp.float32)
    zb = z_ref[0].astype(jnp.float32)
    dot_ref[...] += jnp.sum(diff * zb, axis=1, keepdims=True)
    sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(blk == nb - 1)
    def _epilogue():
        tau = tau_ref[0]
        norms = jnp.sqrt(jnp.maximum(sq_ref[...], 0.0))
        cw = jnp.where(
            tau > 0.0, jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30)), 1.0
        )
        s_ref[0] = (cw * dot_ref[...]).reshape(s_ref.shape[1:])
        norm_ref[0] = norms.reshape(norm_ref.shape[1:])


def digest_tables_rows_pallas(
    parts, agg, z, rows, tau=0.0, *, block: int = DEFAULT_BLOCK,
    interpret: bool = True
):
    """Sampled-column digest tables in one pass of the SAMPLED partitions.

    parts: (n_parts, n, part); agg, z: (n_parts, part); rows: (k,) i32
    sampled partition ids; tau: scalar — > 0 applies the ButterflyClip clip
    weight min(1, tau/||diff||), 0 emits the plain verified:* digests.
    Returns (s (k, n), norms (k, n)), column p of the output = partition
    rows[p].

    The row ids are a scalar-prefetch operand (SMEM), so every BlockSpec
    index_map picks its partition block dynamically — HBM traffic is
    O(k * n * part), not O(n_parts * n * part): the kernel-side half of the
    sampled-digest cost model.
    """
    n_parts, n, d = parts.shape
    k = rows.shape[0]
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        parts = jnp.pad(parts, ((0, 0), (0, 0), (0, dp - d)))
        agg = jnp.pad(agg, ((0, 0), (0, dp - d)))
        z = jnp.pad(z, ((0, 0), (0, dp - d)))
    n_blocks = dp // blk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(k, n_blocks),
        in_specs=[
            pl.BlockSpec((1, n, blk), lambda p, b, rows, tau: (rows[p], 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, b, rows, tau: (rows[p], 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, b, rows, tau: (rows[p], 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, n), lambda p, b, rows, tau: (p, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda p, b, rows, tau: (p, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
    )
    s, norms = pl.pallas_call(
        _rows_digest_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((k, 1, n), jnp.float32),
            jax.ShapeDtypeStruct((k, 1, n), jnp.float32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(rows, jnp.int32),
        jnp.asarray(tau, jnp.float32).reshape(1),
        parts,
        agg.reshape(n_parts, 1, dp),
        z.reshape(n_parts, 1, dp),
    )
    return s[:, 0], norms[:, 0]


def _md_kernel(w_ref, xs_ref, z_ref, out_ref, s_ref, norm_ref, dot_ref,
               sq_ref, *, scales_ref=None):
    """Grid (n_parts, 2, n_blocks) — fused weighted mean + digest epilogue.

    Phase 0 writes the per-partition weighted mean block-locally (the mean
    decomposes over lanes — no cross-block scratch needed); phase 1 streams
    x once more against the finished aggregate accumulating the per-peer
    digest dot and squared norm, emitting both tables on the last block.
    2 HBM passes of x, zero materialized (n, d) temporaries.

    scales_ref (dequant variant): xs arrives in its wire dtype (int8/bf16)
    and both phases see ``xs.astype(f32) * scale`` — the exact formula of
    core.compression.dequantize, so aggregate and digests are computed over
    the dequantized-from-wire values (compressed:verified:mean)."""
    phase = pl.program_id(1)
    blk = pl.program_id(2)
    nb = pl.num_programs(2)
    xs = xs_ref[0].astype(jnp.float32)
    if scales_ref is not None:  # in-register dequantize of the wire payload
        xs = xs * scales_ref[0]

    @pl.when(phase == 0)
    def _aggregate():
        w = w_ref[...].astype(jnp.float32)
        wsum = jnp.maximum(jnp.sum(w), 1e-30)
        out_ref[0] = jnp.sum(w * xs, axis=0, keepdims=True) / wsum

    @pl.when(phase == 1)
    def _digest():
        @pl.when(blk == 0)
        def _reset():
            dot_ref[...] = jnp.zeros_like(dot_ref)
            sq_ref[...] = jnp.zeros_like(sq_ref)

        diff = xs - out_ref[0]
        dot_ref[...] += jnp.sum(
            diff * z_ref[0].astype(jnp.float32), axis=1, keepdims=True
        )
        sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

        @pl.when(blk == nb - 1)
        def _epilogue():
            s_ref[0] = dot_ref[...].reshape(s_ref.shape[1:])
            norm_ref[0] = jnp.sqrt(jnp.maximum(sq_ref[...], 0.0)).reshape(
                norm_ref.shape[1:]
            )


def mean_digest_fused_pallas(
    parts, z, weights=None, *, block: int = DEFAULT_BLOCK, interpret: bool = True
):
    """verified:mean's fused aggregation + digest tables in one pallas_call.

    parts: (n_parts, n, part); z: (n_parts, part); weights: (n,).
    Returns (agg (n_parts, part), s (n_parts, n), norms (n_parts, n)).
    """
    n_parts, n, d = parts.shape
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        parts = jnp.pad(parts, ((0, 0), (0, 0), (0, dp - d)))
        z = jnp.pad(z, ((0, 0), (0, dp - d)))
    n_blocks = dp // blk

    w2 = weights.reshape(n, 1).astype(jnp.float32)
    agg, s, norms = pl.pallas_call(
        _md_kernel,
        grid=(n_parts, 2, n_blocks),
        in_specs=[
            pl.BlockSpec((n, 1), lambda p, ph, b: (0, 0)),
            pl.BlockSpec((1, n, blk), lambda p, ph, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, ph, b: (p, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk), lambda p, ph, b: (p, 0, b)),
            pl.BlockSpec((1, 1, n), lambda p, ph, b: (p, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda p, ph, b: (p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_parts, 1, dp), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w2, parts, z.reshape(n_parts, 1, dp))
    return agg[:, 0, :d], s[:, 0], norms[:, 0]


def _md_dequant_kernel(
    w_ref, scales_ref, xs_ref, z_ref, out_ref, s_ref, norm_ref, dot_ref,
    sq_ref,
):
    """Positional-ref adapter: sidecar scales between w and the wire xs."""
    _md_kernel(
        w_ref, xs_ref, z_ref, out_ref, s_ref, norm_ref, dot_ref, sq_ref,
        scales_ref=scales_ref,
    )


def mean_digest_fused_dequant_pallas(
    qs, scales, z, weights=None, *,
    block: int = DEFAULT_BLOCK, interpret: bool = True,
):
    """compressed:verified:mean's fused aggregation + digests over WIRE
    payloads: qs stays int8/bf16 in HBM for both passes, dequantized
    in-register against the sidecar scales (see
    butterfly_clip_fused_dequant_pallas for the tiling argument).

    qs: (n_parts, n, part) wire dtype; scales: (n_parts, n) f32 (1s for
    bf16); z: (n_parts, part).
    Returns (agg (n_parts, part), s (n_parts, n), norms (n_parts, n)).
    """
    n_parts, n, d = qs.shape
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        qs = jnp.pad(qs, ((0, 0), (0, 0), (0, dp - d)))  # wire zeros: exact
        z = jnp.pad(z, ((0, 0), (0, dp - d)))
    n_blocks = dp // blk

    w2 = weights.reshape(n, 1).astype(jnp.float32)
    sc3 = scales.reshape(n_parts, n, 1).astype(jnp.float32)
    agg, s, norms = pl.pallas_call(
        _md_dequant_kernel,
        grid=(n_parts, 2, n_blocks),
        in_specs=[
            pl.BlockSpec((n, 1), lambda p, ph, b: (0, 0)),
            pl.BlockSpec((1, n, 1), lambda p, ph, b: (p, 0, 0)),
            pl.BlockSpec((1, n, blk), lambda p, ph, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, ph, b: (p, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk), lambda p, ph, b: (p, 0, b)),
            pl.BlockSpec((1, 1, n), lambda p, ph, b: (p, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda p, ph, b: (p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_parts, 1, dp), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w2, sc3, qs, z.reshape(n_parts, 1, dp))
    return agg[:, 0, :d], s[:, 0], norms[:, 0]


def verify_tables_batched_pallas(
    parts, agg, z, tau, *, block: int = DEFAULT_BLOCK, interpret: bool = True
):
    """All-partition verification tables in one pass of the stacked parts.

    parts: (n_parts, n, part); agg, z: (n_parts, part).
    Returns (s (n_parts, n), norms (n_parts, n)).
    """
    n_parts, n, d = parts.shape
    blk = min(block, max(128, d))
    dp = -(-d // blk) * blk
    if dp != d:
        parts = jnp.pad(parts, ((0, 0), (0, 0), (0, dp - d)))
        agg = jnp.pad(agg, ((0, 0), (0, dp - d)))
        z = jnp.pad(z, ((0, 0), (0, dp - d)))
    n_blocks = dp // blk

    tau2 = jnp.asarray(tau, jnp.float32).reshape(1, 1)
    s, norms = pl.pallas_call(
        _vt_batched_kernel,
        grid=(n_parts, n_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n, blk), lambda p, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, b: (p, 0, b)),
            pl.BlockSpec((1, 1, blk), lambda p, b: (p, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, n), lambda p, b: (p, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda p, b: (p, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
            jax.ShapeDtypeStruct((n_parts, 1, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, 1), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(tau2, parts, agg.reshape(n_parts, 1, dp), z.reshape(n_parts, 1, dp))
    return s[:, 0], norms[:, 0]
