"""ChatGLM3-6B dense decoder [arXiv:2406.12793].

28 layers, d_model=4096, 32 heads (GQA kv=2), d_ff=13696, vocab=65024,
2d RoPE (rotary applied to half of each head dim — the GLM convention).
"""
from repro.configs.base import ModelConfig, SA

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    pattern=(SA,),
    n_repeats=28,
    qkv_bias=True,  # GLM uses bias on QKV
    rope="half",
    rope_theta=10000.0,
    norm="rmsnorm",
    act="silu",
    glu=True,
    sub_quadratic=False,
    source="arXiv:2406.12793",
)
