"""Qwen1.5-110B dense decoder [hf:Qwen/Qwen1.5-0.5B family card, scaled entry].

80 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=49152, vocab=152064,
QKV bias (the Qwen1.5 signature).
"""
from repro.configs.base import ModelConfig, SA

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    pattern=(SA,),
    n_repeats=80,
    qkv_bias=True,
    rope="standard",
    rope_theta=1000000.0,
    norm="rmsnorm",
    act="silu",
    glu=True,
    sub_quadratic=False,
    source="hf:Qwen/Qwen1.5-0.5B",
)
