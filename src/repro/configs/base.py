"""Model / input-shape configuration for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``: a composable
stack of ``LayerSpec`` blocks (prefix + repeated pattern + suffix) so that the
model builder can ``lax.scan`` over the homogeneous repeated pattern while
keeping heterogeneous stacks (local:global attention mixes, hybrid
RG-LRU/attention, dense-then-MoE) exact.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class LayerSpec:
    """One residual block of the stack.

    mixer: "attn_full" | "attn_local" | "attn_cross" | "mla" | "ssm" | "rglru"
    mlp:   "dense" | "moe" | "none"
    cross: if True, an additional cross-attention sub-block follows the
           self-mixer (encoder-decoder decoders, e.g. Whisper).
    """

    mixer: str = "attn_full"
    mlp: str = "dense"
    cross: bool = False

    def kind(self) -> tuple:
        return (self.mixer, self.mlp, self.cross)


# Short-hands used by the per-arch config modules.
SA = LayerSpec("attn_full", "dense")
LSA = LayerSpec("attn_local", "dense")
XA = LayerSpec("attn_cross", "dense")
SA_MOE = LayerSpec("attn_full", "moe")
MLA_D = LayerSpec("mla", "dense")
MLA_MOE = LayerSpec("mla", "moe")
SSM = LayerSpec("ssm", "none")
RG = LayerSpec("rglru", "dense")
DEC_XA = LayerSpec("attn_full", "dense", cross=True)  # self+cross+mlp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- layer stack -----------------------------------------------------
    prefix: tuple = ()
    pattern: tuple = ()
    n_repeats: int = 0
    suffix: tuple = ()
    share_pattern_params: bool = False  # ALBERT-style cross-layer sharing

    # --- attention flavour ------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: str = "standard"  # standard | half (ChatGLM 2d) | none
    rope_theta: float = 10000.0
    window: int = 1024  # sliding window for attn_local
    learned_pos: bool = False  # learned absolute positions (Whisper, ALBERT)
    max_position: int = 524288

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # --- MLA (DeepSeek) -----------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    ssm_conv: int = 4

    # --- RG-LRU (Griffin / RecurrentGemma) ------------------------------------
    rglru_width: int = 0  # defaults to d_model when 0
    rglru_conv: int = 4

    # --- encoder / modality stub ----------------------------------------------
    n_encoder_layers: int = 0
    encoder_len: int = 0  # frames (audio) or patches (vision)
    encoder_dim: int = 0  # stub embedding dim fed to the projector

    # --- misc -------------------------------------------------------------
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"
    glu: bool = True
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    sub_quadratic: bool = False  # eligible for the long_500k decode shape
    dtype: str = "bfloat16"
    source: str = ""

    # ----------------------------------------------------------------------
    @property
    def layers(self) -> tuple:
        return self.prefix + self.pattern * self.n_repeats + self.suffix

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def has_encoder(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def is_decoder_only(self) -> bool:
        return not self.has_encoder

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim if self.ssm_state else 0

    def validate(self) -> None:
        assert self.n_layers > 0, self.name
        for spec in self.layers:
            if spec.mlp == "moe":
                assert self.n_experts > 0 and self.top_k > 0, self.name
            if spec.mixer == "mla":
                assert self.kv_lora_rank > 0, self.name
            if spec.mixer == "ssm":
                assert self.ssm_state > 0, self.name
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """A 2-layer, d_model<=512, <=4-expert smoke variant of the same family.

    Keeps one instance of each distinct block kind (up to 2) so the reduced
    model still exercises the family's structural features (e.g. local+global
    attention for gemma3, RG-LRU+attention for recurrentgemma, dense+MoE MLA
    for deepseek).
    """
    seen, picked = set(), []
    for spec in cfg.layers:
        if spec.kind() not in seen:
            seen.add(spec.kind())
            picked.append(spec)
        if len(picked) == 2:
            break
    while len(picked) < 2:
        picked.append(picked[-1])

    n_kv = max(1, (4 * cfg.n_kv_heads) // max(cfg.n_heads, 1)) if cfg.n_heads else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=n_kv,
        head_dim=64 if cfg.n_heads else cfg.head_dim,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        prefix=tuple(picked),
        pattern=(),
        n_repeats=0,
        suffix=(),
        n_experts=min(cfg.n_experts, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        d_ff_expert=128 if cfg.d_ff_expert else 0,
        capacity_factor=4.0,  # no capacity drops at smoke scale

        kv_lora_rank=64 if cfg.kv_lora_rank else 0,
        q_lora_rank=0,
        rope_head_dim=32 if cfg.kv_lora_rank else cfg.rope_head_dim,
        nope_head_dim=64 if cfg.kv_lora_rank else cfg.nope_head_dim,
        v_head_dim=64 if cfg.kv_lora_rank else cfg.v_head_dim,
        ssm_state=64 if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=16,
        rglru_width=256 if cfg.rglru_width else 0,
        window=32,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_len=64 if cfg.encoder_len else 0,
        encoder_dim=128 if cfg.encoder_dim else 0,
        max_position=4096,
        dtype="float32",
    )


# ===========================================================================
# Input shapes (assigned)
# ===========================================================================
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k only for sub-quadratic archs (see DESIGN.md §4)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
