"""Whisper-small encoder-decoder [arXiv:2212.04356].

12 encoder + 12 decoder layers, d_model=768, 12 heads (kv=12), d_ff=3072,
vocab=51865. The mel-spectrogram + conv frontend is a STUB: ``input_specs``
provides post-conv frame embeddings (batch, 1500, 768). Decoder layers are
self-attn + cross-attn + MLP; GELU, LayerNorm, learned positions.
"""
from repro.configs.base import ModelConfig, DEC_XA

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    pattern=(DEC_XA,),
    n_repeats=12,
    rope="none",
    learned_pos=True,
    n_encoder_layers=12,
    encoder_len=1500,
    encoder_dim=768,  # post-conv frontend width == d_model
    norm="layernorm",
    act="gelu",
    glu=False,
    tie_embeddings=True,  # Whisper ties the decoder embedding and LM head
    sub_quadratic=False,
    max_position=32768,  # largest applicable shape (long_500k is skipped)
    source="arXiv:2212.04356",
)
