"""Llama-3.2-11B-Vision language backbone [hf:meta-llama/Llama-3.2-11B-Vision].

40 decoder layers, d_model=4096, 32 heads (GQA kv=8), d_ff=14336,
vocab=128256, with gated cross-attention image layers every 5th layer
(8 cross-attn layers total). The ViT/SigLIP vision encoder + projector is a
STUB: ``input_specs`` provides pre-computed patch embeddings of shape
(batch, 1600, 7680) consumed by a linear projector.
"""
from repro.configs.base import ModelConfig, SA, XA

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    pattern=(SA, SA, SA, SA, XA),
    n_repeats=8,  # 40 layers
    rope="standard",
    rope_theta=500000.0,
    encoder_len=1600,   # patch tokens (stubbed vision tower output)
    encoder_dim=7680,   # Llama-3.2 vision_output_dim before the projector
    norm="rmsnorm",
    act="silu",
    glu=True,
    sub_quadratic=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
