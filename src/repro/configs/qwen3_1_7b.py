"""Qwen3-1.7B dense decoder [hf:Qwen/Qwen3-8B family card, 1.7B entry].

28 layers, d_model=2048, 16 heads (GQA kv=8), head_dim=128, d_ff=6144,
vocab=151936, with QK-norm (the Qwen3 signature).
"""
from repro.configs.base import ModelConfig, SA

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    pattern=(SA,),
    n_repeats=28,
    qk_norm=True,
    rope="standard",
    rope_theta=1000000.0,
    norm="rmsnorm",
    act="silu",
    glu=True,
    tie_embeddings=True,
    sub_quadratic=False,
    source="hf:Qwen/Qwen3-8B",
)
