"""ALBERT-large — the paper's own §4.2 pretraining subject [arXiv:1909.11942].

24 transformer layers with a SINGLE shared parameter set
(share_pattern_params=True), d_model=1024, 16 heads, d_ff=4096, GELU,
LayerNorm, learned positions. Used by examples/albert_pretrain.py with the
LAMB optimizer + BTARD-Clipped-SGD, mirroring the paper's Figure 4 setup.
"""
from repro.configs.base import ModelConfig, SA

CONFIG = ModelConfig(
    name="albert-large",
    family="dense",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=30000,
    pattern=(SA,),
    n_repeats=24,
    share_pattern_params=True,
    rope="none",
    learned_pos=True,
    norm="layernorm",
    act="gelu",
    glu=False,
    sub_quadratic=False,
    max_position=4096,
    source="arXiv:1909.11942 (paper §4.2)",
)
