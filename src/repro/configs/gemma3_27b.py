"""Gemma-3-27B dense decoder [hf:google/gemma-3-1b-pt family card, 27B entry].

62 layers, d_model=5376, 32 heads (GQA kv=16), head_dim=128, d_ff=21504,
vocab=262144, 5:1 local:global attention (window 1024), 128k context.
Sub-quadratic eligible for long_500k: 5/6 of layers are sliding-window and
global layers decode linearly against the cache.
"""
from repro.configs.base import ModelConfig, SA, LSA

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    # 62 = 2 local + 10 * (5 local + 1 global)
    prefix=(LSA, LSA),
    pattern=(LSA, LSA, LSA, LSA, LSA, SA),
    n_repeats=10,
    qk_norm=True,
    rope="standard",
    rope_theta=1000000.0,
    window=1024,
    norm="rmsnorm",
    act="gelu",
    glu=True,
    logit_softcap=30.0,
    tie_embeddings=True,
    sub_quadratic=True,
    source="hf:google/gemma-3-1b-pt",
)
