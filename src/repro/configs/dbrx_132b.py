"""DBRX-132B fine-grained MoE [hf:databricks/dbrx-base].

40 layers, d_model=6144, 48 heads (GQA kv=8), vocab=100352,
16 experts top-4, expert d_ff=10752, no shared experts.
"""
from repro.configs.base import ModelConfig, SA_MOE

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,  # all blocks are MoE
    vocab_size=100352,
    pattern=(SA_MOE,),
    n_repeats=40,
    n_experts=16,
    n_shared_experts=0,
    top_k=4,
    d_ff_expert=10752,
    qkv_bias=False,
    rope="standard",
    rope_theta=500000.0,
    norm="layernorm",
    act="silu",
    glu=True,
    sub_quadratic=False,
    source="hf:databricks/dbrx-base",
)
