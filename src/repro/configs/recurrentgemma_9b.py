"""RecurrentGemma-9B (Griffin) hybrid [arXiv:2402.19427].

38 blocks, d_model=4096, 16 heads local attention (MQA kv=1, window 2048),
d_ff=12288, vocab=256000, RG-LRU recurrent blocks : local-attention blocks
in a 2:1 ratio (pattern rec,rec,attn). Attention-free recurrence makes it
sub-quadratic (long_500k eligible).
"""
from repro.configs.base import ModelConfig, RG, LSA

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    # 38 = 2 rec + 12 * (rec, rec, attn)
    prefix=(RG, RG),
    pattern=(RG, RG, LSA),
    n_repeats=12,
    rope="standard",
    window=2048,
    rglru_width=4096,
    norm="rmsnorm",
    act="gelu",
    glu=True,
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2402.19427",
)
