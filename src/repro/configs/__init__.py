"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Each assigned architecture has one module; ids use the assignment spelling.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    LayerSpec,
    ModelConfig,
    reduce_config,
    shape_applicable,
)

_ARCH_MODULES = {
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "qwen1.5-110b": "qwen1_5_110b",
    "gemma3-27b": "gemma3_27b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-2.7b": "mamba2_2_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "whisper-small": "whisper_small",
    "dbrx-132b": "dbrx_132b",
    "qwen3-1.7b": "qwen3_1_7b",
    "chatglm3-6b": "chatglm3_6b",
    # the paper's own §4.2 model (not part of the assigned 10)
    "albert-large": "albert_large",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if k != "albert-large")


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return cfg


def list_archs(include_extra: bool = False):
    return list(_ARCH_MODULES) if include_extra else list(ASSIGNED_ARCHS)
