"""DeepSeek-V2-Lite (16B total / 2.4B active) MoE with MLA [arXiv:2405.04434].

27 layers, d_model=2048, 16 heads MLA (kv_lora_rank=512, rope_head=64,
nope_head=128, v_head=128), vocab=102400. Layer 0 is a dense MLP
(d_ff=10944); layers 1..26 are MoE with 2 shared + 64 routed experts, top-6,
expert d_ff=1408. NOTE: the assignment line says "160 routed"; the cited
model card (DeepSeek-V2-Lite) has 64 routed experts — we follow the card and
record the discrepancy here and in DESIGN.md.
"""
from repro.configs.base import ModelConfig, MLA_D, MLA_MOE

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,  # MLA: per-head latent, GQA kv=16 as assigned
    head_dim=128,
    d_ff=10944,  # dense layer-0 MLP (card value); expert FF below
    vocab_size=102400,
    prefix=(MLA_D,),
    pattern=(MLA_MOE,),
    n_repeats=26,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    kv_lora_rank=512,
    q_lora_rank=0,  # V2-Lite has no Q compression
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    rope="standard",
    rope_theta=10000.0,
    norm="rmsnorm",
    act="silu",
    glu=True,
    sub_quadratic=False,
    source="arXiv:2405.04434",
)
