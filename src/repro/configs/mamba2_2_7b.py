"""Mamba2-2.7B — attention-free SSD (state-space duality) [arXiv:2405.21060].

64 layers, d_model=2560, expand=2 (d_inner=5120), head_dim=64 (80 heads),
ssm_state=128, vocab=50280. No MLP blocks (d_ff=0) — the SSD mixer is the
whole block, as in the Mamba-2 paper. Fully sub-quadratic.
"""
from repro.configs.base import ModelConfig, SSM

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    pattern=(SSM,),
    n_repeats=64,
    rope="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_expand=2,
    ssm_conv=4,
    norm="rmsnorm",
    sub_quadratic=True,
    source="arXiv:2405.21060",
)
