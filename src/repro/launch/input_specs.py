"""ShapeDtypeStruct stand-ins + PartitionSpecs for every (arch x shape) pair.

Nothing here allocates: the dry-run lowers against these abstract values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import Model
from repro.sharding import batch_axes
from repro.sharding.specs import activation_spec


def abstract_batch(cfg, shape, kind=None):
    """Abstract model inputs for an InputShape."""
    kind = kind or shape.kind
    B, S = shape.global_batch, shape.seq_len
    if kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    elif kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    else:  # decode
        batch = {
            "token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
    if cfg.encoder_len and kind in ("train", "prefill"):
        batch["memory_raw"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_len, cfg.encoder_dim), jnp.bfloat16
        )
    return batch


def batch_specs(cfg, shape, kind=None):
    kind = kind or shape.kind
    b = activation_spec("batch")[0]
    if kind == "train":
        specs = {"tokens": P(b, None)}
    elif kind == "prefill":
        specs = {"tokens": P(b, None)}
    else:
        specs = {"token": P(b), "pos": P(b)}
    if cfg.encoder_len and kind in ("train", "prefill"):
        specs["memory_raw"] = P(b, None, None)
    return specs


def cache_specs(model: Model, shape, mesh):
    """PartitionSpecs for the KV/SSM/RG-LRU cache tree.

    decode_32k: batch >= data => shard batch; long_500k: batch=1 => shard the
    cache sequence dim over 'data' (distributed decode attention — XLA GSPMD
    turns the softmax over the sharded seq dim into partial reductions +
    all-reduce, flash-decode style).
    """
    n_batch_shards = 1
    for a in batch_axes():
        n_batch_shards *= mesh.shape[a]
    shard_seq = shape.global_batch < n_batch_shards
    b = activation_spec("batch")[0] if not shard_seq else None
    seq = "data" if shard_seq else None

    n_model = mesh.shape.get("model", 1)
    cfg = model.cfg
    # kv heads shard over 'model' when divisible; else shard head_dim
    kv_ok = cfg.n_kv_heads and cfg.n_kv_heads % n_model == 0
    kv_spec = (("model", None) if kv_ok else (None, "model"))

    def leaf_spec(path, leaf):
        name = path[-1]
        nd = len(leaf[0]) if isinstance(leaf, tuple) else leaf.ndim
        stacked = "pattern" in path[:-1]
        if name in ("k", "v", "mem_k", "mem_v"):
            spec = (b, seq if name in ("k", "v") else None) + kv_spec
        elif name == "c_kv":
            spec = (b, seq, "model")  # MLA latent rank shards over model
        elif name == "k_rope":
            spec = (b, seq, None)
        elif name == "state":
            spec = (b, "model", None, None)
        elif name == "conv":
            spec = (b, None, "model")
        elif name == "h":
            spec = (b, "model")
        else:
            spec = (None,) * nd
        if stacked:
            spec = (None,) + spec
        return P(*spec)

    shapes = model.cache_shapes(shape.global_batch, shape.seq_len)

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)) and not (
            len(tree) == 2 and isinstance(tree[0], tuple)
        ):
            return [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
        return leaf_spec(path, tree)

    return walk(shapes, ())


def abstract_cache(model: Model, shape):
    return model.abstract_cache(shape.global_batch, shape.seq_len)


def sanitize_specs(spec_tree, abs_tree, mesh):
    """Drop sharding on dims not divisible by the mesh axis size (e.g. MQA
    kv=1 heads cannot shard over 'model')."""

    def fix(spec, leaf):
        shape = leaf.shape if hasattr(leaf, "shape") else leaf[0]
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, entry in zip(shape, entries):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            out.append(entry if dim % size == 0 else None)
        return P(*out)

    return jax.tree.map(
        fix, spec_tree, abs_tree, is_leaf=lambda x: isinstance(x, P)
    )


def resolve_spec_names(spec_tree, mesh):
    """Drop spec axis names not present in the mesh (e.g. 'pod' single-pod)."""
    axes = set(mesh.axis_names)

    def fix(spec):
        out = []
        for entry in spec:
            if entry is None:
                out.append(None)
            elif isinstance(entry, (tuple, list)):
                kept = tuple(a for a in entry if a in axes)
                out.append(kept if kept else None)
            else:
                out.append(entry if entry in axes else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))
