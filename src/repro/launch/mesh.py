"""Production mesh builders (functions — importing never touches jax device
state; jax locks the device count on first backend init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
