import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers AND compiles for the production meshes, and extract the roofline raw
terms (FLOPs / bytes / collective bytes / per-device memory).

The 512 host devices above exist ONLY here (smoke tests and benches must see
one device), which is why this sets XLA_FLAGS before any other import.

cost_analysis() counts a lax.scan body ONCE (verified empirically), so this
module also lowers a single-macro-block PROBE per model and reports
    corrected = full + (trip_count - 1) * probe
for flops / bytes / collective bytes. The only scans in the model are the
macro-block layer scan and (whisper) the encoder scan — by design.

Usage:
  python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # every applicable pair
  ... [--step baseline|btard] [--out results/dryrun]
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config, list_archs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch import input_specs as ispecs
from repro.launch.steps import (
    make_baseline_train_step,
    make_btard_train_step,
    make_decode_step,
    make_prefill_step,
)
from repro.models import Model
from repro.optim import sgd
from repro.sharding import param_specs, set_mesh

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum of result bytes per collective kind (per-device program)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        ty, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(ty)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def analyze_compiled(step_fn, args, tag=""):
    t0 = time.time()
    lowered = step_fn.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    coll = collective_bytes(text)
    rec = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
    }
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            rec[attr] = int(v)
    return rec


# ---------------------------------------------------------------------------
# Single-macro-block probes (scan-body cost correction)
# ---------------------------------------------------------------------------
def make_pattern_probe(model: Model, mesh, shape, kind):
    """Jit one macro-block (fwd for serve kinds; remat fwd+bwd for train)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import transformer as tfm

    cfg = model.cfg
    if not (cfg.pattern and cfg.n_repeats > 1):
        return None, None, 0
    set_mesh(mesh)
    params_abs = model.abstract_params()
    if cfg.share_pattern_params:
        pat_abs = params_abs["pattern"]
        strip = lambda s: s
    else:
        pat_abs = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), params_abs["pattern"]
        )
        strip = lambda s: P(*list(s)[1:]) if len(s) else s

    pspecs_all = ispecs.resolve_spec_names(param_specs(params_abs), mesh)
    pat_specs = jax.tree.map(
        strip, pspecs_all["pattern"], is_leaf=lambda x: isinstance(x, P)
    )
    pat_specs = ispecs.sanitize_specs(pat_specs, pat_abs, mesh)

    B = shape.global_batch
    S = 1 if kind == "decode" else shape.seq_len
    x_abs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    from repro.sharding.specs import activation_spec

    x_spec = activation_spec("batch", None, None)

    mem_abs = None
    if cfg.encoder_len and any(
        s.cross or s.mixer == "attn_cross" for s in cfg.pattern
    ) and kind != "decode":
        mem_abs = jax.ShapeDtypeStruct((B, cfg.encoder_len, cfg.d_model), x_abs.dtype)

    cache_abs = None
    cache_specs_t = None
    if kind in ("prefill", "decode"):
        one = {
            f"l{i}": tfm.block_cache_shapes(cfg, s, B, shape.seq_len)
            for i, s in enumerate(cfg.pattern)
        }
        cache_abs = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l[0], l[1]),
            one,
            is_leaf=lambda l: isinstance(l, tuple) and len(l) == 2 and isinstance(l[0], tuple),
        )
        cs_full = ispecs.resolve_spec_names(ispecs.cache_specs(model, shape, mesh), mesh)
        # rebuild per-block specs (strip the stack dim from pattern specs)
        cs_pat = cs_full.get("pattern") if isinstance(cs_full, dict) else None
        if cs_pat is not None:
            cache_specs_t = jax.tree.map(
                lambda s: P(*list(s)[1:]) if len(s) else s,
                cs_pat,
                is_leaf=lambda x: isinstance(x, P),
            )
            cache_specs_t = ispecs.sanitize_specs(cache_specs_t, cache_abs, mesh)

    pos_abs = (
        jax.ShapeDtypeStruct((B,), jnp.int32)
        if kind == "decode"
        else jax.ShapeDtypeStruct((S,), jnp.int32)
    )

    mode = {"train": "train", "prefill": "prefill", "decode": "decode"}[kind]

    def block_fwd(pt, x, pos, memory, cache_t):
        out, nc, aux = tfm._macro_apply(
            pt, cfg, x, pos=pos, memory=memory, cache_t=cache_t, mode=mode, remat=False
        )
        return out, nc

    if kind == "train":

        def probe(pt, x, pos, memory):
            f = jax.checkpoint(
                lambda p_, x_: block_fwd(p_, x_, pos, memory, None)[0]
            )

            def loss(p_, x_):
                return jnp.sum(f(p_, x_).astype(jnp.float32))

            g = jax.grad(loss, argnums=(0, 1))(pt, x)
            return g

        in_sh = (
            _ns(mesh, pat_specs),
            NamedSharding(mesh, x_spec),
            None,
            None if mem_abs is None else NamedSharding(mesh, P()),
        )
        args = (pat_abs, x_abs, pos_abs, mem_abs)
        fn = jax.jit(probe, in_shardings=in_sh)
    else:

        def probe(pt, x, pos, memory, cache_t):
            return block_fwd(pt, x, pos, memory, cache_t)

        in_sh = (
            _ns(mesh, pat_specs),
            NamedSharding(mesh, x_spec),
            None,
            None if mem_abs is None else NamedSharding(mesh, P()),
            None if cache_specs_t is None else _ns(mesh, cache_specs_t),
        )
        args = (pat_abs, x_abs, pos_abs, mem_abs, cache_abs)
        fn = jax.jit(probe, in_shardings=in_sh)

    return fn, args, model.cfg.n_repeats


def _ns(mesh, spec_tree):
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
def run_pair(arch, shape_name, multi_pod=False, step_kind=None, out_dir=None,
             probe=True, seq_parallel=False):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        print(f"SKIP {arch} x {shape_name}: long_500k needs sub-quadratic attention")
        return None
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh)
    from repro.sharding.specs import set_seq_parallel

    set_seq_parallel(seq_parallel)
    opt = sgd(1e-2, momentum=0.9)

    kind = shape.kind
    if step_kind is None:
        step_kind = "baseline" if kind == "train" else kind

    base_kind = step_kind.replace("-seqp", "")
    if base_kind == "baseline":
        fn, args = make_baseline_train_step(model, opt, mesh, shape)
    elif base_kind == "btard":
        fn, args = make_btard_train_step(model, opt, mesh, shape, clip_iters=20)
    elif base_kind == "prefill":
        fn, args = make_prefill_step(model, mesh, shape)
    elif base_kind == "decode":
        fn, args = make_decode_step(model, mesh, shape)
    else:
        raise ValueError(step_kind)

    mesh_name = "2x16x16" if multi_pod else "16x16"
    if seq_parallel:
        step_kind = step_kind + "-seqp"
    tag = f"{arch} x {shape_name} x {mesh_name} [{step_kind}]"
    print(f"== {tag}", flush=True)
    rec = analyze_compiled(fn, args, tag)
    rec.update(
        arch=arch, shape=shape_name, mesh=mesh_name, step=step_kind,
        n_devices=int(np.prod(list(mesh.shape.values()))),
        param_count=model.param_count(),
        active_param_count=float(model.active_param_count()),
    )

    if probe and kind == "train" or probe and kind in ("prefill", "decode"):
        try:
            pfn, pargs, trips = make_pattern_probe(model, mesh, shape, kind)
            if pfn is not None:
                prec = analyze_compiled(pfn, pargs)
                rec["probe"] = {
                    "flops": prec["flops"],
                    "bytes": prec["bytes"],
                    "collective_bytes": prec["collective_bytes"],
                    "trips": trips,
                }
                rec["flops_corrected"] = rec["flops"] + (trips - 1) * prec["flops"]
                rec["bytes_corrected"] = rec["bytes"] + (trips - 1) * prec["bytes"]
                rec["collective_bytes_corrected"] = rec["collective_bytes"]["total"] + (
                    trips - 1
                ) * prec["collective_bytes"]["total"]
        except Exception as e:  # probe failures must not fail the dry-run
            rec["probe_error"] = f"{type(e).__name__}: {e}"

    print(
        "   flops={flops:.3e} bytes={bytes:.3e} coll={c:.3e} "
        "args={a:.1f}GB temp={t:.1f}GB compile={s}s".format(
            flops=rec.get("flops_corrected", rec["flops"]),
            bytes=rec.get("bytes_corrected", rec["bytes"]),
            c=rec.get("collective_bytes_corrected", rec["collective_bytes"]["total"]),
            a=rec.get("argument_size_in_bytes", 0) / 1e9,
            t=rec.get("temp_size_in_bytes", 0) / 1e9,
            s=rec["compile_s"],
        ),
        flush=True,
    )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_name}__{step_kind}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--step", default=None, choices=[None, "baseline", "btard", "prefill", "decode"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if args.all:
        for arch in list_archs():
            for shape_name in INPUT_SHAPES:
                run_pair(arch, shape_name, args.multi_pod, args.step, args.out,
                         probe=not args.no_probe, seq_parallel=args.seq_parallel)
        return
    assert args.arch and args.shape, "--arch/--shape or --all"
    rec = run_pair(args.arch, args.shape, args.multi_pod, args.step, args.out,
                   probe=not args.no_probe, seq_parallel=args.seq_parallel)
    if rec is None:
        sys.exit(0)


if __name__ == "__main__":
    main()
