"""Batched serving driver: prefill a batch of prompts, then decode tokens.

  python -m repro.launch.serve --arch qwen3-1.7b --reduced --host-devices 8 \\
      --mesh 4x2 --batch 8 --prompt-len 32 --gen 16
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--mesh", default="4x2")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}"
        )

    import jax
    import jax.numpy as jnp

    from repro.configs.base import InputShape
    from repro.data import TokenPipeline
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.models import get_model
    from repro.sharding import set_mesh

    dims = [int(x) for x in args.mesh.split("x")]
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    mesh = jax.make_mesh(tuple(dims), names)
    set_mesh(mesh)

    model = get_model(args.arch, reduced=args.reduced)
    total = args.prompt_len + args.gen
    shape = InputShape("cli", total, args.batch, "decode")
    pshape = InputShape("cli_p", args.prompt_len, args.batch, "prefill")

    prefill_fn, _ = make_prefill_step(model, mesh, shape)  # cache sized `total`
    decode_fn, _ = make_decode_step(model, mesh, shape)

    params = model.init_params(jax.random.key(0))
    pipe = TokenPipeline(model.cfg.vocab_size, args.prompt_len, args.batch)
    batch = pipe.batch(0)
    prompts = batch["tokens"][:, : args.prompt_len]
    pf_batch = {"tokens": prompts}
    if model.cfg.encoder_len:
        pf_batch["memory_raw"] = (
            jax.random.normal(
                jax.random.key(1),
                (args.batch, model.cfg.encoder_len, model.cfg.encoder_dim),
            )
            * 0.02
        )

    cache = model.init_cache(args.batch, total)
    t0 = time.time()
    logits, cache = prefill_fn(params, pf_batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t1 = time.time()
    out = [tok]
    for i in range(args.gen - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, cache = decode_fn(params, cache, {"token": tok, "pos": pos})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t2 = time.time()
    gen = jnp.stack(out, 1)
    print(f"arch={model.cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t1-t0:.2f}s; decode: {(t2-t1)/max(args.gen-1,1)*1000:.1f} ms/token")
    print("first sequences:", gen[:2].tolist())


if __name__ == "__main__":
    main()
