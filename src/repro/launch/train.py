"""End-to-end distributed training driver.

Runs the BTARD (or baseline AR-SGD) train step on whatever devices exist —
the production mesh shape is requested via --mesh, host devices via
--host-devices for CPU bring-up. Data comes from the deterministic
public-seed pipeline; checkpoints via repro.checkpoint.

Examples (CPU bring-up, 8 fake devices):
  python -m repro.launch.train --arch qwen3-1.7b --reduced \\
      --host-devices 8 --mesh 4x2 --steps 20 --defense btard
  python -m repro.launch.train --arch mamba2-2.7b --reduced --host-devices 8 \\
      --mesh 4x2 --steps 10 --attack sign_flip --byzantine 1,3
  # device-resident scan loop: 5 rounds per compiled dispatch, batches
  # generated IN-SCAN from the public seed chain, warm-started CenteredClip
  # with the adaptive early-exit budget
  python -m repro.launch.train --arch qwen3-1.7b --reduced --host-devices 8 \\
      --mesh 4x2 --steps 20 --scan-steps 5 \\
      --aggregator butterfly_clip:warm_start=true,adaptive_tol=1e-4
  # swap the robust aggregator (paper Fig. 3 comparison axis): any
  # registered AggregatorSpec name, with optional static params
  python -m repro.launch.train --arch qwen3-1.7b --reduced --host-devices 8 \\
      --mesh 4x2 --steps 10 --scan-steps 5 --attack sign_flip \\
      --byzantine 1,3 --aggregator krum
  # compressed wire: int8 butterfly payloads + f32 scale sidecars, digests
  # over the dequantized wire values (verification stays exact)
  python -m repro.launch.train --arch qwen3-1.7b --reduced --host-devices 4 \\
      --mesh 2x2 --steps 8 --scan-steps 4 --attack sign_flip --byzantine 1 \\
      --aggregator compressed:verified:mean
"""
import argparse
import os
import time
import warnings


def resolve_cli_aggregator(text, warm_start_clip=False, adaptive_clip=None,
                           n_byzantine=0):
    """Parse ``--aggregator NAME[:k=v,...]`` and fold the DEPRECATED
    ``--warm-start-clip`` / ``--adaptive-clip TOL`` flags into the spec
    (they keep working as aliases for the equivalent spec params).
    Krum's ``n_byzantine`` defaults to the --byzantine list length."""
    from repro.core.aggregators import AggregatorSpec, with_byzantine_default

    spec = AggregatorSpec.parse(text)
    shims = {}
    if warm_start_clip:
        warnings.warn(
            "--warm-start-clip is deprecated; use "
            "--aggregator butterfly_clip:warm_start=true",
            DeprecationWarning, stacklevel=2,
        )
        shims["warm_start"] = True
    if adaptive_clip is not None:
        warnings.warn(
            "--adaptive-clip is deprecated; use "
            f"--aggregator butterfly_clip:adaptive_tol={adaptive_clip}",
            DeprecationWarning, stacklevel=2,
        )
        shims["adaptive_tol"] = adaptive_clip
    if shims:
        accepted = set(spec.definition.param_names)
        dropped = [k for k in shims if k not in accepted]
        if dropped:
            warnings.warn(
                f"aggregator {spec.name!r} takes no {dropped}; the "
                "deprecated clip flags only apply to warm-startable/"
                "adaptive specs and are ignored here",
                stacklevel=2,
            )
        spec = spec.override(
            **{k: v for k, v in shims.items() if k in accepted}
        )
    return with_byzantine_default(spec, n_byzantine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--mesh", default="4x2", help="DATAxMODEL or PODxDATAxMODEL")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--defense", default="btard", choices=["btard", "mean"])
    ap.add_argument("--tau", type=float, default=2.0)
    ap.add_argument("--clip-iters", type=int, default=20)
    ap.add_argument("--attack", default="none",
                    choices=["none", "sign_flip", "random_direction", "ipm"])
    ap.add_argument("--byzantine", default="", help="comma-separated peer idxs")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--scan-steps", type=int, default=0,
                    help="BTARD rounds per jitted lax.scan dispatch "
                         "(0 = one dispatch per round)")
    ap.add_argument("--aggregator", default="butterfly_clip",
                    metavar="NAME[:k=v,...]",
                    help="robust aggregator spec for the btard defense: "
                         "butterfly_clip (verifiable flagship; params tau, "
                         "n_iters, warm_start, adaptive_tol), mean, "
                         "coordinate_median, trimmed_mean[:trim_ratio=R], "
                         "geometric_median, krum[:n_byzantine=B], "
                         "centered_clip[:tau=T]. verified:BASE[:k=v,...] "
                         "lifts a coordinatewise baseline (mean, "
                         "trimmed_mean, coordinate_median) into a "
                         "verifiable one: butterfly all_to_all topology + "
                         "recomputable contribution digests instead of the "
                         "O(n*d) PS all_gather (e.g. "
                         "verified:trimmed_mean:trim_ratio=0.2). "
                         "compressed:SPEC[:codec=int8|bf16] quantizes the "
                         "butterfly all_to_all payloads (int8: ~4x fewer "
                         "wire bytes + one f32 scale sidecar per payload; "
                         "default codec int8) with every digest computed "
                         "over the dequantized wire values, so "
                         "verification stays exact (e.g. "
                         "compressed:verified:mean, "
                         "compressed:butterfly_clip:codec=bf16). "
                         "Non-verifiable specs run without the "
                         "verification/ban machinery. --tau and "
                         "--clip-iters fill the spec's defaults; explicit "
                         "spec params win.")
    ap.add_argument("--groups", type=int, default=0,
                    help="hierarchical butterfly-of-butterflies: split the "
                         "peer axis into GROUPS groups of n/GROUPS; level-1 "
                         "butterfly within each group (per-peer table "
                         "traffic O((n/g)^2) instead of O(n^2)), level-2 "
                         "active-weight mean of the group aggregates "
                         "(exact linear checksum). Verifiable specs only; "
                         "GROUPS must divide the peer count with >= 2 "
                         "members per group. 0 = flat (default)")
    ap.add_argument("--audit-k", type=int, default=0,
                    help="sampled-digest verification: only K owner "
                         "columns per step (a rotating seed-driven window) "
                         "broadcast their digests — table bytes drop "
                         "n^2 -> n*K while every column is audited within "
                         "n/K steps. Composes with --groups (the window "
                         "rotates within each group). 0 = every column "
                         "every step (default)")
    ap.add_argument("--agg-attack", type=float, default=0.0, metavar="SCALE",
                    help="simulate the LYING AGGREGATOR: Byzantine peers "
                         "(--byzantine) corrupt their owned partition "
                         "aggregate by SCALE x rms after aggregating and "
                         "report self-consistent digests; detection is via "
                         "the V2 checksum (linear specs) or the validator "
                         "audit arm (any verifiable spec). 0 = off")
    ap.add_argument("--warm-start-clip", action="store_true",
                    help="DEPRECATED alias for "
                         "--aggregator butterfly_clip:warm_start=true "
                         "(implies the scan step; see kernels/DESIGN.md)")
    ap.add_argument("--adaptive-clip", type=float, default=None, metavar="TOL",
                    help="DEPRECATED alias for "
                         "--aggregator butterfly_clip:adaptive_tol=TOL "
                         "(--clip-iters becomes the static cap)")
    ap.add_argument("--host-data", action="store_true",
                    help="feed host-precomputed batches to the scan step "
                         "instead of generating them in-scan on device "
                         "(the default scan path is fully device-resident)")
    ap.add_argument("--churn", default="", metavar="EVENTS",
                    help="elastic-membership schedule: comma-separated "
                         "KIND@STEP:SLOT events (kind join|leave), e.g. "
                         "'leave@6:1,join@8:1'. A leave vacates the slot; a "
                         "join puts a FRESH identity into a vacant slot "
                         "under probation — it computes public-seed "
                         "gradients spot-checked every step (the "
                         "probe_mismatch audit arm) and only a clean "
                         "--probation-steps window admits it to the "
                         "aggregate. Identity ban ledgers survive churn: a "
                         "banned slot that leaves and rejoins is re-vetted, "
                         "and re-banned the moment it misbehaves, without "
                         "ever re-entering the aggregate")
    ap.add_argument("--probation-steps", type=int, default=3,
                    help="consecutive clean spot-checks a joining peer "
                         "needs before its slot turns active (default 3)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="directory for crash-recovery checkpoints: "
                         "params + optimizer + warm-start carry + the full "
                         "membership/ban ledger are saved at every scan-"
                         "chunk boundary (atomic), so a killed run resumes "
                         "bitwise with --resume. Requires --scan-steps")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the checkpoint in --checkpoint-dir "
                         "(same CLI config required); continues at the "
                         "saved chunk boundary with identical bans and "
                         "aggregates (scan-resume bitwise property)")
    ap.add_argument("--halt-at", type=int, default=None, metavar="STEP",
                    help="crash drill: exit right after the first chunk-"
                         "boundary checkpoint at or beyond STEP (pair with "
                         "--resume to verify recovery)")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}"
        )

    byz = set(int(x) for x in args.byzantine.split(",") if x)

    import json

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.configs.base import InputShape
    from repro.core import butterfly as bf
    from repro.core.sybil import HostMembership, parse_churn
    from repro.data import TokenPipeline
    from repro.launch.steps import (
        make_baseline_train_step,
        make_btard_scan_train_step,
        make_btard_train_step,
    )
    from repro.models import get_model
    from repro.optim import sgd
    from repro.sharding import set_mesh
    from repro.sharding.specs import set_seq_parallel

    dims = [int(x) for x in args.mesh.split("x")]
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    mesh = jax.make_mesh(tuple(dims), names)
    set_mesh(mesh)
    set_seq_parallel(args.seq_parallel)

    model = get_model(args.arch, reduced=args.reduced)
    shape = InputShape("cli", args.seq, args.batch, "train")
    opt = sgd(args.lr, momentum=0.9, nesterov=True)
    n_peers = int(np.prod([mesh.shape[a] for a in names if a != "model"]))

    agg_spec = resolve_cli_aggregator(
        args.aggregator, args.warm_start_clip, args.adaptive_clip, len(byz)
    )
    warm = bool(agg_spec.warm_startable and agg_spec.get("warm_start", False))

    extras = None
    if model.cfg.encoder_len:
        extras = {
            "memory_raw": ((model.cfg.encoder_len, model.cfg.encoder_dim), jnp.float32)
        }
    pipe = TokenPipeline(model.cfg.vocab_size, args.seq, args.batch)

    n_scan = max(args.scan_steps, 1 if warm else 0)
    # the scan path is device-resident by default: batches come from the
    # public peer_key chain INSIDE the compiled scan (same bits as the host
    # pipeline), so each dispatch moves only two (n_scan,) i32 vectors
    device_data = bool(n_scan) and not args.host_data
    flat_cost = dict(
        groups=args.groups or None, audit_k=args.audit_k or None,
        agg_attack=args.agg_attack or None,
    )
    if args.defense == "btard" and n_scan:
        step_fn, _ = make_btard_scan_train_step(
            model, opt, mesh, shape, n_scan_steps=n_scan, tau=args.tau,
            clip_iters=args.clip_iters, attack=args.attack,
            use_pallas=args.use_pallas, aggregator=agg_spec,
            pipeline=pipe if device_data else None, extras=extras,
            **flat_cost,
        )
    elif args.defense == "btard":
        step_fn, _ = make_btard_train_step(
            model, opt, mesh, shape, tau=args.tau, clip_iters=args.clip_iters,
            attack=args.attack, use_pallas=args.use_pallas,
            aggregator=agg_spec, **flat_cost,
        )
    else:
        step_fn, _ = make_baseline_train_step(model, opt, mesh, shape)

    params = model.init_params(jax.random.key(0))
    opt_state = opt.init(params)

    byz_mask = jnp.asarray(
        [1.0 if i in byz else 0.0 for i in range(n_peers)], jnp.float32
    )
    # every peer starts active — even the Byzantine ones; bans flow from the
    # verification checksums below, never from out-of-band knowledge. The
    # membership ledger (core.sybil.HostMembership) owns the slot lifecycle:
    # --churn events toggle slots between dispatches, the probe_mismatch
    # audit arm drives probation spot-checks, and bans are keyed by IDENTITY
    # so a leave/rejoin can never launder them.
    mem = HostMembership(
        n_peers, probation_steps=args.probation_steps,
        events=parse_churn(args.churn) if args.churn else None,
    )
    weights = jnp.asarray(mem.weights())

    def apply_bans(weights, step, *offender_sets):
        newly = mem.ban_slots(
            {int(b) for s in offender_sets for b in s}, step
        )
        if newly:
            print(f"banned peers -> {mem.banned_slots()}", flush=True)
        return jnp.asarray(mem.weights())

    def audit_offenders(verif, tol=1e-5):
        """Peers whose validator audit (gradient recompute or partition-
        aggregation recompute — steps.aggregation_stage) deviated from
        their broadcast payloads. Honest peers report EXACT zeros (the
        recompute is bit-identical), so any excess over float tolerance is
        a lie; works for every verifiable spec, including the nonlinear
        verified:* wrappers whose digests carry no zero-sum checksum."""
        bad = set()
        for k in ("audit_grad_mismatch", "audit_agg_mismatch"):
            if isinstance(verif, dict) and k in verif:
                a = np.asarray(verif[k], np.float64)
                if a.ndim > 1:  # scan mode: catch mid-chunk audits too
                    a = a.max(0)
                bad |= {int(i) for i in np.nonzero(a > tol)[0]}
        return bad

    if args.churn and not n_scan:
        # per-step mode applies events/probes too, but the CI-proven path
        # (and the checkpointed one) is the scan loop — keep configs honest
        print("note: --churn granularity is per step in non-scan mode")
    if (args.checkpoint_dir or args.resume) and not n_scan:
        ap.error("--checkpoint-dir/--resume require --scan-steps "
                 "(checkpoints are cut at scan-chunk boundaries)")
    if args.halt_at is not None and not args.checkpoint_dir:
        ap.error("--halt-at exits after a boundary checkpoint, so it "
                 "requires --checkpoint-dir")

    print(f"arch={model.cfg.name} params={model.param_count():,} "
          f"mesh={dict(mesh.shape)} peers={n_peers} byz={sorted(byz)} "
          f"aggregator={agg_spec.canonical()} "
          f"scan={n_scan or '-'} "
          f"data={'device' if device_data else 'host'}")
    t0 = time.time()
    final_loss = float("nan")
    if args.defense == "btard" and n_scan:
        v_prev = jax.tree.map(jnp.zeros_like, params)
        start_step = 0
        state_path = mem_path = ""
        if args.checkpoint_dir:
            os.makedirs(args.checkpoint_dir, exist_ok=True)
            state_path = os.path.join(args.checkpoint_dir, "state.msgpack")
            mem_path = os.path.join(args.checkpoint_dir,
                                    "membership.msgpack")
        if args.resume:
            example = {"params": params, "opt": opt_state, "v_prev": v_prev}
            state, start_step, ck_meta = load_checkpoint(state_path, example)
            params, opt_state, v_prev = (
                state["params"], state["opt"], state["v_prev"]
            )
            mem_tree, mem_step, _ = load_checkpoint(mem_path)
            if mem_step != start_step:
                raise RuntimeError(
                    f"checkpoint pair out of sync: state@{start_step} vs "
                    f"membership@{mem_step} — a crash mid-save; rerun "
                    "without --resume or restore the previous pair"
                )
            mem.restore_tree(mem_tree)
            weights = jnp.asarray(mem.weights())
            if start_step % n_scan:
                raise RuntimeError(
                    f"resume step {start_step} is not a multiple of "
                    f"--scan-steps {n_scan}; use the original chunking"
                )
            print(f"resumed at step {start_step} "
                  f"(banned={mem.banned_slots()}, arch={ck_meta.get('arch')})",
                  flush=True)
        rem = args.steps % n_scan
        rem_fn = None
        if rem:
            # a shorter trailing chunk needs its own fixed-length program
            rem_fn, _ = make_btard_scan_train_step(
                model, opt, mesh, shape, n_scan_steps=rem, tau=args.tau,
                clip_iters=args.clip_iters, attack=args.attack,
                use_pallas=args.use_pallas, aggregator=agg_spec,
                pipeline=pipe if device_data else None, extras=extras,
                **flat_cost,
            )
        for chunk in range(start_step, args.steps, n_scan):
            idxs = list(range(chunk, min(chunk + n_scan, args.steps)))
            # membership events fire at the chunk boundary: every join/leave
            # scheduled inside this chunk's window toggles its slot before
            # the dispatch (chunk-granular churn — the weights vector is
            # fixed for the compiled scan's duration)
            for s in idxs:
                mem.apply_events(s)
            weights = jnp.asarray(mem.weights())
            if len(idxs) < n_scan:
                step_fn = rem_fn
            steps_arr = jnp.asarray(idxs, jnp.int32)
            seeds = jnp.asarray([s * 7919 + 13 for s in idxs], jnp.int32)
            if device_data:
                params, opt_state, metrics, verif, v_prev = step_fn(
                    params, opt_state, steps_arr, seeds, byz_mask, weights,
                    v_prev,
                )
            else:
                batches = jax.tree.map(
                    lambda *ls: jnp.stack(ls),
                    *[pipe.batch(s, extras=extras) for s in idxs],
                )
                params, opt_state, metrics, verif, v_prev = step_fn(
                    params, opt_state, batches, steps_arr, seeds, byz_mask,
                    weights, v_prev,
                )
            # probation spot-checks: each scanned round reported every
            # peer's deviation from its public-seed recompute; feed the
            # probation slots' rows to the gate (ban on any mismatch,
            # promote after a clean window)
            probes = np.asarray(verif["probe_mismatch"], np.float64)
            if probes.ndim == 1:
                probes = probes[None]
            for i, s in enumerate(idxs):
                mem.observe_probe(probes[i], s)
            # ban policy applied between dispatches from the LAST round's
            # checksums (mid-chunk rounds share the chunk's weights)
            bad = bf.checksum_offender_peers(verif["checksum"][-1])
            if not (args.attack != "none" or args.agg_attack):
                bad = []
            # audit-arm bans are unconditional: honest audits are exact
            # zeros, so a nonzero mismatch is a lie whatever the flags
            weights = apply_bans(weights, idxs[-1], bad,
                                 audit_offenders(verif))
            final_loss = float(metrics["loss"][-1])
            if chunk % max(args.log_every, 1) == 0:
                print(f"step {idxs[-1]:4d} loss={final_loss:.4f}"
                      f" checksum={float(metrics['checksum_max'][-1]):.2e}",
                      flush=True)
            if state_path:
                next_step = idxs[-1] + 1
                save_checkpoint(
                    state_path,
                    {"params": params, "opt": opt_state, "v_prev": v_prev},
                    step=next_step,
                    meta={"arch": args.arch,
                          "aggregator": agg_spec.canonical()},
                )
                save_checkpoint(mem_path, mem.to_tree(), step=next_step)
                if args.halt_at is not None and next_step >= args.halt_at:
                    print(f"halt requested at step {args.halt_at}: "
                          f"checkpointed step {next_step}, exiting "
                          "(resume with --resume)", flush=True)
                    _print_summary(json, mem, byz, final_loss, next_step)
                    return
    else:
        for step in range(args.steps):
            mem.apply_events(step)
            weights = jnp.asarray(mem.weights())
            batch = pipe.batch(step, extras=extras)
            if args.defense == "btard":
                params, opt_state, metrics, verif = step_fn(
                    params, opt_state, batch, jnp.int32(step),
                    jnp.int32(step * 7919 + 13), byz_mask, weights,
                )
                extra = (f" checksum={float(metrics['checksum_max']):.2e}"
                         f" votes={float(metrics['votes_max']):.0f}")
                if isinstance(verif, dict) and "probe_mismatch" in verif:
                    mem.observe_probe(
                        np.asarray(verif["probe_mismatch"], np.float64), step
                    )
                # host-side ban policy: a violated partition checksum
                # implicates its aggregating peer (partition j <-> peer j)
                bad = bf.checksum_offender_peers(verif["checksum"])
                if not (args.attack != "none" or args.agg_attack):
                    bad = []
                weights = apply_bans(weights, step, bad,
                                     audit_offenders(verif))
            else:
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch, jnp.int32(step)
                )
                extra = ""
            final_loss = float(metrics["loss"])
            if step % args.log_every == 0:
                print(f"step {step:4d} loss={final_loss:.4f}{extra}",
                      flush=True)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s ({dt/args.steps:.2f}s/step)")
    _print_summary(json, mem, byz, final_loss, args.steps)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, {"params": params, "opt": opt_state},
                        step=args.steps, meta={"arch": args.arch})
        print("checkpoint saved:", args.checkpoint)


def _print_summary(json, mem, byz, final_loss, steps_done):
    """One machine-parseable line for CI assertions (churn gauntlet)."""
    s = mem.summary()
    s.update(byzantine=sorted(byz), final_loss=final_loss,
             steps_done=int(steps_done))
    print("SUMMARY " + json.dumps(s), flush=True)


if __name__ == "__main__":
    main()
