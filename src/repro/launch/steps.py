"""Distributed step builders for the production mesh.

Three step kinds per architecture:

* baseline train   — auto-GSPMD FSDP('data') x TP('model') AR-SGD (the
                     paper's All-Reduce comparison; also the 33-pair roofline
                     baseline).
* BTARD train      — the paper's technique as a first-class distributed step:
                     stage 1 computes per-peer gradients (shard_map manual
                     over the peer axes = pod x data, auto over 'model');
                     stage 2 is the AggregatorSpec-dispatched robust
                     all-reduce (fully-manual shard_map). Verifiable specs
                     run the butterfly: all_to_all gradient partitions,
                     per-partition aggregation by the owner (CenteredClip
                     for the flagship, the base coordinatewise fn for
                     verified:* wrapped specs; optionally Pallas kernels),
                     the O(n^2)-scalar verification tables / contribution
                     digests, all_gather back. Non-verifiable specs (mean,
                     krum, ...) all_gather the stack and apply the registry
                     fn (trusted-PS model, zero tables).
* serve (prefill / decode) — auto-GSPMD with KV-cache shardings
                     (sequence-sharded for long_500k).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.aggregators import resolve_spec
from repro.core.centered_clip import (
    centered_clip,
    centered_clip_adaptive,
    clip_residuals,
)
from repro.launch import input_specs as ispecs
from repro.models import Model
from repro.optim.optimizers import apply_updates
from repro.sharding import param_specs, set_mesh


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def _shard_map(f, mesh, in_specs, out_specs, axis_names, check_vma=False):
    """jax.shard_map with a fallback to the pre-0.5 experimental API, where
    the manual-axes set is expressed as its complement (``auto``) and
    check_vma was called check_rep."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=set(axis_names), check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _sm

    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, auto=auto,
    )


def opt_state_specs(opt_state_abs, pspecs):
    """Optimizer state mirrors the param tree per moment buffer."""

    def per_bucket(bucket):
        return pspecs

    return {k: pspecs for k in opt_state_abs} if isinstance(opt_state_abs, dict) else opt_state_abs


# ===========================================================================
# Baseline AR-SGD train step (auto GSPMD, FSDP x TP)
# ===========================================================================
def make_baseline_train_step(model: Model, optimizer, mesh, shape):
    set_mesh(mesh)
    params_abs = model.abstract_params()
    pspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(param_specs(params_abs), mesh), params_abs, mesh
    )
    bspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(ispecs.batch_specs(model.cfg, shape, "train"), mesh),
        ispecs.abstract_batch(model.cfg, shape, "train"),
        mesh,
    )
    opt_abs = jax.eval_shape(optimizer.init, params_abs)
    ospecs = {k: pspecs for k in opt_abs}

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    jitted = jax.jit(
        train_step,
        in_shardings=(
            _named(mesh, pspecs),
            _named(mesh, ospecs),
            _named(mesh, bspecs),
            None,
        ),
        out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs), None),
    )
    abstract_args = (
        params_abs,
        opt_abs,
        ispecs.abstract_batch(model.cfg, shape, "train"),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return jitted, abstract_args


# ===========================================================================
# BTARD butterfly stage (fully-manual shard_map over every mesh axis)
# ===========================================================================
def _flatten_local(leaves, dtype=jnp.float32):
    return jnp.concatenate([l.reshape(-1).astype(dtype) for l in leaves])


def _unflatten_local(vec, leaves):
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape))
        out.append(vec[off : off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return out


def _collapse_peer_mesh(mesh):
    """Collapse multi-axis peer meshes (pod x data) into ONE manual axis.

    jaxlib 0.4.37's SPMD partitioner RET_CHECKs ("Incompatible manual
    sharding ... aligned.has_value()") on partial-manual shard_map regions
    whose manual set spans MULTIPLE mesh axes next to an auto 'model' axis;
    a single manual axis is the well-trodden code path. Device order under
    P(('pod', 'data')) equals P('peers') on the reshaped mesh (pod-major),
    so caller-side shardings built on the original mesh stay compatible.
    Returns (mesh, peer_axes)."""
    peer_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if len(peer_axes) <= 1:
        return mesh, peer_axes
    from jax.sharding import Mesh

    other = tuple(a for a in mesh.axis_names if a not in peer_axes)
    perm = [mesh.axis_names.index(a) for a in peer_axes + other]
    devs = np.transpose(mesh.devices, perm)
    devs = devs.reshape((-1,) + devs.shape[len(peer_axes):])
    return Mesh(devs, ("peers",) + other), ("peers",)


def aggregation_stage(
    g_vec, peer_axes, n_peers, spec, weights, seed, use_pallas=False,
    delta_max=None, v0_full=None, gather_axes=(), groups=None,
    audit_k=None, agg_attack_scale=None, byz_mask=None, audit_grad=None,
):
    """Fully-manual-region robust all-reduce of one local gradient vector,
    dispatched by :class:`~repro.core.aggregators.AggregatorSpec`. Returns
    (aggregated vector, verification dict).

    Verifiable specs run the paper's butterfly topology: the local
    (model-shard) gradient vector is split into n_peers partitions;
    partition j is robustly aggregated by peer j (all_to_all), exactly
    Alg. 2 with partitions laid out over the TPU peer axis. For the
    ButterflyClip flagship the CenteredClip params (tau / n_iters /
    adaptive_tol) come from the spec and the tables are the tau-clipped
    residuals; for ``verified:<base>`` wrapped coordinatewise specs
    (core.verification) the partition owner applies the BASE fn to its
    all_to_all'd stack and broadcasts the generalized contribution digests
    s_i = <z, x_i - v>, ||x_i - v|| instead — same O(n^2)-scalar table
    traffic, same O(d)-per-peer gradient traffic as the flagship, where the
    unwrapped baselines pay the O(n*d) PS all_gather below. The V2
    checksum is emitted only for specs with the linear zero-sum identity
    (butterfly_clip, verified:mean); nonlinear wrapped specs report 0 and
    rely on validator recomputation (the host protocol's audit arm).

    ``compressed:<verifiable>`` specs (core.compression) quantize each
    (peer -> owner) payload before the exchange: the gradient all_to_all
    carries int8/bf16 wire words (≈4x / 2x fewer bytes than f32) plus one
    f32 sidecar scale per payload in a second scalar all_to_all. All
    aggregation and every digest then run over the dequantized-from-wire
    values — dispatch continues with the INNER spec — so sender, owner and
    validator agree bit-for-bit and honest peers are never accused over
    rounding. On the Pallas paths the received wire stack feeds the fused
    dequantize kernels directly (HBM reads stay 1-2 bytes/coordinate).

    Non-verifiable specs (mean, median, Krum, ...) have no partition
    ownership to verify: every peer all_gathers the full stack and applies
    the registry fn (the trusted-PS communication model, O(n·d) per peer
    instead of the butterfly's O(d)); the verification tables come back as
    zeros and the launch-side ban policy never fires. ``gather_axes`` names
    the NON-peer manual mesh axes (model shards): coordinatewise specs
    apply per shard (exact — they decompose over coordinates), while
    norm/distance-based specs (Krum, geometric median, CenteredClip) first
    join the shards along those axes so the full-vector geometry — and
    e.g. Krum's single global argmin — is preserved; the joined layout is
    a fixed coordinate permutation of the parameter vector, irrelevant to
    permutation-invariant fns, and each device slices its own shard back.

    v0_full: optional (d,) previous aggregated vector (replicated — every
    peer holds it after last step's all_gather); warm-startable specs seed
    their iteration from it, cutting the budget (DESIGN.md). Adaptive
    specs' per-device while_loops with data-dependent trip counts are fine
    in the manual region because the loop body contains no collectives;
    the verification tables are computed exactly once against the final
    iterate, so the broadcast protocol is budget-oblivious.

    Flat-cost verification axes (core.hierarchy — verifiable specs only):

    ``groups=g`` runs the butterfly-of-butterflies: the peer axis splits
    into g groups of gs = n/g via ``axis_index_groups`` (one manual mesh
    axis, two collective scopes). Level 1 is the ordinary butterfly WITHIN
    each group — gs partitions of size d/gs, owner = member index, digests
    against the group aggregate — so per-peer table traffic is O(gs^2)
    instead of O(n^2). Level 2 combines the g group aggregates by
    active-weight mean with a grouped psum at fixed member index (linear —
    the zero-sum checksum identity holds exactly for ANY base), and each
    group reconstructs the same full vector from its own level-1 gather.

    ``audit_k=k`` is sampled-digest mode: only the k owner columns in this
    step's rotating window (start = seed mod n) broadcast digests; every
    other owner ships zeros. Because checksum and votes are computed FROM
    the zeroed digests, the ban policy is silent at unsampled columns by
    construction (the zero-scatter invariant) — table bytes drop to
    O(n*k) while the rotating window bounds every column's audit staleness
    by n/k full cycles. Composes with ``groups``.

    ``agg_attack_scale`` + ``byz_mask`` simulate the LYING OWNER: a
    Byzantine partition owner corrupts its aggregate after aggregating and
    reports digests recomputed against the corrupted value — perfectly
    self-consistent tables, undetectable by the V1 mismatch rule. The
    validator audit arm (always on for verifiable specs) is what catches
    it: the shared seed elects one owner column per step, every validator
    recomputes that partition's aggregation from the same payloads, and
    the max deviation from the broadcast value is reported per peer in
    ``audit_agg_mismatch`` (exact zero for honest owners). ``audit_grad``
    threads the analogous gradient-recompute deviation from the caller
    (the payload audit — see _build_btard_step); both feed the host ban
    policy, closing the loop for nonlinear verified:* specs whose digests
    carry no checksum.
    """
    spec = resolve_spec(spec)
    d = g_vec.shape[0]
    if not spec.verifiable:
        stack = jax.lax.all_gather(g_vec, peer_axes)  # (n_peers, d) each
        v0 = None
        if v0_full is not None and spec.warm_startable:
            v0 = v0_full.astype(jnp.float32)
        join = tuple(gather_axes) if not spec.coordinatewise else ()
        if join:
            stack = jax.lax.all_gather(stack, join, axis=1, tiled=True)
            if v0 is not None:
                v0 = jax.lax.all_gather(v0, join, axis=0, tiled=True)
        # pin the gathered transport dtype before the f32 upcast below —
        # same hoist hazard as the butterfly barrier at the all_to_all
        stack = jax.lax.optimization_barrier(stack)
        agg_fn = spec.build(n_peers, stack.shape[1], use_pallas=use_pallas)
        flat, info = agg_fn(
            stack.astype(jnp.float32),
            weights if spec.weighted else None,
            v0,
            jax.random.key(seed),
        )
        if join:  # slice this device's model shard back out
            my = jnp.zeros((), jnp.int32)
            for a in join:  # row-major over the joined axes == gather order
                my = my * jax.lax.psum(1, a) + jax.lax.axis_index(a)
            flat = jax.lax.dynamic_slice_in_dim(flat, my * d, d)
        verif = {
            "checksum": jnp.zeros((1,), jnp.float32),
            "votes": jnp.zeros((1,), jnp.float32),
            "clip_iters": jnp.asarray(info.iters, jnp.int32)[None],
            "s_table": jnp.zeros((n_peers, n_peers), jnp.float32),
            "norm_table": jnp.zeros((n_peers, n_peers), jnp.float32),
            # the trusted-PS model has no audit protocol — zeros keep the
            # verif tree uniform across specs
            "audit_target": jnp.zeros((1,), jnp.int32),
            "audit_grad_mismatch": jnp.zeros((1,), jnp.float32),
            "audit_agg_mismatch": jnp.zeros((1,), jnp.float32),
        }
        return flat.astype(jnp.float32), verif

    from repro.core import compression as comp_mod
    from repro.core import verification as verif_mod

    my_idx = jax.lax.axis_index(peer_axes)
    hier = groups is not None and groups > 1
    if hier:
        from repro.core.hierarchy import group_shape

        n_groups, gs = group_shape(n_peers, groups)
        lvl1_groups = [[a * gs + c for c in range(gs)] for a in range(n_groups)]
        lvl2_groups = [[a * gs + c for a in range(n_groups)] for c in range(gs)]
        my_group = my_idx // gs
        fold_idx = my_idx % gs  # member index == level-1 partition owner
        n_loc = gs
        # the owner aggregates its GROUP's payloads with the group's weights
        weights = jnp.take(weights.reshape(n_groups, gs), my_group, axis=0)
    else:
        lvl1_groups = lvl2_groups = None
        fold_idx = my_idx
        n_loc = n_peers

    part = -(-d // n_loc)
    pad = part * n_loc - d
    if pad:
        g_vec = jnp.concatenate([g_vec, jnp.zeros((pad,), g_vec.dtype)])
    x = g_vec.reshape(n_loc, part)
    # each peer receives everyone's copy of ITS partition. The barrier pins
    # the transport dtype: without it XLA hoists the downstream f32 upcast
    # ahead of the collective, silently undoing bf16 transport (§Perf H3)
    # — or, for compressed specs, the wire codec itself.
    comp_wire = None
    if comp_mod.is_wrapped(spec):
        # compressed:* — quantize each (peer -> owner) payload BEFORE the
        # exchange: the gradient all_to_all ships 1-2 byte wire words, plus
        # ONE f32 sidecar scalar per payload in a second tiny all_to_all
        # (n_peers floats vs part*n_peers wire words). Every digest below
        # runs over the DEQUANTIZED wire values (core.compression), so the
        # owner's tables match any validator's recompute bit-for-bit and
        # rounding can never trip an accusation.
        codec = comp_mod.codec_of(spec)
        wire, scales = comp_mod.quantize(x, codec)  # (n, part), (n,) f32
        recv_w = jax.lax.all_to_all(
            wire, peer_axes, split_axis=0, concat_axis=0, tiled=True,
            axis_index_groups=lvl1_groups,
        )
        recv_s = jax.lax.all_to_all(
            scales, peer_axes, split_axis=0, concat_axis=0, tiled=True,
            axis_index_groups=lvl1_groups,
        )
        recv_w, recv_s = jax.lax.optimization_barrier((recv_w, recv_s))
        comp_wire = (recv_w, recv_s)
        recv = comp_mod.dequantize(recv_w, recv_s)  # the f32 wire values
        spec = comp_mod.inner_spec(spec)  # dispatch below is by inner spec
    else:
        recv = jax.lax.all_to_all(
            x, peer_axes, split_axis=0, concat_axis=0, tiled=True,
            axis_index_groups=lvl1_groups,
        )
        recv = jax.lax.optimization_barrier(recv)

    # --- z for the verification tables (Alg. 6): derived from the shared
    # MPRNG seed, folded by partition owner index; commitments are host-side
    # (protocol). Known before the aggregation runs, so the fused kernel can
    # emit the tables from its epilogue pass. Hierarchical mode folds by
    # MEMBER index: z is shared across groups (core.hierarchy's z1).
    z = jax.random.normal(jax.random.fold_in(jax.random.key(seed), fold_idx), (part,))
    z = z / jnp.maximum(jnp.linalg.norm(z), 1e-30)

    if verif_mod.is_wrapped(spec):
        # wrapped coordinatewise spec: the partition owner runs the BASE fn
        # over its all_to_all'd stack (exact — coordinatewise fns decompose
        # over the partition split) and broadcasts the generalized digests;
        # the fused-vs-standalone kernel dispatch lives in owner_aggregate.
        agg, s_local, norms_local, iters_used = verif_mod.owner_aggregate(
            spec, recv, z, weights, use_pallas=use_pallas,
            key=jax.random.key(seed), wire=comp_wire,
        )
        tau_v = 0.0
        with_checksum = verif_mod.has_zero_checksum(spec)
        return _verify_audit_tail(
            g_vec, d, pad, recv, agg, s_local, norms_local, iters_used,
            weights, peer_axes, delta_max, z, seed, n_peers, n_loc, fold_idx,
            my_idx, tau_v, with_checksum, lvl1_groups, lvl2_groups, audit_k,
            agg_attack_scale, byz_mask, audit_grad,
        )

    p = spec.param_dict()
    tau, clip_iters = p["tau"], p["n_iters"]
    adaptive_tol = p["adaptive_tol"]

    v0 = None
    if v0_full is not None:
        if pad:
            v0_full = jnp.concatenate(
                [v0_full, jnp.zeros((pad,), v0_full.dtype)]
            )
        v0 = v0_full.reshape(n_loc, part)[fold_idx].astype(jnp.float32)

    iters_used = jnp.asarray(clip_iters, jnp.int32)
    if adaptive_tol is not None and use_pallas:
        from repro.kernels.ops import butterfly_clip_adaptive_op, verify_tables_op

        # early-exit one-pass-per-iteration driver (single-partition batch),
        # then ONE verification-table pass against the final iterate
        agg_b, iters = butterfly_clip_adaptive_op(
            recv[None], tau, adaptive_tol, weights,
            v0=None if v0 is None else v0[None], max_iters=clip_iters,
        )
        agg, iters_used = agg_b[0], iters[0]
        s_local, norms_local = verify_tables_op(
            recv, agg, z.astype(jnp.float32), tau
        )
    elif use_pallas and comp_wire is not None:
        from repro.kernels.ops import butterfly_clip_fused_dequant_op

        # the wire payloads stay int8/bf16 in HBM: the fused dequantize+
        # clip+digest kernel makes its n_iters + 2 passes over 1-2 byte
        # data, dequantizing in-register against the sidecar scales
        qs, qscales = comp_wire
        agg_b, s_b, n_b = butterfly_clip_fused_dequant_op(
            qs[None], qscales[None], tau, z.astype(jnp.float32)[None],
            weights, v0=None if v0 is None else v0[None], n_iters=clip_iters,
        )
        agg, s_local, norms_local = agg_b[0], s_b[:, 0], n_b[:, 0]
    elif use_pallas:
        from repro.kernels.ops import centered_clip_fused_op

        # fused one-pass-per-iteration kernel: aggregate + s_i = <z, Delta_i>
        # + ||x_i - v|| in n_iters + 2 HBM passes of the peer stack
        agg, s_local, norms_local = centered_clip_fused_op(
            recv, tau, z.astype(jnp.float32), weights, v0=v0, n_iters=clip_iters
        )
    else:
        if adaptive_tol is not None:
            agg, iters_used = centered_clip_adaptive(
                recv, tau, adaptive_tol, clip_iters, weights=weights, v0=v0
            )
        else:
            agg = centered_clip(
                recv, tau=tau, n_iters=clip_iters, weights=weights, v0=v0
            )
        agg = agg.astype(jnp.float32)
        deltas = clip_residuals(recv.astype(jnp.float32), agg, tau)
        s_local = deltas @ z  # (n_peers,) — s_i^{my partition}
        norms_local = jnp.linalg.norm(recv.astype(jnp.float32) - agg[None], axis=1)

    return _verify_audit_tail(
        g_vec, d, pad, recv, agg, s_local, norms_local, iters_used, weights,
        peer_axes, delta_max, z, seed, n_peers, n_loc, fold_idx, my_idx,
        float(tau), True, lvl1_groups, lvl2_groups, audit_k,
        agg_attack_scale, byz_mask, audit_grad,
    )


def _verify_audit_tail(
    g_vec, d, pad, recv, agg, s_local, norms_local, iters_used, weights,
    peer_axes, delta_max, z, seed, n_peers, n_loc, fold_idx, my_idx, tau_v,
    with_checksum, lvl1_groups, lvl2_groups, audit_k, agg_attack_scale,
    byz_mask, audit_grad,
):
    """Shared post-aggregation tail of the verifiable butterfly paths:
    lying-owner simulation, validator audit, sampled-column masking, then
    the table broadcast (:func:`_emit_tables`)."""
    # --- aggregator-shift attack (the lying owner): the Byzantine owner
    # corrupts its partition aggregate AFTER aggregating and recomputes its
    # digests against the corrupted value — self-consistent tables, so the
    # V1 mismatch rule never fires; detection falls to the V2 checksum
    # (linear specs) or the validator audit below (any spec).
    agg_honest = agg
    if agg_attack_scale is not None and byz_mask is not None:
        is_byz = byz_mask[my_idx] > 0
        rms = jnp.linalg.norm(agg) / jnp.sqrt(jnp.float32(agg.shape[0]))
        agg = jnp.where(is_byz, agg + agg_attack_scale * (rms + 1e-8), agg)
        diff = recv.astype(jnp.float32) - agg[None]
        n_att = jnp.linalg.norm(diff, axis=1)
        dots = diff @ z.astype(jnp.float32)
        if tau_v > 0:
            s_att = jnp.minimum(1.0, tau_v / jnp.maximum(n_att, 1e-30)) * dots
        else:
            s_att = dots
        s_local = jnp.where(is_byz, s_att, s_local)
        norms_local = jnp.where(is_byz, n_att, norms_local)

    # --- validator audit arm (launch-side CHOOSETARGET): the shared seed
    # elects one owner column per step; validators recompute that column's
    # aggregation from the same payloads (bit-identical here — agg_honest
    # IS that recompute) and report the max deviation of the value the
    # owner actually broadcast. Exact zero for honest owners.
    t_col = jnp.mod(jnp.asarray(seed, jnp.int32), n_loc)
    audit_agg = jnp.where(
        fold_idx == t_col,
        jnp.max(jnp.abs(agg.astype(jnp.float32)
                        - agg_honest.astype(jnp.float32))),
        0.0,
    )

    # --- sampled-digest masking: only the audit_k owner columns in this
    # step's rotating window broadcast digests; everyone else ships zeros.
    # checksum/votes below are computed FROM the zeroed digests, so the ban
    # policy is silent at unsampled columns by construction (the
    # zero-scatter invariant — core.hierarchy).
    if audit_k is not None:
        k_tot = min(int(audit_k), n_loc)
        sampled_me = jnp.mod(fold_idx - jnp.asarray(seed, jnp.int32), n_loc) < k_tot
        s_local = jnp.where(sampled_me, s_local, 0.0)
        norms_local = jnp.where(sampled_me, norms_local, 0.0)

    extra = {
        "audit_target": jnp.mod(jnp.asarray(seed, jnp.int32), n_peers)[None],
        "audit_grad_mismatch": (
            jnp.zeros((1,), jnp.float32) if audit_grad is None
            else jnp.asarray(audit_grad, jnp.float32)[None]
        ),
        "audit_agg_mismatch": jnp.asarray(audit_agg, jnp.float32)[None],
    }
    return _emit_tables(
        g_vec, d, pad, agg, s_local, norms_local, iters_used, weights,
        peer_axes, delta_max, with_checksum=with_checksum,
        lvl1_groups=lvl1_groups, lvl2_groups=lvl2_groups, extra_verif=extra,
    )


def _emit_tables(g_vec, d, pad, agg, s_local, norms_local, iters_used,
                 weights, peer_axes, delta_max, with_checksum=True,
                 lvl1_groups=None, lvl2_groups=None, extra_verif=None):
    """Shared table-broadcast tail of the verifiable butterfly paths:
    checksum/Delta_max votes from the owner's local tables, the O(n^2)
    scalar table all_gathers, and the aggregated-partition all_gather.
    ``with_checksum=False`` (nonlinear verified:* specs — no zero-sum
    identity) reports a zero checksum so the launch-side ban policy never
    fires on honest finite-precision residue.

    Hierarchical mode (``lvl1_groups``/``lvl2_groups`` set): the owner's
    digest row IS its table row — each peer emits its (gs,) digests under a
    peer-axis out spec, so global table traffic is n*gs scalars instead of
    n^2. The level-2 combine is the active-weight mean of the g group
    aggregates, evaluated by grouped psum at fixed member index (linear in
    the group aggregates, so the zero-sum checksum identity is exact for
    ANY base); each group then reconstructs the same full vector from its
    own level-1 all_gather."""
    if with_checksum:
        checksum = jnp.abs((s_local * weights).sum())
    else:
        checksum = jnp.zeros(())
    votes = ((norms_local > delta_max) * weights).sum() if delta_max is not None else jnp.zeros(())
    if lvl1_groups is not None:
        # hierarchical: per-peer (gs,) table rows (n*gs scalars globally)
        s_table = s_local[None]
        norm_table = norms_local[None]
        w_grp = weights.sum()  # this group's active weight W_a
        num = jax.lax.psum(
            w_grp * agg.astype(jnp.float32), peer_axes,
            axis_index_groups=lvl2_groups,
        )
        den = jax.lax.psum(w_grp, peer_axes, axis_index_groups=lvl2_groups)
        v2 = num / jnp.maximum(den, 1e-30)
        full = jax.lax.all_gather(
            v2.astype(g_vec.dtype), peer_axes, tiled=True,
            axis_index_groups=lvl1_groups,
        )  # (gs*part,) == padded d, same in every group
        # barrier before the upcast: the gather must ship transport dtype
        full = jax.lax.optimization_barrier(full).astype(jnp.float32)
    else:
        # broadcast the scalar tables (O(n^2) data total — size-independent)
        s_table = jax.lax.all_gather(s_local, peer_axes)  # (n_parts, n_peers)
        norm_table = jax.lax.all_gather(norms_local, peer_axes)
        full = jax.lax.all_gather(
            agg.astype(g_vec.dtype), peer_axes, tiled=True
        )  # (n_peers*part,) — gather in transport dtype
        # barrier before the upcast: the gather must ship transport dtype
        full = jax.lax.optimization_barrier(full).astype(jnp.float32)
    if pad:
        full = full[:d]
    # checksum/votes are per-partition (expand-dims -> peer-axis out spec);
    # the gathered s/norm tables are the SAME on every peer (the broadcast)
    # so they leave the region as replicated (n_parts, n_peers) arrays —
    # except hierarchical mode, where each peer's row leaves under the peer
    # axis as a global (n_peers, gs) table.
    verif = {
        "checksum": checksum[None],
        "votes": jnp.asarray(votes)[None],
        "clip_iters": jnp.asarray(iters_used, jnp.int32)[None],
        "s_table": s_table,
        "norm_table": norm_table,
    }
    if extra_verif:
        verif.update(extra_verif)
    return full, verif


def butterfly_stage(
    g_vec, peer_axes, n_peers, tau, clip_iters, weights, seed, use_pallas=False,
    delta_max=None, v0_full=None, adaptive_tol=None,
):
    """DEPRECATED shim — resolves to :func:`aggregation_stage` with the
    equivalent ButterflyClip :class:`AggregatorSpec`."""
    import warnings

    warnings.warn(
        "butterfly_stage is deprecated; call aggregation_stage with an "
        "AggregatorSpec (repro.core.aggregators) instead",
        DeprecationWarning, stacklevel=2,
    )
    from repro.core.aggregators import AggregatorSpec

    spec = AggregatorSpec(
        "butterfly_clip",
        (("adaptive_tol", adaptive_tol), ("n_iters", int(clip_iters)),
         ("tau", float(tau)), ("warm_start", v0_full is not None)),
    )
    return aggregation_stage(
        g_vec, peer_axes, n_peers, spec, weights, seed,
        use_pallas=use_pallas, delta_max=delta_max, v0_full=v0_full,
    )


def device_attack(grads_vec, byz_mask, peer_axes, kind, key, lam=100.0):
    """Device-side Byzantine simulation on the local gradient vector."""
    my_idx = jax.lax.axis_index(peer_axes)
    is_byz = byz_mask[my_idx] > 0
    if kind == "none":
        return grads_vec
    if kind == "sign_flip":
        return jnp.where(is_byz, -lam * grads_vec, grads_vec)
    if kind == "random_direction":
        v = jax.random.normal(key, grads_vec.shape, grads_vec.dtype)
        v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)
        scale = lam * jnp.linalg.norm(grads_vec)
        return jnp.where(is_byz, scale * v, grads_vec)
    if kind == "ipm":
        n_honest = jnp.maximum((1.0 - byz_mask).sum(), 1.0)
        honest_sum = jax.lax.psum(
            jnp.where(is_byz, 0.0, 1.0) * grads_vec, peer_axes
        )
        mu = honest_sum / n_honest
        return jnp.where(is_byz, -0.6 * mu, grads_vec)
    raise ValueError(kind)


# ===========================================================================
# BTARD distributed train step
# ===========================================================================
def _build_btard_step(
    model: Model,
    optimizer,
    mesh,
    shape,
    tau: float = 1.0,
    clip_iters: int = 20,
    attack: str = "none",
    use_pallas: bool = False,
    delta_max: float | None = 1e9,
    zero1: bool = True,
    transport_dtype=jnp.float32,
    warm_start: bool = False,
    adaptive_tol: float | None = None,
    aggregator=None,
    groups: int | None = None,
    audit_k: int | None = None,
    agg_attack: float | None = None,
):
    """Shared construction for the single-step and scanned BTARD steps.

    ``aggregator`` is an :class:`AggregatorSpec` / ``"name[:k=v,...]"``
    string / None (-> flagship ButterflyClip); the legacy knobs (tau /
    clip_iters / adaptive_tol / warm_start) fill the spec's declared params
    as defaults. The shard_map carry/specs derive from the resolved spec's
    capability flags: only a warm-startable spec with ``warm_start`` set
    threads the previous-aggregate input into the aggregation region.

    ``groups`` / ``audit_k`` select the flat-cost verification axes
    (hierarchical butterfly-of-butterflies / sampled-digest mode — see
    :func:`aggregation_stage`); ``agg_attack`` turns on the lying-owner
    simulation at the given shift scale. All three apply to verifiable
    specs only.

    Returns (step_core, mesh, specs dict, abstract args) where
    step_core(params, opt_state, batch, step, seed, byz_mask, weights,
    v_prev) -> (params, opt_state, metrics, verif, v_agg); v_prev / v_agg
    is the flattened previous/current aggregate (the warm-start carry).
    """
    spec = resolve_spec(aggregator).with_defaults(
        tau=tau, n_iters=clip_iters, max_iters=clip_iters,
        adaptive_tol=adaptive_tol, warm_start=warm_start,
    )
    carry_v0 = spec.warm_startable and bool(spec.get("warm_start", False))
    mesh, peer_axes = _collapse_peer_mesh(mesh)
    hier = bool(groups and groups > 1 and spec.verifiable)
    # the non-peer manual axes (model shards) — non-coordinatewise specs
    # join these inside aggregation_stage to see full-vector geometry
    model_axes = tuple(a for a in mesh.axis_names if a not in peer_axes)
    set_mesh(mesh)
    cfg = model.cfg
    n_peers = int(np.prod([mesh.shape[a] for a in peer_axes]))
    if hier:
        from repro.core.hierarchy import group_shape

        group_shape(n_peers, groups)  # validates g | n and gs >= 2

    params_abs = model.abstract_params()
    # replicated over peers: param specs WITHOUT the fsdp axis
    pspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(param_specs(params_abs), mesh), params_abs, mesh
    )
    pspecs = jax.tree.map(
        lambda s: P(*[_drop_data(e) for e in s]), pspecs, is_leaf=lambda x: isinstance(x, P)
    )
    bspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(ispecs.batch_specs(cfg, shape, "train"), mesh),
        ispecs.abstract_batch(cfg, shape, "train"),
        mesh,
    )
    opt_abs = jax.eval_shape(optimizer.init, params_abs)
    ospecs = {k: pspecs for k in opt_abs}

    # ---- stage 1: per-peer grads (manual peers, auto model) ----------------
    def peer_grads(params, batch):
        from repro.sharding.specs import set_manual_axes

        set_manual_axes(peer_axes)  # trace-time: shard() skips peer axes
        try:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True
            )(params, batch)
        finally:
            set_manual_axes(())
        return loss[None], jax.tree.map(lambda g: g[None], grads)

    stage1 = _shard_map(
        peer_grads,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda s: P(), pspecs, is_leaf=_is_p), _peer_lead(bspecs, peer_axes)),
        out_specs=(P(peer_axes), jax.tree.map(lambda s: P(peer_axes), pspecs, is_leaf=_is_p)),
        axis_names=set(peer_axes),
        check_vma=False,
    )

    # ---- stage 2: butterfly robust all-reduce (fully manual) ---------------
    def butterfly_all(grads, seed, byz_mask, weights, key, *rest):
        leaves = jax.tree.leaves(grads)
        # beyond-paper: gradients can travel the butterfly in bf16 — halves
        # the all_to_all + all_gather volume; CenteredClip still iterates in
        # f32 (EXPERIMENTS.md §Perf H3)
        vec = _flatten_local([l[0] for l in leaves], transport_dtype)
        vec_honest = vec
        vec = device_attack(vec, byz_mask, peer_axes, attack, key)
        # per-peer public-seed spot-check residue: every peer's max
        # deviation between the payload it broadcast and the recompute from
        # the public batch (vec_honest IS that recompute here) — exact zero
        # for honest peers. The host membership layer consumes this for
        # PROBATION slots only (the Sybil gate of core.sybil: a joining
        # peer is spot-checked every step of its probation window), the
        # protocol-faithful subset of a per-peer observable.
        probe = jnp.max(jnp.abs(vec.astype(jnp.float32)
                                - vec_honest.astype(jnp.float32)))
        if model_axes:
            probe = jax.lax.pmax(probe, model_axes)
        audit_grad = None
        if spec.verifiable:
            # gradient-recompute audit (CHOOSETARGET's payload arm): the
            # shared seed elects one peer; validators recompute its
            # gradient from the PUBLIC batch — bit-identical here, the
            # pre-attack vector IS that recompute — and report the max
            # deviation of the payload it actually sent. Exact zero for
            # honest peers, so the host ban policy can fire on any nonzero
            # regardless of the spec's digest linearity.
            t_peer = jnp.mod(jnp.asarray(seed, jnp.int32), n_peers)
            audit_grad = jnp.where(
                jax.lax.axis_index(peer_axes) == t_peer,
                jnp.max(jnp.abs(vec.astype(jnp.float32)
                                - vec_honest.astype(jnp.float32))),
                0.0,
            )
        v0_full = None
        if carry_v0:
            # previous aggregate, flattened in the SAME leaf order as vec
            v0_full = _flatten_local(jax.tree.leaves(rest[0]), jnp.float32)
        agg_vec, verif = aggregation_stage(
            vec, peer_axes, n_peers, spec, weights, seed,
            use_pallas=use_pallas, delta_max=delta_max, v0_full=v0_full,
            gather_axes=model_axes, groups=groups if hier else None,
            audit_k=audit_k if spec.verifiable else None,
            agg_attack_scale=agg_attack, byz_mask=byz_mask,
            audit_grad=audit_grad,
        )
        agg_leaves = _unflatten_local(agg_vec, [l[0] for l in leaves])
        agg = jax.tree.unflatten(jax.tree.structure(grads), agg_leaves)
        verif["probe_mismatch"] = probe[None]
        return agg, verif

    manual_pspecs = jax.tree.map(
        lambda s: P(peer_axes, *s), pspecs, is_leaf=_is_p
    )
    agg_specs = pspecs  # the aggregate tree shards exactly like the params
    stage2 = _shard_map(
        butterfly_all,
        mesh=mesh,
        in_specs=(manual_pspecs, P(), P(), P(), P())
        + ((agg_specs,) if carry_v0 else ()),
        out_specs=(
            agg_specs,
            {
                "checksum": P(peer_axes),
                "votes": P(peer_axes),
                "clip_iters": P(peer_axes),
                # hierarchical tables leave per-peer ((n, gs) global rows);
                # flat tables are the replicated post-broadcast (n, n)
                "s_table": P(peer_axes, None) if hier else P(None, None),
                "norm_table": P(peer_axes, None) if hier else P(None, None),
                "audit_target": P(peer_axes),
                "audit_grad_mismatch": P(peer_axes),
                "audit_agg_mismatch": P(peer_axes),
                "probe_mismatch": P(peer_axes),
            },
        ),
        axis_names=set(mesh.axis_names),
        check_vma=False,
    )

    def step_core(params, opt_state, batch, step, seed, byz_mask, weights,
                  v_prev=None):
        loss, grads = stage1(params, batch)
        # attack key from the traced (seed, step) pair — a literal-seeded
        # key here would be randomness outside the protocol transcript
        # (btard-lint purity rule; the MPRNG chain covers all other keys)
        key = jax.random.fold_in(jax.random.key(seed), step)
        rest = (v_prev,) if carry_v0 else ()
        agg, verif = stage2(grads, seed, byz_mask, weights, key, *rest)
        updates, opt_state = optimizer.update(agg, opt_state, params, step)
        params = apply_updates(params, updates)
        metrics = {
            "loss": loss.mean(),
            "checksum_max": verif["checksum"].max(),
            "votes_max": verif["votes"].max(),
            "clip_iters_max": verif["clip_iters"].max(),
        }
        return params, opt_state, metrics, verif, agg

    if zero1:
        zaxis = peer_axes[0] if len(peer_axes) == 1 else "data"
        n_zshards = mesh.shape.get(zaxis, 1)
        ospecs = {
            k: jax.tree.map(
                lambda s, l: _with_data(s, l.shape, n_zshards, zaxis),
                pspecs,
                opt_abs[k],
                is_leaf=_is_p,
            )
            for k in opt_abs
        }

    specs = {
        "params": pspecs,
        "opt": ospecs,
        "batch": bspecs,
        "agg": agg_specs,
    }
    abstract_args = (
        params_abs,
        opt_abs,
        ispecs.abstract_batch(cfg, shape, "train"),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((n_peers,), jnp.float32),
        jax.ShapeDtypeStruct((n_peers,), jnp.float32),
    )
    return step_core, mesh, specs, abstract_args


def make_btard_train_step(
    model: Model,
    optimizer,
    mesh,
    shape,
    tau: float = 1.0,
    clip_iters: int = 20,
    attack: str = "none",
    use_pallas: bool = False,
    delta_max: float | None = 1e9,
    zero1: bool = True,
    transport_dtype=jnp.float32,
    adaptive_tol: float | None = None,
    aggregator=None,
    groups: int | None = None,
    audit_k: int | None = None,
    agg_attack: float | None = None,
):
    """Returns (jitted step, abstract args).

    step(params, opt_state, batch, step_idx, seed, byz_mask, weights)
      -> (params, opt_state, metrics, verif)
    Params are replicated over the peer axes (each peer = full replica,
    model-sharded over 'model'); optimizer state is ZeRO-1-sharded over the
    peer axis when zero1 (the butterfly partition owner updates its shard —
    exactly Alg. 7's per-partition ownership). ``aggregator`` selects the
    robust aggregation stage by AggregatorSpec (default ButterflyClip).

    The single-step API carries no previous aggregate between calls, so a
    spec's ``warm_start`` is forced off here — use
    :func:`make_btard_scan_train_step`, whose v_prev carry implements it.
    """
    spec = resolve_spec(aggregator)
    if "warm_start" in spec.definition.param_names:
        spec = spec.override(warm_start=False)
    step_core, mesh, specs, abstract_args = _build_btard_step(
        model, optimizer, mesh, shape, tau=tau, clip_iters=clip_iters,
        attack=attack, use_pallas=use_pallas, delta_max=delta_max,
        zero1=zero1, transport_dtype=transport_dtype, warm_start=False,
        adaptive_tol=adaptive_tol, aggregator=spec, groups=groups,
        audit_k=audit_k, agg_attack=agg_attack,
    )

    def train_step(params, opt_state, batch, step, seed, byz_mask, weights):
        params, opt_state, metrics, verif, _ = step_core(
            params, opt_state, batch, step, seed, byz_mask, weights
        )
        return params, opt_state, metrics, verif

    jitted = jax.jit(
        train_step,
        in_shardings=(
            _named(mesh, specs["params"]),
            _named(mesh, specs["opt"]),
            _named(mesh, specs["batch"]),
            None,
            None,
            None,
            None,
        ),
        out_shardings=(
            _named(mesh, specs["params"]), _named(mesh, specs["opt"]),
            None, None,
        ),
    )
    return jitted, abstract_args


def make_btard_scan_train_step(
    model: Model,
    optimizer,
    mesh,
    shape,
    n_scan_steps: int,
    tau: float = 1.0,
    clip_iters: int = 20,
    attack: str = "none",
    use_pallas: bool = False,
    delta_max: float | None = 1e9,
    zero1: bool = True,
    transport_dtype=jnp.float32,
    warm_start: bool = False,
    adaptive_tol: float | None = None,
    aggregator=None,
    pipeline=None,
    extras=None,
    groups: int | None = None,
    audit_k: int | None = None,
    agg_attack: float | None = None,
):
    """The BTARD train step under ``lax.scan``: ``n_scan_steps`` full rounds
    per dispatch, one compiled program, zero host sync between rounds.

    Host-batch mode (pipeline=None):
      step(params, opt_state, batches, steps, seeds, byz_mask, weights,
      v_prev) -> (params, opt_state, metrics, verif, v_last)
      batches: the single-step batch tree with a leading (n_scan_steps,) dim.

    Device-resident mode (pipeline = a ``repro.data.TokenPipeline``):
      step(params, opt_state, steps, seeds, byz_mask, weights, v_prev)
      Each round's batch is generated INSIDE the scan body from the public
      ``peer_key`` chain (``pipeline.device_batch``) and sharded to the
      batch specs — zero host->device batch bytes per step, and the bits
      match the host pipeline exactly (tests/test_device_data.py), so
      verification/accusation semantics are unchanged.

    steps / seeds: (n_scan_steps,) i32. v_prev / v_last: the aggregate tree
    (zeros_like(params) to start) — with ``warm_start`` each round's
    CenteredClip starts from the previous round's aggregate, which cuts the
    iteration budget (see kernels/DESIGN.md); without it the carry is
    threaded but unused. ``adaptive_tol`` makes that saving automatic: the
    clip loop early-exits at ||v_{l+1}-v_l|| <= tol (clip_iters = cap).
    metrics / verif gain a leading scan dim.
    Returns (jitted step, abstract args).
    """
    step_core, mesh, specs, abstract_args = _build_btard_step(
        model, optimizer, mesh, shape, tau=tau, clip_iters=clip_iters,
        attack=attack, use_pallas=use_pallas, delta_max=delta_max,
        zero1=zero1, transport_dtype=transport_dtype, warm_start=warm_start,
        adaptive_tol=adaptive_tol, aggregator=aggregator, groups=groups,
        audit_k=audit_k, agg_attack=agg_attack,
    )
    agg_shardings = _named(mesh, specs["agg"])
    # the in-scan generator is pinned REPLICATED: every peer generates the
    # full public batch and slices its share — the paper's public-data model
    # (any peer recomputes any batch), and the only sharding under which the
    # non-partitionable threefry PRNG emits the SAME bits as the host
    # pipeline (GSPMD partitioning of the generator changes random bits;
    # tested in tests/test_device_data.py). Generation cost is trivial next
    # to fwd+bwd; the peer-sharded consumer reshards with a local slice.
    replicated_batch = jax.tree.map(
        lambda s: NamedSharding(mesh, P()), specs["batch"], is_leaf=_is_p
    )

    def body_of(batch_for, byz_mask, weights):
        def body(carry, xs):
            params, opt_state, v_prev = carry
            step, seed = xs[-2], xs[-1]
            batch = batch_for(xs)
            params, opt_state, metrics, verif, agg = step_core(
                params, opt_state, batch, step, seed, byz_mask, weights,
                v_prev=v_prev,
            )
            return (params, opt_state, agg), (metrics, verif)

        return body

    if pipeline is not None:

        def scan_step(params, opt_state, steps, seeds, byz_mask, weights,
                      v_prev):
            def batch_for(xs):
                # the in-scan data phase: public-seed batch for this round,
                # generated on device (replicated — see replicated_batch)
                batch = pipeline.device_batch(xs[-2], extras=extras)
                return jax.tree.map(
                    jax.lax.with_sharding_constraint, batch, replicated_batch
                )

            (params, opt_state, v_last), (metrics, verif) = jax.lax.scan(
                body_of(batch_for, byz_mask, weights),
                (params, opt_state, v_prev), (steps, seeds),
            )
            return params, opt_state, metrics, verif, v_last

        in_shardings = (
            _named(mesh, specs["params"]), _named(mesh, specs["opt"]),
            None, None, None, None, agg_shardings,
        )
    else:

        def scan_step(params, opt_state, batches, steps, seeds, byz_mask,
                      weights, v_prev):
            (params, opt_state, v_last), (metrics, verif) = jax.lax.scan(
                body_of(lambda xs: xs[0], byz_mask, weights),
                (params, opt_state, v_prev), (batches, steps, seeds),
            )
            return params, opt_state, metrics, verif, v_last

        # stacked batches: leading scan dim replicated, per-step as before
        scan_bspecs = jax.tree.map(
            lambda s: P(None, *s), specs["batch"], is_leaf=_is_p
        )
        in_shardings = (
            _named(mesh, specs["params"]), _named(mesh, specs["opt"]),
            _named(mesh, scan_bspecs), None, None, None, None, agg_shardings,
        )

    jitted = jax.jit(
        scan_step,
        in_shardings=in_shardings,
        out_shardings=(
            _named(mesh, specs["params"]), _named(mesh, specs["opt"]),
            None, None, agg_shardings,
        ),
    )
    p_abs, o_abs, b_abs, step_abs, seed_abs, byz_abs, w_abs = abstract_args
    stack = lambda tree: jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_scan_steps,) + l.shape, l.dtype), tree
    )
    steps_abs = jax.ShapeDtypeStruct((n_scan_steps,), jnp.int32)
    v_abs = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), p_abs
    )
    if pipeline is not None:
        scan_abstract = (p_abs, o_abs, steps_abs, steps_abs, byz_abs, w_abs,
                         v_abs)
    else:
        scan_abstract = (p_abs, o_abs, stack(b_abs), steps_abs, steps_abs,
                         byz_abs, w_abs, v_abs)
    return jitted, scan_abstract


def _is_p(x):
    return isinstance(x, P)


def _drop_data(entry):
    if entry in ("data", "pod", "peers"):
        return None
    if isinstance(entry, (tuple, list)):
        kept = tuple(a for a in entry if a not in ("data", "pod", "peers"))
        return kept or None
    return entry


def _with_data(spec, shape, n_shards, axis="data"):
    """ZeRO-1: shard the first shardable (unsharded & divisible) dim of the
    moment buffers on the peer axis — the butterfly partition owner updates
    its shard."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % n_shards == 0:
            entries[i] = axis
            return P(*entries)
    return P(*entries)


def _peer_lead(bspecs, peer_axes):
    def fix(s):
        return P(peer_axes, *list(s)[1:])

    return jax.tree.map(fix, bspecs, is_leaf=_is_p)


# ===========================================================================
# Serving steps
# ===========================================================================
def make_decode_step(model: Model, mesh, shape, fsdp_params: bool | None = None):
    set_mesh(mesh)
    params_abs = model.abstract_params()
    if fsdp_params is None:
        per_chip = model.param_count() * 2 / mesh.shape["model"]
        fsdp_params = per_chip > 10e9  # replicate unless it would not fit
    pspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(param_specs(params_abs), mesh), params_abs, mesh
    )
    if not fsdp_params:
        pspecs = jax.tree.map(
            lambda s: P(*[_drop_data(e) for e in s]), pspecs, is_leaf=_is_p
        )
    cspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(ispecs.cache_specs(model, shape, mesh), mesh),
        ispecs.abstract_cache(model, shape),
        mesh,
    )
    bspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(ispecs.batch_specs(model.cfg, shape, "decode"), mesh),
        ispecs.abstract_batch(model.cfg, shape, "decode"),
        mesh,
    )

    def decode(params, cache, batch):
        logits, new_cache = model.decode_step(params, batch, cache)
        return logits, new_cache

    jitted = jax.jit(
        decode,
        in_shardings=(
            _named(mesh, pspecs),
            _named(mesh, cspecs),
            _named(mesh, bspecs),
        ),
        out_shardings=(None, _named(mesh, cspecs)),
    )
    abstract_args = (
        params_abs,
        ispecs.abstract_cache(model, shape),
        ispecs.abstract_batch(model.cfg, shape, "decode"),
    )
    return jitted, abstract_args


def make_prefill_step(model: Model, mesh, shape, fsdp_params: bool = True):
    set_mesh(mesh)
    params_abs = model.abstract_params()
    pspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(param_specs(params_abs), mesh), params_abs, mesh
    )
    if not fsdp_params:
        pspecs = jax.tree.map(
            lambda s: P(*[_drop_data(e) for e in s]), pspecs, is_leaf=_is_p
        )
    cspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(ispecs.cache_specs(model, shape, mesh), mesh),
        ispecs.abstract_cache(model, shape),
        mesh,
    )
    bspecs = ispecs.sanitize_specs(
        ispecs.resolve_spec_names(ispecs.batch_specs(model.cfg, shape, "prefill"), mesh),
        ispecs.abstract_batch(model.cfg, shape, "prefill"),
        mesh,
    )

    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache)

    jitted = jax.jit(
        prefill,
        in_shardings=(
            _named(mesh, pspecs),
            _named(mesh, bspecs),
            _named(mesh, cspecs),
        ),
        out_shardings=(None, _named(mesh, cspecs)),
    )
    abstract_args = (
        params_abs,
        ispecs.abstract_batch(model.cfg, shape, "prefill"),
        ispecs.abstract_cache(model, shape),
    )
    return jitted, abstract_args
