"""Msgpack pytree checkpointing (params, optimizer state, step, metadata).

Arrays are stored as (dtype, shape, raw bytes); the tree structure is
path-keyed so restore does not need an example tree. Writes are atomic
(tmp + rename) — a crashed save never corrupts the previous checkpoint.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        flat[key] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def _path_str(p):
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, tree, step: int = 0, meta: dict | None = None):
    payload = {
        "step": step,
        "meta": meta or {},
        "arrays": _flatten(tree),
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path: str, example_tree=None):
    """Returns (tree, step, meta). With example_tree the stored arrays are
    mapped back into its structure (and dtypes cast to match); without it, a
    flat {path: array} dict is returned."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    arrays = {
        k: np.frombuffer(v["data"], dtype=v["dtype"]).reshape(v["shape"])
        for k, v in payload["arrays"].items()
    }
    if example_tree is None:
        return arrays, payload["step"], payload["meta"]

    paths, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    leaves = []
    for path, leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = arrays[key]
        leaves.append(jnp.asarray(arr).astype(leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves), payload["step"], payload["meta"]
