"""Msgpack pytree checkpointing (params, optimizer state, step, metadata).

Arrays are stored as (dtype, shape, raw bytes); the tree structure is
path-keyed so restore does not need an example tree. Writes are atomic
(tmp + rename) — a crashed save never corrupts the previous checkpoint.

Dtype fidelity is exact for every leaf the protocol carries: bf16 wire
buffers and int8 codec state round-trip through their own byte width (not a
float64 detour), and the MPRNG uint32 key chain restores as uint32 — the
scan-resume bitwise property needs the restored state to be the SAME BITS,
not a value-preserving cast. Restores are writable copies (``frombuffer``
views are read-only) and checked against ``FORMAT_VERSION``: a checkpoint
from a different layout generation is rejected with a clear error instead
of a downstream shape/index crash (NamedTuple paths are positional, so a
field added to ``ProtocolState`` silently shifts every index).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

# Bump whenever the on-disk layout changes meaning — e.g. a field added to a
# NamedTuple in the saved tree (positional paths renumber), or a change to
# how arrays are encoded. v1 = the unversioned seed format; v2 adds the
# version field + elastic-membership state in ProtocolState.
FORMAT_VERSION = 2


def _np_dtype(name: str) -> np.dtype:
    """Resolve a stored dtype string, including the ml_dtypes extension
    types (bfloat16, float8_*) that plain ``np.dtype`` only knows when
    ml_dtypes has registered them."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        flat[key] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def _path_str(p):
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, tree, step: int = 0, meta: dict | None = None):
    payload = {
        "format_version": FORMAT_VERSION,
        "step": step,
        "meta": meta or {},
        "arrays": _flatten(tree),
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path: str, example_tree=None):
    """Returns (tree, step, meta). With example_tree the stored arrays are
    mapped back into its structure (and dtypes cast to match); without it, a
    flat {path: array} dict is returned. Raises ValueError on a checkpoint
    written by a different format generation."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    version = payload.get("format_version", 1)
    if version != FORMAT_VERSION:
        raise ValueError(
            f"checkpoint {path!r} has format_version={version}, this build "
            f"reads format_version={FORMAT_VERSION} — the saved tree layout "
            "is incompatible (positional NamedTuple paths do not survive "
            "field changes); re-save from a matching build instead of "
            "restoring it here"
        )
    arrays = {
        # copy(): frombuffer views are read-only and would poison any
        # in-place consumer of the restored tree
        k: np.frombuffer(v["data"], dtype=_np_dtype(v["dtype"]))
        .reshape(v["shape"])
        .copy()
        for k, v in payload["arrays"].items()
    }
    if example_tree is None:
        return arrays, payload["step"], payload["meta"]

    paths, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    leaves = []
    for path, leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = arrays[key]
        leaf_dtype = getattr(leaf, "dtype", None)
        if leaf_dtype is not None and arr.dtype != np.dtype(leaf_dtype):
            # a cast here is a VALUE restore, not a bit restore — allowed
            # (e.g. loading f32 params into a bf16 eval tree), but the
            # stored dtype always wins when the example agrees
            arr = arr.astype(leaf_dtype)
        leaves.append(jnp.asarray(arr).reshape(np.shape(leaf)))
    return (
        jax.tree_util.tree_unflatten(treedef, leaves),
        payload["step"],
        payload["meta"],
    )
