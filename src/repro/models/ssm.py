"""Mamba-2 SSD (state-space duality) mixer — chunked dual form.

Training/prefill uses the chunked algorithm from arXiv:2405.21060 §6: each
chunk is a small quadratic attention-like block (MXU-friendly matmuls), and
chunk states are combined with an *associative scan* (log-depth, fully
counted by cost_analysis — see DESIGN.md on scan accounting).

Decode carries (state, conv buffer) and performs the linear recurrence step.
n_groups = 1 (B/C shared across heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import cdtype, conv1d_init, causal_conv1d, causal_conv1d_step, dense_init
from repro.sharding import shard


def ssm_init(key, cfg, spec=None):
    dt = cdtype(cfg)
    ks = jax.random.split(key, 4)
    d_in = cfg.ssm_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    conv_ch = d_in + 2 * N
    p = {
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * d_in + 2 * N + H, dt),
        "out_proj": dense_init(ks[1], d_in, cfg.d_model, dt),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": jnp.ones((d_in,), jnp.float32),
    }
    p.update(conv1d_init(ks[2], conv_ch, cfg.ssm_conv, dt))
    return p


def _split_proj(cfg, zxbcdt):
    d_in, N, H = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in : 2 * d_in]
    Bc = zxbcdt[..., 2 * d_in : 2 * d_in + N]
    Cc = zxbcdt[..., 2 * d_in + N : 2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N :]
    return z, x, Bc, Cc, dt


def _gated_norm(p, cfg, y, z):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    yn = yf * jax.lax.rsqrt((yf**2).mean(-1, keepdims=True) + cfg.norm_eps)
    return (yn * p["gate_norm"]).astype(y.dtype)


def ssd_chunked(x, a_log, dt, Bm, Cm, chunk, init_state=None):
    """Chunked SSD.

    x:  (B, S, H, P)   inputs per head
    a_log: (B, S, H)   per-step log decay  (= dt * A, negative)
    dt: (B, S, H)      input step sizes
    Bm, Cm: (B, S, N)  shared input/output projections (n_groups=1)
    Returns y (B, S, H, P) and final state (B, H, N, P).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # pad tail with dt=0 steps: decay=1, zero input => state untouched
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    ac = a_log.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    lcum = jnp.cumsum(ac, axis=2)  # (B,nc,Q,H) inclusive cumulative log decay
    # --- intra-chunk (quadratic within chunk) ------------------------------
    # L[i,j] = exp(lcum_i - lcum_j) for j <= i  (decay from j+1..i)
    seg = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]  # (B,nc,Q_i,Q_j,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc, preferred_element_type=jnp.float32)
    M = G[..., None] * L * dtc[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(x.dtype), xc)

    # --- chunk-local final states ------------------------------------------
    decay_to_end = jnp.exp(lcum[:, :, -1:, :] - lcum)  # (B,nc,Q,H)
    wB = Bc[:, :, :, None, :] * (dtc * decay_to_end)[..., None]  # (B,nc,Q,H,N)
    S_local = jnp.einsum("bcqhn,bcqhp->bchnp", wB.astype(x.dtype), xc)

    # --- inter-chunk associative scan ---------------------------------------
    chunk_decay = jnp.exp(lcum[:, :, -1, :])  # (B,nc,H)

    def combine(l, r):
        al, sl = l
        ar, sr = r
        return al * ar, sl * ar[..., None, None] + sr

    a_all, S_all = jax.lax.associative_scan(
        combine, (chunk_decay, S_local.astype(jnp.float32)), axis=1
    )
    if init_state is not None:
        S_all = S_all + a_all[..., None, None] * init_state[:, None].astype(jnp.float32)
    # state entering chunk c = S_all[c-1] (shifted), or init_state for c=0
    if init_state is None:
        S_in = jnp.concatenate(
            [jnp.zeros(S_all[:, :1].shape, S_all.dtype), S_all[:, :-1]], axis=1
        )
    else:
        S_in = jnp.concatenate(
            [init_state[:, None].astype(jnp.float32), S_all[:, :-1]], axis=1
        )
    y_inter = jnp.einsum(
        "bcqn,bchnp->bcqhp",
        Cc,
        S_in.astype(Cc.dtype),
    ) * jnp.exp(lcum)[..., None].astype(x.dtype)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)[:, :S_orig]
    return y, S_all[:, -1]


def ssm_apply(p, cfg, spec, x, *, pos=None, memory=None, cache=None, mode="train"):
    B, S, _ = x.shape
    d_in, N, H, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    A = -jnp.exp(p["A_log"])  # (H,) negative

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    zxbcdt = shard(zxbcdt, "batch", None, "model")
    z, xs, Bm, Cm, dt_raw = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    new_cache = {} if cache is not None else None
    if mode == "decode":
        conv_buf, conv_out = causal_conv1d_step(p, cache["conv"], conv_in[:, 0])
        conv_out = jax.nn.silu(conv_out)[:, None]
        new_cache["conv"] = conv_buf
    else:
        conv_out = jax.nn.silu(causal_conv1d(p, conv_in))
        if new_cache is not None:
            pad = max(0, (cfg.ssm_conv - 1) - S)
            tail = conv_in[:, S - (cfg.ssm_conv - 1) :] if S >= cfg.ssm_conv - 1 else (
                jnp.pad(conv_in, ((0, 0), (pad, 0), (0, 0)))
            )
            new_cache["conv"] = tail

    xs = conv_out[..., :d_in].reshape(B, -1, H, P)
    Bm = conv_out[..., d_in : d_in + N]
    Cm = conv_out[..., d_in + N :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a_log = dt * A  # (B,S,H), negative

    if mode == "decode":
        state = cache["state"].astype(jnp.float32)  # (B,H,N,P)
        a = jnp.exp(a_log[:, 0])  # (B,H)
        inc = jnp.einsum("bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32), (dt[:, 0][..., None] * xs[:, 0].astype(jnp.float32)))
        state = state * a[..., None, None] + inc
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), state)
        y = y + p["D"][:, None] * xs[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype)  # (B,1,H,P)
        new_cache["state"] = state.astype(cache["state"].dtype)
    else:
        y, final_state = ssd_chunked(xs, a_log, dt, Bm, Cm, cfg.ssm_chunk)
        y = y + (p["D"][None, None, :, None] * xs.astype(jnp.float32)).astype(y.dtype)
        if new_cache is not None:
            new_cache["state"] = final_state.astype(cdtype(cfg))

    y = y.reshape(B, -1, d_in)
    y = _gated_norm(p, cfg, y, z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, new_cache


def ssm_cache_shape(cfg, spec, batch, seq_len, has_memory):
    dt = cdtype(cfg)
    d_in, N = cfg.ssm_inner, cfg.ssm_state
    return {
        "state": ((batch, cfg.ssm_heads, N, cfg.ssm_head_dim), dt),
        "conv": ((batch, cfg.ssm_conv - 1, d_in + 2 * N), dt),
    }
