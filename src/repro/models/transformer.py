"""Block assembly: mixer dispatch, macro-block scan over the repeated pattern.

The ONLY lax.scan in the model is the macro-block scan (see DESIGN.md on
cost_analysis scan accounting). ``scan_groups`` exposes (body, trip_count)
probes so launch/dryrun.py can correct roofline terms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, mlp_init, norm_init
from repro.sharding import shard

_MIXERS = {
    "attn_full": (attn.gqa_init, attn.gqa_apply, attn.gqa_cache_shape),
    "attn_local": (attn.gqa_init, attn.gqa_apply, attn.gqa_cache_shape),
    "attn_cross": (attn.gqa_init, None, attn.gqa_cache_shape),
    "mla": (attn.mla_init, attn.mla_apply, attn.mla_cache_shape),
    "ssm": (ssm_mod.ssm_init, ssm_mod.ssm_apply, ssm_mod.ssm_cache_shape),
    "rglru": (rglru_mod.rglru_init, rglru_mod.rglru_apply, rglru_mod.rglru_cache_shape),
}


# ---------------------------------------------------------------------------
# One residual block
# ---------------------------------------------------------------------------
def block_init(key, cfg, spec):
    ks = jax.random.split(key, 4)
    init_fn = _MIXERS[spec.mixer][0]
    p = {"norm1": norm_init(cfg), "mixer": init_fn(ks[0], cfg, spec) if spec.mixer != "attn_cross" else init_fn(ks[0], cfg, spec)}
    if spec.cross:
        p["norm_x"] = norm_init(cfg)
    if spec.mlp == "dense":
        p["norm2"] = norm_init(cfg)
        p["mlp"] = mlp_init(ks[1], cfg)
    elif spec.mlp == "moe":
        p["norm2"] = norm_init(cfg)
        p["moe"] = moe_mod.moe_init(ks[2], cfg, spec)
    return p


def block_apply(p, cfg, spec, x, *, pos, memory, cache, mode):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    h = apply_norm(p["norm1"], cfg, x)
    if spec.mixer == "attn_cross":
        y, c = attn.cross_attn_apply(
            p["mixer"], cfg, spec, h, memory=memory, cache=cache.get("mixer") if cache else None, mode=mode
        )
    else:
        apply_fn = _MIXERS[spec.mixer][1]
        y, c = apply_fn(
            p["mixer"], cfg, spec, h,
            pos=pos, memory=memory,
            cache=cache.get("mixer") if cache else None, mode=mode,
        )
    x = x + y
    if new_cache is not None:
        new_cache["mixer"] = c or {}

    if spec.cross and spec.mixer != "attn_cross":
        h = apply_norm(p["norm_x"], cfg, x)
        y, c = attn.cross_attn_apply(
            p["mixer"], cfg, spec, h, memory=memory,
            cache=cache.get("cross") if cache else None, mode=mode,
        )
        x = x + y
        if new_cache is not None:
            new_cache["cross"] = c or {}

    if spec.mlp == "dense":
        x = x + apply_mlp(p["mlp"], cfg, apply_norm(p["norm2"], cfg, x))
    elif spec.mlp == "moe":
        y, aux_l = moe_mod.moe_apply(p["moe"], cfg, apply_norm(p["norm2"], cfg, x))
        x = x + y
        aux = aux + aux_l
    x = shard(x, "batch", "seqp", None)
    return x, new_cache, aux


def block_cache_shapes(cfg, spec, batch, seq_len):
    shapes = {}
    cache_fn = _MIXERS[spec.mixer][2]
    shapes["mixer"] = cache_fn(cfg, spec, batch, seq_len, cfg.has_encoder)
    if spec.cross and spec.mixer != "attn_cross":
        shapes["cross"] = {
            k: v
            for k, v in attn.gqa_cache_shape(cfg, spec, batch, seq_len, True).items()
            if k.startswith("mem_")
        }
        shapes["mixer"] = {
            k: v for k, v in shapes["mixer"].items() if not k.startswith("mem_")
        }
    return shapes


# ---------------------------------------------------------------------------
# Stack: prefix (unscanned) + pattern (scanned macro-blocks) + suffix
# ---------------------------------------------------------------------------
def stack_init(key, cfg):
    p = {}
    kp, kq, ks = jax.random.split(key, 3)
    if cfg.prefix:
        p["prefix"] = [
            block_init(jax.random.fold_in(kp, i), cfg, s)
            for i, s in enumerate(cfg.prefix)
        ]
    if cfg.pattern and cfg.n_repeats:
        def one_macro(k):
            return {
                f"l{i}": block_init(jax.random.fold_in(k, i), cfg, s)
                for i, s in enumerate(cfg.pattern)
            }

        if cfg.share_pattern_params:
            p["pattern"] = one_macro(kq)
        else:
            p["pattern"] = jax.vmap(one_macro)(jax.random.split(kq, cfg.n_repeats))
    if cfg.suffix:
        p["suffix"] = [
            block_init(jax.random.fold_in(ks, i), cfg, s)
            for i, s in enumerate(cfg.suffix)
        ]
    return p


def _constrain_block_params(params_t):
    """Re-assert FSDP sharding on the per-iteration param slice so XLA
    all-gathers each layer INSIDE the scan body (ZeRO-3) instead of
    gathering the whole stacked leaf up front (EXPERIMENTS.md §Perf H2)."""
    from repro.sharding.specs import get_manual_axes, get_mesh, param_specs

    mesh = get_mesh()
    if mesh is None or "data" in get_manual_axes():
        return params_t
    from jax.sharding import NamedSharding, PartitionSpec as P

    specs = param_specs(params_t, stacked_prefixes=())
    axes = set(mesh.axis_names)

    def fix(leaf, spec):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        ok = []
        for dim, e in zip(leaf.shape, entries):
            if e is None or e not in axes:
                ok.append(None)
                continue
            ok.append(e if dim % mesh.shape[e] == 0 else None)
        return jax.lax.with_sharding_constraint(leaf, NamedSharding(mesh, P(*ok)))

    return jax.tree.map(fix, params_t, specs, is_leaf=lambda s: isinstance(s, P))


def _macro_apply(params_t, cfg, x, *, pos, memory, cache_t, mode, remat):
    """Apply one macro-block (len(cfg.pattern) sub-blocks)."""
    params_t = _constrain_block_params(params_t)
    new_caches = {}
    aux = jnp.zeros((), jnp.float32)

    def run(x):
        nonlocal new_caches, aux
        out = x
        for i, spec in enumerate(cfg.pattern):
            c = cache_t.get(f"l{i}") if cache_t is not None else None
            out, nc, a = block_apply(
                params_t[f"l{i}"], cfg, spec, out,
                pos=pos, memory=memory, cache=c, mode=mode,
            )
            if cache_t is not None:
                new_caches[f"l{i}"] = nc
            aux = aux + a
        return out

    x = run(x)
    return x, new_caches, aux


def stack_apply(p, cfg, x, *, pos, memory=None, cache=None, mode="train", remat=True):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None

    if cfg.prefix:
        pc = []
        for i, spec in enumerate(cfg.prefix):
            c = cache["prefix"][i] if cache is not None else None
            x, nc, a = block_apply(
                p["prefix"][i], cfg, spec, x, pos=pos, memory=memory, cache=c, mode=mode
            )
            aux = aux + a
            pc.append(nc)
        if new_cache is not None:
            new_cache["prefix"] = pc

    if cfg.pattern and cfg.n_repeats:
        shared = cfg.share_pattern_params

        def body(carry, xs):
            xx, aa = carry
            params_t = p["pattern"] if shared else xs[0]
            cache_t = xs[1] if cache is not None else None
            fn = _macro_apply
            if remat and mode == "train":
                fn = jax.checkpoint(
                    lambda pt, xv, ct: _macro_apply(
                        pt, cfg, xv, pos=pos, memory=memory,
                        cache_t=ct, mode=mode, remat=False,
                    ),
                    static_argnums=(),
                )
                xx, nc, a = fn(params_t, xx, cache_t)
            else:
                xx, nc, a = _macro_apply(
                    params_t, cfg, xx, pos=pos, memory=memory,
                    cache_t=cache_t, mode=mode, remat=False,
                )
            return (xx, aa + a), nc

        xs_params = None if shared else p["pattern"]
        xs_cache = cache["pattern"] if cache is not None else None
        if xs_params is None and xs_cache is None:
            xs = (None, None)
            (x, aux), ncs = jax.lax.scan(
                lambda c, _: body(c, (None, None)), (x, aux), None,
                length=cfg.n_repeats,
            )
        else:
            xs = (xs_params, xs_cache)
            (x, aux), ncs = jax.lax.scan(body, (x, aux), xs)
        if new_cache is not None:
            new_cache["pattern"] = ncs

    if cfg.suffix:
        sc = []
        for i, spec in enumerate(cfg.suffix):
            c = cache["suffix"][i] if cache is not None else None
            x, nc, a = block_apply(
                p["suffix"][i], cfg, spec, x, pos=pos, memory=memory, cache=c, mode=mode
            )
            aux = aux + a
            sc.append(nc)
        if new_cache is not None:
            new_cache["suffix"] = sc

    return x, new_cache, aux


def stack_cache_shapes(cfg, batch, seq_len):
    cache = {}
    if cfg.prefix:
        cache["prefix"] = [
            block_cache_shapes(cfg, s, batch, seq_len) for s in cfg.prefix
        ]
    if cfg.pattern and cfg.n_repeats:
        one = {
            f"l{i}": block_cache_shapes(cfg, s, batch, seq_len)
            for i, s in enumerate(cfg.pattern)
        }

        def add_stack(leaf):
            shape, dt = leaf
            return ((cfg.n_repeats,) + shape, dt)

        cache["pattern"] = jax.tree.map(
            add_stack, one, is_leaf=lambda l: isinstance(l, tuple) and len(l) == 2 and isinstance(l[0], tuple)
        )
    if cfg.suffix:
        cache["suffix"] = [
            block_cache_shapes(cfg, s, batch, seq_len) for s in cfg.suffix
        ]
    return cache


# ---------------------------------------------------------------------------
# Whisper-style bidirectional encoder
# ---------------------------------------------------------------------------
def encoder_init(key, cfg):
    from repro.configs.base import LayerSpec

    spec = LayerSpec("attn_full", "dense")
    def one(k):
        return block_init(k, cfg, spec)

    p = {
        "encoder_layers": jax.vmap(one)(jax.random.split(key, cfg.n_encoder_layers)),
        "encoder_norm": norm_init(cfg),
        "enc_pos": jnp.zeros((cfg.encoder_len, cfg.d_model), jnp.float32),
    }
    return p


def encoder_apply(p, cfg, frames):
    """frames: (B, M, d_model) post-projector. Bidirectional self-attention."""
    from repro.configs.base import LayerSpec

    spec = LayerSpec("attn_full", "dense")
    x = frames + p["enc_pos"].astype(frames.dtype)

    def body(carry, params_t):
        xx = carry
        h = apply_norm(params_t["norm1"], cfg, xx)
        y, _ = _encoder_self_attn(params_t["mixer"], cfg, h)
        xx = xx + y
        xx = xx + apply_mlp(
            params_t["mlp"], cfg, apply_norm(params_t["norm2"], cfg, xx)
        )
        return xx, None

    x, _ = jax.lax.scan(body, x, p["encoder_layers"])
    return apply_norm(p["encoder_norm"], cfg, x)


def _encoder_self_attn(p, cfg, x):
    B, S, _ = x.shape
    H, Kv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = attn._project_q(p, cfg, x)
    k, v = attn._project_kv(p, cfg, x)
    msk = jnp.ones((1, 1, 1, S, S), bool)
    y = attn._dense_attention(q, k, v, msk).reshape(B, S, H * D)
    y = jnp.einsum("bse,ed->bsd", y, p["wo"])
    return y, None
