"""Real-model BTARD workloads: zoo LM training steps behind the trainer API.

``lm_setup(arch)`` packages a model from the config registry as the
``(loss_fn, params0, batch_fn)`` triple ``BTARDTrainer`` consumes — the same
shape as the toy ``classification_setup``, so every engine path (host loop,
jitted scan, every registered aggregator, every attack) runs unchanged on
real transformer/MoE/SSM/RG-LRU gradients. Per-peer batches come from the
public-seed ``TokenPipeline`` (``device_batch`` is jit/scan-traceable in
(step, peer), so the scanned engine generates data on device), and the
gradient pytree crosses into the engine's ``(n, d)`` f32 world at the
``core.flatten`` ravel boundary inside the trainer.

Mixed precision: ``dtype="bfloat16"`` stores params/activations in bf16
(``reduce_config`` defaults to f32 for smoke sizes; pass ``dtype`` to
override). The trainer's flat master params stay f32 either way — the bf16
pytree is the derived cast at the boundary — and the PR 6 wire codecs
(``compressed:*:codec=bf16``) quantize the f32 rows for transport with f32
digests over dequantized wire values, so zero-honest-accusations remains
structural, not a tolerance.
"""
from __future__ import annotations

import dataclasses

from repro.data import TokenPipeline
from repro.models import get_model


def _normalize_arch(arch: str) -> str:
    """Accept CLI spellings like ``albert_large`` for registry key
    ``albert-large`` (ids use hyphens; shells prefer underscores)."""
    from repro.configs import _ARCH_MODULES

    if arch in _ARCH_MODULES:
        return arch
    alt = arch.replace("_", "-")
    if alt in _ARCH_MODULES:
        return alt
    raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")


def lm_model(arch: str, *, reduced: bool = True, dtype: str | None = None):
    """Resolve a zoo model, optionally overriding the storage dtype."""
    from repro.configs import get_config, reduce_config
    from repro.models.model import Model

    cfg = get_config(_normalize_arch(arch))
    if reduced:
        cfg = reduce_config(cfg)
    if dtype is not None and cfg.dtype != dtype:
        cfg = dataclasses.replace(cfg, dtype=dtype)
    return Model(cfg)


def lm_setup(arch: str, *, seq_len: int = 32, batch_size: int = 2,
             reduced: bool = True, dtype: str | None = None,
             global_seed: int = 0, init_seed: int = 0):
    """(loss_fn, params0, batch_fn, model) for a zoo LM under BTARD.

    * loss_fn(params, batch) -> scalar (router aux folded in for MoE).
    * params0: the model's init pytree (bf16 leaves when dtype says so).
    * batch_fn(peer, step, flipped): public-seed tokens for xi_peer^step,
      traceable in (peer, step) — runs inside the scanned engine's device
      data phase. ``flipped`` (the paper's label-flip attack, static bool)
      reverses the token stream: a deterministic target corruption any
      validator reproduces from the public seed, the LM analogue of
      l -> K-1-l.
    """
    import jax

    model = lm_model(arch, reduced=reduced, dtype=dtype)
    pipe = TokenPipeline(
        model.cfg.vocab_size, seq_len, batch_size, global_seed=global_seed
    )

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)[0]

    def batch_fn(peer, step, flipped):
        batch = pipe.device_batch(step, peer)
        if flipped:
            batch = dict(batch, tokens=batch["tokens"][:, ::-1])
        return batch

    params0 = model.init_params(jax.random.key(init_seed))
    return loss_fn, params0, batch_fn, model
