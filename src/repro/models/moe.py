"""Mixture-of-Experts MLP with capacity-based scatter dispatch.

Expert compute is FLOP-honest (proportional to active parameters): tokens are
scattered into an (E, capacity, d) buffer per expert, processed with a single
(E, d, ff) batched matmul (experts sharded over 'model' => expert
parallelism), and combined back with the router probabilities. Overflowing
tokens are dropped (standard capacity-factor semantics); a switch-style
load-balance auxiliary loss is returned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, cdtype, dense_init, mlp_init, apply_mlp
from repro.sharding import shard

def moe_init(key, cfg, spec=None):
    dt = cdtype(cfg)
    ks = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "experts_wi": jax.vmap(lambda k: dense_init(k, d, f, dt))(
            jax.random.split(ks[1], E)
        ),
        "experts_wdown": jax.vmap(lambda k: dense_init(k, f, d, dt))(
            jax.random.split(ks[3], E)
        ),
    }
    if cfg.glu:
        p["experts_wg"] = jax.vmap(lambda k: dense_init(k, d, f, dt))(
            jax.random.split(ks[2], E)
        )
    if cfg.n_shared_experts:
        import dataclasses

        shared_cfg = dataclasses.replace(cfg, d_ff=cfg.n_shared_experts * f)
        p["shared"] = mlp_init(ks[4], shared_cfg, cfg.n_shared_experts * f)
    return p


def capacity(cfg, n_tokens):
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_apply(p, cfg, x):
    """x: (B, S, d) -> (y, aux_loss).

    Dispatch is GROUPED BY BATCH ROW (vmap over B): the token-order cumsum
    and the scatter into the (E, C, d) buffer stay local to each row, so the
    batch dim shards over ('pod','data') under plain GSPMD and the
    (b,e,c,d)x(e,d,f) expert einsum shards E over 'model' (expert
    parallelism). A token-major global dispatch defeats GSPMD: the expert
    matmul then runs on the GLOBAL token set on every device — measured 9x
    FLOP inflation on dbrx (EXPERIMENTS.md §Perf H1). Capacity is per row:
    C = capacity_factor * top_k * S / E.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)

    def route_group(xg):
        """xg: (S, d) -> dispatch buffer + combine metadata for one row."""
        logits = jnp.einsum("td,de->te", xg.astype(jnp.float32), p["router"])
        probs = jax.nn.softmax(logits, axis=-1)  # (S, E)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        # load-balance aux (Switch): E * sum_e f_e * p_e
        me = probs.mean(0)
        ce = jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32).mean(0)
        aux = E * jnp.sum(me * ce)

        buf = jnp.zeros((E, C, d), x.dtype)
        base = jnp.zeros((E,), jnp.int32)
        slots, keeps = [], []
        for k in range(K):
            oh = jax.nn.one_hot(top_e[:, k], E, dtype=jnp.int32)  # (S, E)
            pos_in_e = jnp.cumsum(oh, axis=0) - 1 + base[None, :]
            slot = jnp.take_along_axis(pos_in_e, top_e[:, k : k + 1], axis=1)[:, 0]
            base = base + oh.sum(0)
            keep = slot < C
            slot = jnp.where(keep, slot, C - 1)
            buf = buf.at[top_e[:, k], slot].add(
                jnp.where(keep[:, None], xg, 0).astype(buf.dtype)
            )
            slots.append(slot)
            keeps.append(keep)
        return buf, jnp.stack(slots), jnp.stack(keeps), top_e, top_p, aux

    buf, slots, keeps, top_e, top_p, aux = jax.vmap(route_group)(x)
    buf = shard(buf, "batch", "model", None, None)  # (B, E, C, d)

    h = jnp.einsum("becd,edf->becf", buf, p["experts_wi"])
    if "experts_wg" in p:
        g = jnp.einsum("becd,edf->becf", buf, p["experts_wg"])
        h = act_fn(cfg, g) * h
    else:
        h = act_fn(cfg, h)
    expert_out = jnp.einsum("becf,efd->becd", h, p["experts_wdown"])
    expert_out = shard(expert_out, "batch", "model", None, None)

    def combine_group(eo, slots_g, keeps_g, top_e_g, top_p_g):
        out = jnp.zeros((S, d), jnp.float32)
        for k in range(K):
            gathered = eo[top_e_g[:, k], slots_g[k]]  # (S, d)
            w = (top_p_g[:, k] * keeps_g[k]).astype(jnp.float32)
            out = out + w[:, None] * gathered.astype(jnp.float32)
        return out

    y = jax.vmap(combine_group)(expert_out, slots, keeps, top_e, top_p)
    y = y.astype(x.dtype)
    if "shared" in p:
        y = y + apply_mlp(p["shared"], cfg, x)
    return y, aux.mean()
