"""Shared building blocks: norms, MLPs, RoPE, embeddings, causal conv."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import shard


def cdtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _init(key, shape, scale, dtype):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=1.0):
    return _init(key, (d_in, d_out), scale, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_init(cfg, dim=None):
    dim = dim or cfg.d_model
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(p, cfg, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm" and "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        var = (xf**2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps=1e-6):
    """QK-norm over the head dim. x: (..., head_dim)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf**2).mean(-1, keepdims=True) + eps)
    return (y * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (optionally gated)
# ---------------------------------------------------------------------------
def mlp_init(key, cfg, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    dt = cdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": dense_init(k1, cfg.d_model, d_ff, dt),
        "wdown": dense_init(k3, d_ff, cfg.d_model, dt),
    }
    if cfg.glu:
        p["wg"] = dense_init(k2, cfg.d_model, d_ff, dt)
    return p


def act_fn(cfg, x):
    if cfg.act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def apply_mlp(p, cfg, x):
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if "wg" in p:
        h = act_fn(cfg, jnp.einsum("...d,df->...f", x, p["wg"])) * h
    else:
        h = act_fn(cfg, h)
    h = shard(h, "batch", None, "model")
    return jnp.einsum("...f,fd->...d", h, p["wdown"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(cfg, dim):
    half = dim // 2
    return 1.0 / (cfg.rope_theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, pos, cfg, dim=None):
    """x: (..., seq, heads, head_dim) or (..., heads, head_dim) with pos (...,seq)/scalar.

    cfg.rope == 'standard': rotate the full head dim (NeoX halves layout).
    cfg.rope == 'half':     GLM 2d-rope — rotate only the first half of the
                            head dim, pass through the second half.
    cfg.rope == 'none':     identity.
    """
    if cfg.rope == "none":
        return x
    hd = dim or x.shape[-1]
    rot = hd if cfg.rope == "standard" else hd // 2
    freqs = jnp.asarray(rope_freqs(cfg, rot))  # (rot/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., seq, rot/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, rot/2)
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., : rot // 2], x_rot[..., rot // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------
def embed_init(key, cfg):
    dt = cdtype(cfg)
    p = {"embed": _init(key, (cfg.vocab_size, cfg.d_model), 1.0, dt)}
    if cfg.learned_pos:
        p["pos_embed"] = _init(
            jax.random.fold_in(key, 1), (cfg.max_position, cfg.d_model), 1.0, dt
        )
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(
            jax.random.fold_in(key, 2), cfg.d_model, cfg.vocab_size, dt
        )
    return p


def embed_tokens(p, cfg, tokens, pos=None):
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.name.startswith("gemma") or cfg.name.startswith("recurrentgemma"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.learned_pos and pos is not None:
        x = x + jnp.take(p["pos_embed"], pos, axis=0)
    return x


def logits_out(p, cfg, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["embed"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["lm_head"])
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return shard(logits, "batch", None, "model")


# ---------------------------------------------------------------------------
# Causal depthwise conv (SSM / RG-LRU front conv)
# ---------------------------------------------------------------------------
def conv1d_init(key, channels, width, dtype):
    return {
        "conv_w": _init(key, (width, channels), 1.0, dtype),
        "conv_b": jnp.zeros((channels,), dtype),
    }


def causal_conv1d(p, x):
    """x: (B, S, C). Depthwise causal conv, kernel width K."""
    w = p["conv_w"]  # (K, C)
    k = w.shape[0]
    pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros(x.shape, x.dtype)
    for i in range(k):  # unrolled: K is 4
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + p["conv_b"]


def causal_conv1d_step(p, buf, x_t):
    """Single decode step. buf: (B, K-1, C) past inputs; x_t: (B, C)."""
    w = p["conv_w"]
    k = w.shape[0]
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"]
    new_buf = window[:, 1:, :] if k > 1 else buf
    return new_buf, out
