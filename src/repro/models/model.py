"""Public model API: init / loss / prefill / decode_step.

Batch dicts (produced by data pipeline or launch.input_specs):
  train:   {"tokens": (B, S+1) i32, ["memory_raw": (B, M, enc_dim)]}
  prefill: {"tokens": (B, S) i32,  ["memory_raw"]}
  decode:  {"token": (B,) i32, "pos": (B,) i32} + cache
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.layers import (
    apply_norm,
    cdtype,
    dense_init,
    embed_init,
    embed_tokens,
    logits_out,
    norm_init,
)
from repro.sharding import shard

LOSS_CHUNK = 2048


class Model:
    def __init__(self, cfg):
        cfg.validate()
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init_params(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p = embed_init(ks[0], cfg)
        p.update(tfm.stack_init(ks[1], cfg))
        p["final_norm"] = norm_init(cfg)
        if cfg.has_encoder or cfg.family == "vlm":
            if cfg.encoder_dim and cfg.encoder_dim != cfg.d_model:
                p["projector"] = dense_init(
                    ks[2], cfg.encoder_dim, cfg.d_model, cdtype(cfg)
                )
            if cfg.has_encoder:
                p.update(tfm.encoder_init(ks[3], cfg))
        return p

    def abstract_params(self):
        return jax.eval_shape(self.init_params, jax.random.key(0))

    def param_count(self):
        tree = self.abstract_params()
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))

    def active_param_count(self):
        """Parameters touched per token (MoE: routed experts count top_k/E)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.n_experts:
            return total
        tree = self.abstract_params()
        expert = sum(
            int(np.prod(l.shape))
            for path, l in jax.tree_util.tree_flatten_with_path(tree)[0]
            if any("experts_" in str(k) for k in path)
        )
        return total - expert + expert * cfg.top_k / cfg.n_experts

    # -------------------------------------------------------------- memory
    def _memory(self, params, batch):
        cfg = self.cfg
        if "memory_raw" not in batch:
            return None
        mem = batch["memory_raw"].astype(cdtype(cfg))
        if "projector" in params:
            mem = jnp.einsum("bme,ed->bmd", mem, params["projector"])
        if cfg.has_encoder:
            mem = tfm.encoder_apply(params, cfg, mem)
        return shard(mem, "batch", None, None)

    # ---------------------------------------------------------------- train
    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        B, S = inputs.shape
        pos = jnp.arange(S)
        memory = self._memory(params, batch)
        x = embed_tokens(params, cfg, inputs, pos=pos if cfg.learned_pos else None)
        x = shard(x, "batch", None, None)
        x, _, aux = tfm.stack_apply(
            params, cfg, x, pos=pos, memory=memory, cache=None, mode="train"
        )
        x = apply_norm(params["final_norm"], cfg, x)

        # chunked + rematted cross-entropy: never materializes (B, S, V) f32
        # logits, and the backward recomputes each chunk's logits instead of
        # storing them. Chunk count is the CEILING of S / LOSS_CHUNK with
        # balanced widths, so every chunk (ragged tail included) stays within
        # the LOSS_CHUNK memory bound — floor division let a chunk grow to
        # 2*LOSS_CHUNK-1 tokens (S=4095 materialized the full logits matrix).
        n_chunks = -(-S // LOSS_CHUNK)
        csz = -(-S // n_chunks)

        @jax.checkpoint
        def chunk_loss(emb_params, x_sl, tgt_sl):
            logits = logits_out(emb_params, cfg, x_sl)
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, tgt_sl[..., None], axis=-1)[..., 0]
            return (lse - tgt).sum()

        emb_params = {k: params[k] for k in ("embed", "lm_head") if k in params}
        total = jnp.zeros((), jnp.float32)
        for i in range(n_chunks):
            sl = slice(i * csz, min((i + 1) * csz, S))
            total = total + chunk_loss(emb_params, x[:, sl], targets[:, sl])
        loss = total / (B * S)
        metrics = {"loss": loss, "aux_loss": aux}
        if cfg.n_experts:
            loss = loss + cfg.router_aux_coef * aux
        return loss, metrics

    # -------------------------------------------------------------- prefill
    def prefill(self, params, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos = jnp.arange(S)
        memory = self._memory(params, batch)
        x = embed_tokens(params, cfg, tokens, pos=pos if cfg.learned_pos else None)
        x = shard(x, "batch", None, None)
        x, new_cache, _ = tfm.stack_apply(
            params, cfg, x, pos=pos, memory=memory, cache=cache, mode="prefill"
        )
        x = apply_norm(params["final_norm"], cfg, x[:, -1:])
        logits = logits_out(params, cfg, x)
        return logits[:, 0], new_cache

    # --------------------------------------------------------------- decode
    def decode_step(self, params, batch, cache):
        cfg = self.cfg
        token, pos = batch["token"], batch["pos"]
        x = embed_tokens(
            params, cfg, token[:, None], pos=pos[:, None] if cfg.learned_pos else None
        )
        x, new_cache, _ = tfm.stack_apply(
            params, cfg, x, pos=pos, memory=None, cache=cache, mode="decode"
        )
        x = apply_norm(params["final_norm"], cfg, x)
        logits = logits_out(params, cfg, x)
        return logits[:, 0], new_cache

    # ---------------------------------------------------------------- cache
    def cache_shapes(self, batch_size, seq_len):
        return tfm.stack_cache_shapes(self.cfg, batch_size, seq_len)

    def init_cache(self, batch_size, seq_len):
        shapes = self.cache_shapes(batch_size, seq_len)
        return jax.tree.map(
            lambda l: jnp.zeros(*l),
            shapes,
            is_leaf=_is_shape_leaf,
        )

    def abstract_cache(self, batch_size, seq_len):
        shapes = self.cache_shapes(batch_size, seq_len)
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l[0], l[1]),
            shapes,
            is_leaf=_is_shape_leaf,
        )


def _is_shape_leaf(l):
    return isinstance(l, tuple) and len(l) == 2 and isinstance(l[0], tuple)


@functools.lru_cache(maxsize=None)
def get_model(arch: str, reduced: bool = False) -> Model:
    from repro.configs import get_config, reduce_config

    cfg = get_config(arch)
    if reduced:
        cfg = reduce_config(cfg)
    return Model(cfg)
