"""RG-LRU recurrent mixer (Griffin / RecurrentGemma, arXiv:2402.19427).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * r_t),  r_t/i_t input-dependent sigmoids.

Training/prefill uses jax.lax.associative_scan over time (log-depth,
cost-analysis-visible); decode carries (h, conv buffer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (
    causal_conv1d,
    causal_conv1d_step,
    cdtype,
    conv1d_init,
    dense_init,
)
from repro.sharding import shard

RGLRU_C = 8.0


def rglru_init(key, cfg, spec=None):
    dt = cdtype(cfg)
    w = cfg.rglru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": dense_init(ks[0], cfg.d_model, w, dt),
        "gate_w": dense_init(ks[1], cfg.d_model, w, dt),
        "wa": dense_init(ks[2], w, w, dt),
        "wx": dense_init(ks[3], w, w, dt),
        # init so that a ~ Uniform-ish decay in (0.9, 0.999)
        "lam": jnp.asarray(
            np.log(np.expm1(-np.log(np.linspace(0.9, 0.999, w)) / RGLRU_C)),
            jnp.float32,
        ),
        "out_proj": dense_init(ks[4], w, cfg.d_model, dt),
    }
    p.update(conv1d_init(ks[5], w, cfg.rglru_conv, dt))
    return p


def _gates(p, xc):
    """xc: (..., w) conv output -> (log_a, gated_input) in f32."""
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xc, p["wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xc, p["wx"]).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * xc.astype(jnp.float32))
    return log_a, b


def rglru_apply(p, cfg, spec, x, *, pos=None, memory=None, cache=None, mode="train"):
    B, S, _ = x.shape
    xb = jnp.einsum("bsd,dw->bsw", x, p["in_proj"])
    xb = shard(xb, "batch", None, "model")
    gate = jax.nn.silu(jnp.einsum("bsd,dw->bsw", x, p["gate_w"]).astype(jnp.float32))

    new_cache = {} if cache is not None else None
    if mode == "decode":
        conv_buf, xc = causal_conv1d_step(p, cache["conv"], xb[:, 0])
        new_cache["conv"] = conv_buf
        log_a, b = _gates(p, xc)
        h = cache["h"].astype(jnp.float32) * jnp.exp(log_a) + b  # (B, w)
        new_cache["h"] = h.astype(cache["h"].dtype)
        h = h[:, None]
    else:
        xc = causal_conv1d(p, xb)
        log_a, b = _gates(p, xc)  # (B,S,w)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al + ar, bl * jnp.exp(ar) + br

        log_a_cum, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
        if cache is not None and "h" in cache:
            h = h + cache["h"].astype(jnp.float32)[:, None] * jnp.exp(log_a_cum)
        if new_cache is not None:
            new_cache["h"] = h[:, -1].astype(cdtype(cfg))
            K = cfg.rglru_conv - 1
            tail = xb[:, S - K :] if S >= K else jnp.pad(xb, ((0, 0), (K - S, 0), (0, 0)))
            new_cache["conv"] = tail

    y = (h * gate[:, : h.shape[1]]).astype(x.dtype)
    y = jnp.einsum("bsw,wd->bsd", y, p["out_proj"])
    return y, new_cache


def rglru_cache_shape(cfg, spec, batch, seq_len, has_memory):
    dt = cdtype(cfg)
    w = cfg.rglru_width or cfg.d_model
    return {
        "h": ((batch, w), dt),
        "conv": ((batch, cfg.rglru_conv - 1, w), dt),
    }
