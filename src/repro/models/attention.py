"""Attention flavours: GQA (full / sliding-window / cross), MLA (DeepSeek).

All einsums keep KV heads grouped — (B, S, K, G, D) query layout — so GQA
never materializes repeated KV. Softmax runs in f32.

Long sequences use a *python-unrolled* blocked online-softmax (no lax.scan)
so the dry-run roofline sees the true FLOP/byte counts (cost_analysis counts
a scan body only once — see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, cdtype, dense_init, rms_head_norm
from repro.sharding import shard

NEG_INF = -2.0e38
DENSE_MAX_KV = 8192  # use dense path when kv_len <= this
KV_BLOCK = 1024


# ---------------------------------------------------------------------------
# Core softmax-attention primitives (grouped-query layout)
# ---------------------------------------------------------------------------
def _dense_attention(q, k, v, mask):
    """q: (B,S,K,G,D); k,v: (B,T,K,D); mask: (B,1,1,S,T) or (1,1,1,S,T)."""
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32)
    scores = scores * (1.0 / np.sqrt(q.shape[-1]))
    scores = jnp.where(jnp.moveaxis(mask, -2, -2), scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out


def _blocked_attention(q, k, v, qpos, kpos, window=0):
    """Online-softmax over KV blocks, python-unrolled.

    q: (B,S,K,G,D); k,v: (B,T,K,D); qpos: (S,), kpos: (T,) absolute positions.
    window=0 -> plain causal; window>0 -> also restrict to the sliding window.
    """
    B, S, K, G, D = q.shape
    Dv = v.shape[-1]  # may differ from D (MLA: K=192, V=128)
    T = k.shape[1]
    scale = 1.0 / np.sqrt(D)
    m = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l = jnp.zeros((B, K, G, S), jnp.float32)
    acc = jnp.zeros((B, S, K, G, Dv), jnp.float32)
    n_blocks = (T + KV_BLOCK - 1) // KV_BLOCK
    for j in range(n_blocks):
        lo = j * KV_BLOCK
        hi = min(T, lo + KV_BLOCK)
        kb, vb = k[:, lo:hi], v[:, lo:hi]
        kp = kpos[lo:hi]
        msk = kp[None, :] <= qpos[:, None]
        if window:
            msk &= kp[None, :] > (qpos[:, None] - window)
        s = jnp.einsum("bskgd,btkd->bkgst", q, kb, preferred_element_type=jnp.float32)
        s = s * scale + jnp.where(msk, 0.0, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(-1)
        acc = acc * jnp.moveaxis(corr, 3, 1)[..., None] + jnp.einsum(
            "bkgst,btkd->bskgd", p.astype(v.dtype), vb
        ).astype(jnp.float32)
        m = m_new
    denom = jnp.moveaxis(l, 3, 1)[..., None]
    return (acc / jnp.maximum(denom, 1e-37)).astype(q.dtype)


def _windowed_attention(q, k, v, window):
    """Sliding-window causal self-attention, O(S * window).

    Query blocks unrolled; each block attends a static KV slice
    [qs - window, qs + Bq). q,k,v same seq length S.
    """
    B, S, K, G, D = q.shape
    Bq = min(S, max(128, KV_BLOCK))
    if S <= window:  # window covers everything: plain causal
        qpos = jnp.arange(S)
        return _blocked_attention(q, k, v, qpos, qpos, window=window)
    scale = 1.0 / np.sqrt(D)
    pad = window
    kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    outs = []
    for qs in range(0, S, Bq):
        qb = q[:, qs : qs + Bq]
        span = window + qb.shape[1]
        kb = kp[:, qs : qs + span]  # absolute kv positions [qs-window, qs+Bq)
        vb = vp[:, qs : qs + span]
        qpos = qs + jnp.arange(qb.shape[1])
        kpos = qs - window + jnp.arange(span)
        msk = (kpos[None, :] <= qpos[:, None]) & (
            kpos[None, :] > qpos[:, None] - window
        ) & (kpos[None, :] >= 0)
        s = jnp.einsum("bskgd,btkd->bkgst", qb, kb, preferred_element_type=jnp.float32)
        s = s * scale + jnp.where(msk, 0.0, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        outs.append(jnp.einsum("bkgst,btkd->bskgd", p.astype(vb.dtype), vb))
    return jnp.concatenate(outs, axis=1)


def _decode_attention(q, k_cache, v_cache, pos, window=0):
    """q: (B,1,K,G,D); caches: (B,T,K,D); pos: (B,) current position."""
    B, _, K, G, D = q.shape
    T = k_cache.shape[1]
    scale = 1.0 / np.sqrt(D)
    t_idx = jnp.arange(T)
    msk = t_idx[None, :] <= pos[:, None]
    if window:
        msk &= t_idx[None, :] > (pos[:, None] - window)
    s = jnp.einsum(
        "bkgd,btkd->bkgt", q[:, 0], k_cache, preferred_element_type=jnp.float32
    )
    s = s * scale + jnp.where(msk[:, None, None, :], 0.0, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out[:, None]  # (B,1,K,G,D)


def causal_attention(q, k, v, window=0):
    """Self-attention over full sequences (train / prefill)."""
    S, T = q.shape[1], k.shape[1]
    if window and T > window:
        return _windowed_attention(q, k, v, window)
    if T <= DENSE_MAX_KV:
        pos = jnp.arange(T)
        msk = pos[None, :] <= pos[:, None]
        if window:
            msk &= pos[None, :] > pos[:, None] - window
        return _dense_attention(q, k, v, msk[None, None, None])
    qpos = jnp.arange(S)
    return _blocked_attention(q, k, v, qpos, jnp.arange(T), window=window)


# ---------------------------------------------------------------------------
# GQA block (full / local / cross)
# ---------------------------------------------------------------------------
def gqa_init(key, cfg, spec):
    dt = cdtype(cfg)
    ks = jax.random.split(key, 6)
    H, Kv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cross_only = spec.mixer == "attn_cross"
    p = {
        "wq": dense_init(ks[0], cfg.d_model, H * D, dt),
        "wo": dense_init(ks[3], H * D, cfg.d_model, dt),
    }
    if not cross_only:
        p["wk"] = dense_init(ks[1], cfg.d_model, Kv * D, dt)
        p["wv"] = dense_init(ks[2], cfg.d_model, Kv * D, dt)
    if cfg.qkv_bias:
        p["wq_bias"] = jnp.zeros((H * D,), dt)
        if not cross_only:
            p["wk_bias"] = jnp.zeros((Kv * D,), dt)
            p["wv_bias"] = jnp.zeros((Kv * D,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((D,), jnp.float32)
        p["k_norm"] = jnp.ones((D,), jnp.float32)
    if spec.cross or spec.mixer == "attn_cross":
        # separate KV projection for the encoder memory
        p["mem_wk"] = dense_init(ks[4], cfg.d_model, Kv * D, dt)
        p["mem_wv"] = dense_init(ks[5], cfg.d_model, Kv * D, dt)
        if spec.mixer == "attn_cross":
            p["xgate"] = jnp.zeros((), jnp.float32)  # llama-vision gated x-attn
        else:  # self+cross decoder layer: separate cross projections
            kq = jax.random.fold_in(ks[4], 7)
            kw = jax.random.fold_in(ks[5], 7)
            p["mem_wq"] = dense_init(kq, cfg.d_model, H * D, dt)
            p["mem_wo"] = dense_init(kw, H * D, cfg.d_model, dt)
    return p


def _project_q(p, cfg, x):
    B, S, _ = x.shape
    H, Kv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    if "wq_bias" in p:
        q = q + p["wq_bias"]
    q = q.reshape(B, S, Kv, H // Kv, D)
    if "q_norm" in p:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
    return q


def _project_kv(p, cfg, x, wk="wk", wv="wv"):
    B, S, _ = x.shape
    Kv, D = cfg.n_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,de->bse", x, p[wk])
    v = jnp.einsum("bsd,de->bse", x, p[wv])
    if wk == "wk" and "wk_bias" in p:
        k = k + p["wk_bias"]
        v = v + p["wv_bias"]
    k = k.reshape(B, S, Kv, D)
    v = v.reshape(B, S, Kv, D)
    if "k_norm" in p:
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    return k, v


def gqa_apply(p, cfg, spec, x, *, pos, memory=None, cache=None, mode="train"):
    """Causal self-attention part of a GQA block.

    Returns (y, new_cache). x: (B,S,d). pos: (S,) train / (B,) decode.
    Cross-attention (``spec.cross`` or mixer=='attn_cross') is handled
    separately by ``cross_attn_apply`` (own norm/residual at block level).
    """
    B, S, _ = x.shape
    H, Kv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.window if spec.mixer == "attn_local" else 0
    new_cache = {} if cache is not None else None

    q = _project_q(p, cfg, x)
    if mode == "decode":
        q = apply_rope(q.reshape(B, S, H, D), pos[:, None], cfg).reshape(
            B, S, Kv, H // Kv, D
        )
        k_new, v_new = _project_kv(p, cfg, x)
        k_new = apply_rope(k_new, pos[:, None], cfg)
        kc = _cache_insert(cache["k"], k_new, pos)
        vc = _cache_insert(cache["v"], v_new, pos)
        new_cache["k"], new_cache["v"] = kc, vc
        attn = _decode_attention(q, kc, vc, pos, window=window)
    else:
        q = apply_rope(q.reshape(B, S, H, D), pos[None, :], cfg)
        k, v = _project_kv(p, cfg, x)
        k = apply_rope(k, pos[None, :], cfg)
        if new_cache is not None:  # prefill: persist KV (grouped layout)
            new_cache["k"] = _cache_prefill(cache["k"], k)
            new_cache["v"] = _cache_prefill(cache["v"], v)
        # expand KV to full heads: keeps the head dim shardable over 'model'
        # even when n_kv < TP degree (bandwidth-for-shardability trade; the
        # cache itself stays grouped)
        if Kv < H:
            k = jnp.repeat(k, H // Kv, axis=2)
            v = jnp.repeat(v, H // Kv, axis=2)
        q = shard(q.reshape(B, S, H, 1, D), "batch", None, "model", None, None)
        k = shard(k, "batch", None, "model", None)
        v = shard(v, "batch", None, "model", None)
        attn = causal_attention(q, k, v, window=window)

    y = shard(attn.reshape(B, S, H * D), "batch", None, "model")
    y = jnp.einsum("bse,ed->bsd", y, p["wo"])
    return y, new_cache


def cross_attn_apply(p, cfg, spec, x, *, memory=None, cache=None, mode="train"):
    """Cross-attention over encoder memory. Returns (y, new_cache_entries)."""
    B, S, _ = x.shape
    H, Kv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cross_only = spec.mixer == "attn_cross"
    wq, wo = ("wq", "wo") if cross_only else ("mem_wq", "mem_wo")
    new_entries = {} if cache is not None else None

    q = jnp.einsum("bsd,de->bse", x, p[wq])
    if cross_only and "wq_bias" in p:
        q = q + p["wq_bias"]
    q = q.reshape(B, S, Kv, H // Kv, D)
    if cache is not None and mode == "decode":
        mk, mv = cache["mem_k"], cache["mem_v"]
        new_entries["mem_k"], new_entries["mem_v"] = mk, mv
    else:
        mk, mv = _project_kv(p, cfg, memory, wk="mem_wk", wv="mem_wv")
        if new_entries is not None:
            new_entries["mem_k"], new_entries["mem_v"] = mk, mv
    M = mk.shape[1]
    if mode != "decode" and Kv < H:  # head-shardable expand (see gqa_apply)
        mk = jnp.repeat(mk, H // Kv, axis=2)
        mv = jnp.repeat(mv, H // Kv, axis=2)
        q = shard(q.reshape(B, S, H, 1, D), "batch", None, "model", None, None)
        mk = shard(mk, "batch", None, "model", None)
        mv = shard(mv, "batch", None, "model", None)
    msk = jnp.ones((1, 1, 1, S, M), bool)
    xa = _dense_attention(q, mk, mv, msk).reshape(B, S, H * D)
    if "xgate" in p:
        xa = xa * jnp.tanh(p["xgate"]).astype(xa.dtype)
    xa = shard(xa, "batch", None, "model")
    y = jnp.einsum("bse,ed->bsd", xa, p[wo])
    return y, new_entries


def _cache_insert(cache, new, pos):
    """cache: (B,T,...), new: (B,1,...), pos: (B,)."""

    def ins(c, n, p):
        idx = (p,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), idx)

    return jax.vmap(ins)(cache, new, pos)


def _cache_prefill(cache, full):
    """Write the first S positions of the cache."""
    S = full.shape[1]
    if cache.shape[1] == S:
        return full.astype(cache.dtype)
    return jax.lax.dynamic_update_slice(
        cache, full.astype(cache.dtype), (0,) * cache.ndim
    )


def gqa_cache_shape(cfg, spec, batch, seq_len, has_memory):
    dt = cdtype(cfg)
    shapes = {}
    if spec.mixer != "attn_cross":
        shapes["k"] = ((batch, seq_len, cfg.n_kv_heads, cfg.head_dim), dt)
        shapes["v"] = ((batch, seq_len, cfg.n_kv_heads, cfg.head_dim), dt)
    if spec.cross or spec.mixer == "attn_cross":
        mem_len = cfg.encoder_len
        shapes["mem_k"] = ((batch, mem_len, cfg.n_kv_heads, cfg.head_dim), dt)
        shapes["mem_v"] = ((batch, mem_len, cfg.n_kv_heads, cfg.head_dim), dt)
    return shapes


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------
def mla_init(key, cfg, spec):
    dt = cdtype(cfg)
    ks = jax.random.split(key, 4)
    H = cfg.n_heads
    qd = cfg.nope_head_dim + cfg.rope_head_dim
    p = {
        "wq": dense_init(ks[0], cfg.d_model, H * qd, dt),
        "kv_a": dense_init(ks[1], cfg.d_model, cfg.kv_lora_rank + cfg.rope_head_dim, dt),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
        "kv_b": dense_init(
            ks[2], cfg.kv_lora_rank, H * (cfg.nope_head_dim + cfg.v_head_dim), dt
        ),
        "wo": dense_init(ks[3], H * cfg.v_head_dim, cfg.d_model, dt),
    }
    return p


def _mla_compress(p, cfg, x, pos, decode):
    """Returns (c_kv normed, k_rope roped)."""
    B, S, _ = x.shape
    a = jnp.einsum("bsd,de->bse", x, p["kv_a"])
    c_kv, k_rope = a[..., : cfg.kv_lora_rank], a[..., cfg.kv_lora_rank :]
    c_kv = rms_head_norm(p["kv_norm"], c_kv, cfg.norm_eps)
    pos_b = pos[:, None] if decode else pos[None, :]
    k_rope = apply_rope(k_rope[:, :, None, :], pos_b, cfg)[:, :, 0, :]
    return c_kv, k_rope


def mla_apply(p, cfg, spec, x, *, pos, memory=None, cache=None, mode="train"):
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    scale = 1.0 / np.sqrt(nd + rd)

    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    pos_b = pos[:, None] if mode == "decode" else pos[None, :]
    q_rope = apply_rope(q_rope, pos_b, cfg)

    kv_b = p["kv_b"].reshape(rank, H, nd + vd)
    w_k, w_v = kv_b[..., :nd], kv_b[..., nd:]

    c_new, kr_new = _mla_compress(p, cfg, x, pos, mode == "decode")
    new_cache = None
    if mode == "decode":
        c_kv = _cache_insert(cache["c_kv"], c_new, pos)
        k_rope = _cache_insert(cache["k_rope"], kr_new, pos)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        # absorbed decode: attend in the latent space (the MLA cache win)
        q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_k)
        s = jnp.einsum("bhr,btr->bht", q_lat, c_kv, preferred_element_type=jnp.float32)
        s = s + jnp.einsum(
            "bhp,btp->bht", q_rope[:, 0], k_rope, preferred_element_type=jnp.float32
        )
        T = c_kv.shape[1]
        msk = jnp.arange(T)[None, :] <= pos[:, None]
        s = s * scale + jnp.where(msk[:, None, :], 0.0, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bht,btr->bhr", pr.astype(c_kv.dtype), c_kv)
        o = jnp.einsum("bhr,rhv->bhv", o_lat, w_v)[:, None]  # (B,1,H,vd)
    else:
        if cache is not None:  # prefill persists the compressed cache
            new_cache = {
                "c_kv": _cache_prefill(cache["c_kv"], c_new),
                "k_rope": _cache_prefill(cache["k_rope"], kr_new),
            }
        # expand and run standard attention (kv heads == H)
        k_nope = jnp.einsum("btr,rhn->bthn", c_new, w_k)
        v = jnp.einsum("btr,rhv->bthv", c_new, w_v)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_new[:, :, None, :], (B, S, H, rd))], -1
        )
        qq = jnp.concatenate([q_nope, q_rope], -1).reshape(B, S, H, 1, nd + rd)
        o = causal_attention(qq, k, v, window=0).reshape(B, S, H, vd)

    y = shard(o.reshape(B, S, H * vd), "batch", None, "model")
    y = jnp.einsum("bse,ed->bsd", y, p["wo"])
    return y, new_cache


def mla_cache_shape(cfg, spec, batch, seq_len, has_memory):
    dt = cdtype(cfg)
    return {
        "c_kv": ((batch, seq_len, cfg.kv_lora_rank), dt),
        "k_rope": ((batch, seq_len, cfg.rope_head_dim), dt),
    }
