"""Sharding rules: FSDP('data') x TP('model') x pod, with activation helpers.

The model code calls ``shard(x, 'batch', None, 'model')`` with *logical* axis
names; when no mesh is registered (unit tests on one device) this is a no-op,
so the same model runs single-device and distributed.

Logical axis vocabulary:
  'batch'  -> all batch-parallel mesh axes present: ('pod', 'data')
  'fsdp'   -> 'data' (parameter sharding axis)
  'model'  -> 'model' (tensor/expert parallel axis)
  'seq'    -> 'data' (sequence sharding for long-context decode KV caches)
  None     -> replicated
"""
from __future__ import annotations

import re
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def set_mesh(mesh) -> None:
    _state.mesh = mesh


def get_mesh():
    return getattr(_state, "mesh", None)


def set_manual_axes(axes) -> None:
    """Axes currently under a manual shard_map region: shard() must not
    constrain over them (trace-time thread-local)."""
    _state.manual = tuple(axes)


def get_manual_axes():
    return getattr(_state, "manual", ())


def set_seq_parallel(on: bool) -> None:
    """Megatron-style sequence parallelism: residual-stream activations are
    sharded over 'model' along the sequence dim between blocks (see
    EXPERIMENTS.md §Perf)."""
    _state.seqp = bool(on)


def get_seq_parallel() -> bool:
    return getattr(_state, "seqp", False)


def _mesh_axes():
    mesh = get_mesh()
    return tuple(mesh.axis_names) if mesh is not None else ()


def batch_axes():
    """Mesh axes over which the global batch is sharded. 'peers' is the
    collapsed pod x data axis the BTARD step builds for its manual regions
    (launch/steps._collapse_peer_mesh)."""
    axes = _mesh_axes()
    if "peers" in axes:
        return ("peers",)
    return tuple(a for a in ("pod", "data") if a in axes)


def peer_axes():
    """Mesh axes forming the BTARD peer dimension (see DESIGN.md §2)."""
    return batch_axes()


def _resolve(logical):
    axes = _mesh_axes()
    manual = get_manual_axes()
    if logical is None:
        return None
    if logical == "batch":
        got = tuple(a for a in batch_axes() if a not in manual)
        if not got:
            return None
        # single axis as a scalar name, not a 1-tuple: P('data') and
        # P(('data',)) partition identically, but spec CONSUMERS (cache
        # sharding checks, ZeRO-1 insertion) match on the scalar form
        return got[0] if len(got) == 1 else got
    if logical == "fsdp" or logical == "seq":
        return "data" if "data" in axes and "data" not in manual else None
    if logical == "seqp":  # sequence-parallel residual stream (opt-in)
        on = get_seq_parallel()
        return "model" if on and "model" in axes and "model" not in manual else None
    if logical == "model":
        return "model" if "model" in axes and "model" not in manual else None
    # a raw mesh axis name
    return logical if logical in axes and logical not in manual else None


def activation_spec(*logical) -> P:
    return P(*[_resolve(l) for l in logical])


def shard(x, *logical):
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = activation_spec(*logical)
    # drop axes whose product does not divide the dim (e.g. seq=1 decode)
    entries = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if entry is None:
            entries.append(None)
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        entries.append(entry if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries))
    )


# ===========================================================================
# Parameter sharding rules
# ===========================================================================
# Keyed on the *leaf name* produced by the model initializers. Rank refers to
# the un-stacked (per-layer) rank; stacked pattern params get a leading None.
# fsdp shards the contraction-side dim; model shards heads/ff/experts/vocab.
_RULES = [
    # name regex, spec for the trailing dims
    (r"embed$", ("model", "fsdp")),  # (vocab, d)
    (r"lm_head$", ("fsdp", "model")),  # (d, vocab)
    (r"pos_embed$", (None, "fsdp")),
    (r"projector$", ("fsdp", None)),
    (r"(wq|wk|wv)$", ("fsdp", "model")),
    (r"(wq|wk|wv)_bias$", ("model",)),
    (r"wo$", ("model", "fsdp")),
    (r"(wi|wg)$", ("fsdp", "model")),
    (r"wdown$", ("model", "fsdp")),
    (r"router$", ("fsdp", None)),
    (r"experts_(wi|wg)$", ("model", "fsdp", None)),  # (E, d, ff)
    (r"experts_wdown$", ("model", None, "fsdp")),  # (E, ff, d)
    # MLA
    (r"kv_a$", ("fsdp", None)),
    (r"kv_b$", (None, "model")),
    (r"q_a$", ("fsdp", None)),
    (r"q_b$", (None, "model")),
    # SSM / RG-LRU
    (r"in_proj$", ("fsdp", "model")),
    (r"out_proj$", ("model", "fsdp")),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"(A_log|D|dt_bias)$", ("model",)),
    (r"(wa|wx)$", ("fsdp", "model")),
    (r"lam$", ("model",)),
    (r"(gate_w)$", ("fsdp", "model")),
    # norms and other vectors: replicated
    (r".*", None),
]


def _spec_for_leaf(path: str, ndim: int, stacked: bool) -> P:
    for pat, spec in _RULES:
        if re.search(pat, path):
            if spec is None:
                return P()
            resolved = [_resolve(s) for s in spec]
            if stacked:
                resolved = [None] + resolved
            # pad/trim to ndim
            while len(resolved) < ndim:
                resolved.insert(0, None)
            resolved = resolved[-ndim:] if len(resolved) > ndim else resolved
            return P(*resolved)
    return P()


def param_specs(params, stacked_prefixes=("pattern", "encoder_layers")):
    """PartitionSpec pytree matching ``params``.

    Leaves under a stacked group (scanned macro-blocks) carry a leading
    layer-stack dim which is kept unsharded (sliced by the scan).
    """

    def walk(tree, path, stacked):
        if isinstance(tree, dict):
            return {
                k: walk(
                    v,
                    f"{path}/{k}",
                    stacked or k in stacked_prefixes,
                )
                for k, v in tree.items()
            }
        if isinstance(tree, (list, tuple)):
            out = [walk(v, f"{path}/{i}", stacked) for i, v in enumerate(tree)]
            return type(tree)(out)
        return _spec_for_leaf(path, tree.ndim, stacked)

    return walk(params, "", False)
