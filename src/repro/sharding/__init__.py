from repro.sharding.specs import (  # noqa: F401
    activation_spec,
    batch_axes,
    param_specs,
    set_mesh,
    get_mesh,
    shard,
    peer_axes,
)
