"""Optimizers, schedules, data pipeline, checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import TokenPipeline, classification_batch, peer_seed
from repro.optim import (
    adam,
    clip_by_global_norm,
    cosine_schedule,
    lamb,
    sgd,
    warmup_cosine_schedule,
)
from repro.optim.optimizers import apply_updates, global_norm


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "opt", [sgd(0.1), sgd(0.1, momentum=0.9, nesterov=True), adam(0.05), lamb(0.1)]
)
def test_optimizers_minimize_quadratic(opt):
    params = {"w": jnp.ones((8,)) * 3.0, "b": jnp.ones(())}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    state = opt.init(params)
    for step in range(150):
        g = jax.grad(loss)(params)
        ups, state = opt.update(g, state, params, step)
        params = apply_updates(params, ups)
    assert float(loss(params)) < 0.05


def test_cosine_schedule_endpoints():
    s = cosine_schedule(1.0, 100)
    assert abs(float(s(0)) - 1.0) < 1e-6
    assert float(s(100)) < 1e-6
    w = warmup_cosine_schedule(1.0, 10, 110)
    assert float(w(0)) == 0.0
    assert abs(float(w(10)) - 1.0) < 1e-6


def test_global_norm_clip():
    tree = {"a": jnp.ones((4,)) * 10.0}
    clipped, g = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(g) - 20.0) < 1e-4


# ---------------------------------------------------------------------------
def test_pipeline_determinism_public_seeds():
    """xi_i^t: any peer can recompute any other's batch — the paper's
    public-data assumption."""
    p = TokenPipeline(128, 16, 4)
    b1 = p.batch(step=3, peer=2)
    b2 = p.batch(step=3, peer=2)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = p.batch(step=3, peer=1)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert peer_seed(0, 3, 2) != peer_seed(0, 2, 3)


def test_pipeline_learnable_structure():
    """80% of transitions follow x -> (a x + c) % V."""
    p = TokenPipeline(97, 256, 2, a=5, c=7, noise=0.2)
    toks = np.asarray(p.batch(0)["tokens"])
    match = (toks[:, 1:] == (5 * toks[:, :-1] + 7) % 97).mean()
    assert 0.7 < match < 0.95, match


def test_classification_batch_flip():
    b = classification_batch(0, 32, 8, 10)
    bf = classification_batch(0, 32, 8, 10, flip_labels=True)
    np.testing.assert_array_equal(np.asarray(b["x"]), np.asarray(bf["x"]))
    np.testing.assert_array_equal(np.asarray(9 - b["y"]), np.asarray(bf["y"]))


# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3)},
        "opt": [jnp.ones((4,), jnp.float32)],
    }
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, tree, step=7, meta={"arch": "x"})
    restored, step, meta = load_checkpoint(path, tree)
    assert step == 7 and meta["arch"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype
