"""End-to-end behaviour: LM training on the public-seed pipeline learns the
synthetic structure; the full BTARD loop trains a real (reduced) transformer
with Byzantine peers present."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
from repro.data import TokenPipeline
from repro.models import get_model
from repro.models.model import Model
from repro.optim import adam


def test_lm_training_beats_uniform():
    """A tiny model on the affine-bigram stream must drop well below uniform
    cross-entropy (proves the data pipeline is learnable + model trains)."""
    cfg = dataclasses.replace(get_model("qwen3-1.7b", reduced=True).cfg, vocab_size=64)
    m = Model(cfg)
    pipe = TokenPipeline(64, 32, 16, noise=0.1)
    params = m.init_params(jax.random.key(0))
    opt = adam(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch, i):
        (loss, _), g = jax.value_and_grad(m.loss_fn, has_aux=True)(params, batch)
        ups, state = opt.update(g, state, params, i)
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, ups
        )
        return params, state, loss

    losses = []
    for i in range(60):
        params, state, loss = step(params, state, pipe.batch(i), i)
        losses.append(float(loss))
    uniform = np.log(64)
    assert losses[-1] < uniform - 0.8, (losses[0], losses[-1], uniform)


def test_full_btard_on_reduced_transformer():
    """16 simulated peers, 5 Byzantine, sign-flip mid-run: the protocol bans
    them and the LM keeps training (the paper's §4 scenario end-to-end)."""
    cfg = dataclasses.replace(get_model("qwen3-1.7b", reduced=True).cfg, vocab_size=32)
    m = Model(cfg)
    pipe = TokenPipeline(32, 16, 4, noise=0.1)

    def batch_fn(peer, step, flipped):
        return pipe.batch(step, peer)

    def loss_fn(params, batch):
        return m.loss_fn(params, batch)[0]

    params0 = m.init_params(jax.random.key(0))
    tcfg = TrainerConfig(
        n_peers=16,
        byzantine=(11, 12, 13, 14, 15),
        attack=AttackConfig(kind="sign_flip", start_step=4),
        defense="btard",
        tau=2.0,
        m_validators=2,
        clip_iters=40,
        seed=0,
    )
    tr = BTARDTrainer(loss_fn, params0, batch_fn, tcfg, optimizer=adam(3e-3))
    tr.run(25)
    assert {11, 12, 13, 14, 15} <= tr.banned
    assert not (tr.banned - {11, 12, 13, 14, 15})
    final_loss = float(loss_fn(tr.unraveled_params(), pipe.batch(999)))
    assert np.isfinite(final_loss)
    assert final_loss < np.log(32) + 0.5
