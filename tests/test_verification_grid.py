"""The generalized verification wrapper, proven by an adversarial
attack x aggregator x verifier grid (ISSUE 5 acceptance):

* every ``verified:``-wrapped coordinatewise spec (mean, trimmed_mean,
  coordinate_median) AND the ButterflyClip flagship ban Byzantine peers
  within K=5 steps under {sign_flip, scaled, random, colluding} attacks,
  with no honest peer ever banned;
* honest runs produce ZERO accusations (peer or system) over 50 steps —
  the nonlinear wrapped specs statically disable the V2 checksum, so
  finite-precision residue can never slander anyone;
* the stepwise and scanned engines produce identical bans/accusations and
  matching aggregates for every grid cell;
* hypothesis property tests for the digest layer: the Pallas digest ops
  equal kernels/ref.py for arbitrary shapes/weights, the per-partition
  digest decomposition is exact, and a single perturbed coordinate in one
  peer's contribution always changes that peer's digest pair (and ONLY
  that peer's — no cross-contamination, so no false accusations).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import butterfly as bf
from repro.core import engine as eng
from repro.core import verification as verif
from repro.core.aggregators import (
    AggregatorSpec,
    aggregate,
    registered_aggregators,
    verified,
    verified_aggregate,
)
from repro.core.protocol import AttackConfig

N, D = 8, 48
BYZ = (6, 7)
BAN_WITHIN = 5  # acceptance: sign-flip Byzantine banned within 5 scan steps
GRID_STEPS = 8
HONEST_STEPS = 50

# the verifier axis: every wrapped coordinatewise spec + the flagship
GRID_SPECS = [
    AggregatorSpec("verified:mean"),
    AggregatorSpec("verified:trimmed_mean", (("trim_ratio", 0.25),)),
    AggregatorSpec("verified:coordinate_median"),
    AggregatorSpec("butterfly_clip"),
]

# the attack axis, mapped onto the engine's registered attack kinds:
# sign_flip = pure flip, scaled = the paper's 1000x-amplified flip,
# random = a large common random direction, colluding = inner-product
# manipulation off the honest mean (Xie et al.)
ATTACKS = {
    "sign_flip": dict(kind="sign_flip", lam=1.0),
    "scaled": dict(kind="sign_flip", lam=1000.0),
    "random": dict(kind="random_direction", lam=100.0),
    "colluding": dict(kind="ipm_06"),
}


def _grads_fn(n=N, d=D):
    w_true = jax.random.normal(jax.random.key(9), (d,))

    def peer_grad(peer, step, params):
        k = jax.random.key((peer * 7919 + step) % (2**31 - 1))
        X = jax.random.normal(k, (4, d))
        return 2 * X.T @ (X @ params - X @ w_true) / 4

    def grads_fn(params, t, flips):
        G = jax.vmap(lambda i: peer_grad(i, t, params))(jnp.arange(n))
        return G, G

    return grads_fn


def _cfg(spec, attack_kw, m_validators=3):
    # clip_iters=200 runs the flagship's CenteredClip to its fixed point so
    # the V2 checksum is honest-clean (the fixed-budget residue otherwise
    # trips it on this far-from-converged workload); wrapped specs declare
    # no n_iters and ignore it.
    return eng.config_from_attack(
        N, D, AttackConfig(start_step=0, **attack_kw),
        tau=1.0, clip_iters=200, m_validators=m_validators, aggregator=spec,
    )


def _run_stepwise(cfg, byz_mask, steps):
    grads_fn = _grads_fn()
    step_fn = eng.jit_protocol_step(cfg)
    state = eng.init_state(cfg, seed=0)
    flips = jnp.zeros((N,), bool)
    params = jnp.zeros(D, jnp.float32)
    outs = []
    for _ in range(steps):
        G, H = grads_fn(params, state.step, flips)
        state, out = step_fn(state, byz_mask, G, H)
        outs.append(out)
    return state, outs


def _run_scan(cfg, byz_mask, steps):
    grads_fn = _grads_fn()
    return jax.jit(
        lambda s, b, p: eng.scan_protocol(cfg, s, b, p, grads_fn, steps)
    )(eng.init_state(cfg, seed=0), byz_mask, jnp.zeros(D, jnp.float32))


# ---------------------------------------------------------------------------
# The adversarial grid: attack x aggregator x {stepwise, scan}
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("attack", sorted(ATTACKS))
@pytest.mark.parametrize("spec", GRID_SPECS, ids=lambda s: s.name)
def test_grid_bans_byzantine_and_scan_equals_stepwise(spec, attack):
    """Every verifiable spec bans every Byzantine peer within BAN_WITHIN
    steps under every attack, never bans an honest peer, and the stepwise
    and scanned engines agree exactly on bans/accusations (aggregates to
    f32 tolerance — jit contexts fuse differently)."""
    cfg = _cfg(spec, ATTACKS[attack])
    byz_mask = jnp.asarray([1.0 if i in BYZ else 0.0 for i in range(N)])

    state_sw, step_outs = _run_stepwise(cfg, byz_mask, GRID_STEPS)
    state_sc, _, outs = _run_scan(cfg, byz_mask, GRID_STEPS)

    # stepwise == scan: bans and accusations bitwise, aggregates close
    banned_sw = np.stack([np.asarray(o.banned_now) for o in step_outs])
    accuse_sw = np.stack([np.asarray(o.accuse_mat) for o in step_outs])
    np.testing.assert_array_equal(np.asarray(outs.banned_now), banned_sw)
    np.testing.assert_array_equal(np.asarray(outs.accuse_mat), accuse_sw)
    np.testing.assert_array_equal(
        np.asarray(state_sc.ban_step), np.asarray(state_sw.ban_step)
    )
    g_sw = np.stack([np.asarray(o.g_hat) for o in step_outs])
    scale = np.abs(g_sw).max(axis=1, keepdims=True) + 1.0
    np.testing.assert_allclose(
        np.asarray(outs.g_hat) / scale, g_sw / scale, atol=2e-5
    )

    # the detection arm: every Byzantine peer banned within BAN_WITHIN
    ban_step = np.asarray(state_sc.ban_step)
    for i in BYZ:
        assert 0 <= ban_step[i] < BAN_WITHIN, (
            f"{spec.name} under {attack}: byz peer {i} ban_step={ban_step[i]}"
        )
    # ... and no honest peer ever banned (no collateral damage)
    for i in range(N):
        if i not in BYZ:
            assert ban_step[i] == -1, (
                f"{spec.name} under {attack}: honest peer {i} banned"
            )


@pytest.mark.slow
@pytest.mark.parametrize("spec", GRID_SPECS, ids=lambda s: s.name)
def test_honest_runs_have_zero_accusations(spec):
    """50 honest steps, both engines: not a single peer or system
    accusation, no bans — the nonlinear wrapped specs' disabled V2
    checksum means finite-precision residue cannot slander anyone."""
    cfg = _cfg(spec, dict(kind="none"))
    byz_mask = jnp.zeros((N,), jnp.float32)

    state_sc, _, outs = _run_scan(cfg, byz_mask, HONEST_STEPS)
    assert not np.asarray(outs.accuse_mat).any(), spec.name
    assert not np.asarray(outs.sys_accuse).any(), spec.name
    assert not np.asarray(outs.banned_now).any(), spec.name
    assert not (np.asarray(state_sc.ban_step) >= 0).any(), spec.name

    state_sw, step_outs = _run_stepwise(cfg, byz_mask, HONEST_STEPS)
    assert not any(np.asarray(o.accuse_mat).any() for o in step_outs)
    assert not any(np.asarray(o.sys_accuse).any() for o in step_outs)
    assert not (np.asarray(state_sw.ban_step) >= 0).any()


def test_wrapped_specs_detect_aggregator_attack():
    """A Byzantine partition OWNER lying about its aggregate is caught even
    where the V2 zero-sum identity does not exist (nonlinear wrapped
    specs): the validator audit recomputes the audited peer's partition
    aggregation (CheckComputations covers the full work)."""
    for spec in GRID_SPECS:
        cfg = eng.config_from_attack(
            N, D,
            AttackConfig(kind="none", start_step=0, aggregator_attack=True,
                         aggregator_scale=5.0, misreport_s=True),
            tau=1.0, clip_iters=200, m_validators=3, aggregator=spec,
        )
        byz_mask = jnp.asarray(
            [1.0 if i in BYZ else 0.0 for i in range(N)]
        )
        state, _, outs = _run_scan(cfg, byz_mask, GRID_STEPS)
        ban_step = np.asarray(state.ban_step)
        reasons = np.asarray(state.ban_reason)
        for i in BYZ:
            assert ban_step[i] >= 0, (
                f"{spec.name}: lying aggregator {i} never banned"
            )
        for i in range(N):
            if i not in BYZ:
                assert ban_step[i] == -1, (
                    f"{spec.name}: honest peer {i} banned "
                    f"(reason {reasons[i]})"
                )


# ---------------------------------------------------------------------------
# Registry / combinator contract
# ---------------------------------------------------------------------------
def test_verified_combinator_and_registry():
    names = set(registered_aggregators())
    assert {"verified:mean", "verified:trimmed_mean",
            "verified:coordinate_median"} <= names
    # combinator: coordinatewise -> wrapped (params preserved), verifiable
    # unchanged, full-vector rejected
    w = verified(AggregatorSpec("trimmed_mean", (("trim_ratio", 0.3),)))
    assert w.name == "verified:trimmed_mean" and w.get("trim_ratio") == 0.3
    assert w.verifiable and not w.warm_startable and w.coordinatewise
    assert verified("butterfly_clip").name == "butterfly_clip"
    assert verified(w) == w
    for name in ("krum", "geometric_median", "centered_clip"):
        with pytest.raises(ValueError, match="not coordinatewise"):
            verified(name)
    # CLI round trip incl. base params
    spec = AggregatorSpec.parse("verified:trimmed_mean:trim_ratio=0.3")
    assert spec == w
    assert AggregatorSpec.parse(spec.canonical()) == spec


def test_wrapped_flat_aggregate_matches_base():
    """aggregate() on a wrapped spec == the base aggregator (the wrapper
    changes verifiability, never the value)."""
    xs = jax.random.normal(jax.random.key(3), (N, D))
    w = jnp.ones((N,)).at[2].set(0.0)
    for base in ("mean", "trimmed_mean", "coordinate_median"):
        got, _ = aggregate(f"verified:{base}", xs, weights=w)
        want, _ = aggregate(base, xs, weights=w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_verified_aggregate_equals_per_partition_application():
    """The simulated path aggregates the full matrix once and splits; the
    distributed path aggregates each partition independently. Coordinate
    decomposition makes them equal — the property that lets a partition
    owner recompute exactly the digest every peer reported."""
    g = jax.random.normal(jax.random.key(5), (N, 52))
    w = jnp.ones((N,)).at[1].set(0.0)
    z = bf.get_random_directions(7, N, bf.pad_to_parts(52, N) // N)
    for spec in GRID_SPECS[:3]:
        agg, parts, s, norms, _ = verified_aggregate(spec, g, z, weights=w)
        base = verif.base_spec(spec)
        part = parts.shape[-1]
        base_fn = base.build(N, part)
        for j in range(N):
            vj, _ = base_fn(parts[:, j, :], w, None, None)
            np.testing.assert_allclose(
                np.asarray(agg[j]), np.asarray(vj), atol=1e-6
            )
            sj, nj = jax.jit(
                lambda xs, v, zz: (
                    ((xs - v[None]) @ zz),
                    jnp.linalg.norm(xs - v[None], axis=1),
                )
            )(parts[:, j, :], agg[j], z[j])
            np.testing.assert_allclose(np.asarray(s[:, j]), np.asarray(sj),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(norms[:, j]),
                                       np.asarray(nj), atol=1e-5)


# ---------------------------------------------------------------------------
# Hypothesis property tests: digest kernels == ref, mismatch exactness
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    n_parts=st.integers(1, 6),
    n=st.integers(2, 12),
    d=st.integers(2, 700),
    seed=st.integers(0, 99999),
)
def test_property_digest_op_matches_ref(n_parts, n, d, seed):
    """Pallas standalone digest pass == kernels/ref.py per partition, over
    ragged shapes (padding must be exact)."""
    from repro.kernels.ops import digest_tables_all_op
    from repro.kernels.ref import digest_tables_ref

    parts = jax.random.normal(jax.random.key(seed), (n_parts, n, d)) * 2
    agg = jax.random.normal(jax.random.key(seed + 1), (n_parts, d))
    z = jax.random.normal(jax.random.key(seed + 2), (n_parts, d))
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=1, keepdims=True), 1e-30)
    s, norms = digest_tables_all_op(parts, agg, z)  # (n, n_parts)
    assert s.shape == (n, n_parts) and norms.shape == (n, n_parts)
    for j in range(n_parts):
        s_r, n_r = digest_tables_ref(parts[j], agg[j], z[j])
        np.testing.assert_allclose(np.asarray(s[:, j]), np.asarray(s_r),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(norms[:, j]), np.asarray(n_r),
                                   atol=1e-4, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n_parts=st.integers(1, 5),
    n=st.integers(2, 12),
    d=st.integers(2, 700),
    banned=st.booleans(),
    seed=st.integers(0, 99999),
)
def test_property_mean_digest_fused_matches_ref(n_parts, n, d, banned, seed):
    """The fused verified:mean aggregation+digest kernel == ref, for
    arbitrary shapes and (banned-row) weights."""
    from repro.kernels.ops import mean_digest_fused_op
    from repro.kernels.ref import mean_digest_fused_ref

    parts = jax.random.normal(jax.random.key(seed), (n_parts, n, d)) * 2
    z = jax.random.normal(jax.random.key(seed + 3), (n_parts, d))
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=1, keepdims=True), 1e-30)
    w = jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0) if banned else None
    agg, s, norms = mean_digest_fused_op(parts, z, w)
    for j in range(n_parts):
        v_r, s_r, n_r = mean_digest_fused_ref(parts[j], z[j], w)
        np.testing.assert_allclose(np.asarray(agg[j]), np.asarray(v_r),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(s[:, j]), np.asarray(s_r),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(norms[:, j]), np.asarray(n_r),
                                   atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 12),
    d=st.integers(2, 300),
    peer=st.integers(0, 10**6),
    coord=st.integers(0, 10**6),
    delta=st.floats(0.1, 100.0),
    flip=st.booleans(),
    seed=st.integers(0, 99999),
)
def test_property_single_coordinate_perturbation_always_changes_digest(
    n, d, peer, coord, delta, flip, seed
):
    """Digest-mismatch detection is exact: perturbing ONE coordinate of one
    peer's contribution always changes that peer's digest pair (in exact
    arithmetic s shifts by delta*z_c != 0 — checked here in f64), and never
    changes any other peer's digests (the broadcast v is fixed), so the
    recompute accuses exactly the cheater."""
    i, c = peer % n, coord % d
    delta = (-delta if flip else delta)
    xs = np.asarray(
        jax.random.normal(jax.random.key(seed), (n, d)) * 2, np.float64
    )
    v = np.asarray(jax.random.normal(jax.random.key(seed + 1), (d,)),
                   np.float64)
    z = np.asarray(bf.get_random_directions(seed + 2, 1, d)[0], np.float64)

    def digests(x):
        diff = x - v[None]
        return diff @ z, np.linalg.norm(diff, axis=1)

    s0, n0 = digests(xs)
    xs2 = xs.copy()
    xs2[i, c] += delta
    s1, n1 = digests(xs2)
    assert s1[i] != s0[i] or n1[i] != n0[i]
    # in exact arithmetic the projection alone already moves: delta*z_c != 0
    assert z[c] != 0.0 and abs(delta * z[c]) > 0.0
    # no cross-contamination: every other peer's digests are untouched
    mask = np.arange(n) != i
    np.testing.assert_array_equal(s1[mask], s0[mask])
    np.testing.assert_array_equal(n1[mask], n0[mask])


def test_engine_bans_single_coordinate_cheater():
    """End-to-end digest-mismatch detection: a peer that perturbs ONE
    coordinate of its gradient (honest digests recomputed from the public
    seed disagree) is accused and banned once audited, for every wrapped
    spec — deterministic seed, so the audit schedule is fixed."""
    cheater = 2
    STEPS = 12  # >= worst-case audit latency at m_validators=3

    def grads_fn(params, t, flips):
        base = _grads_fn()
        G, H = base(params, t, flips)
        G = G.at[cheater, 5].add(0.5)  # one coordinate, every step
        return G, H

    for spec in GRID_SPECS[:3]:
        cfg = _cfg(spec, dict(kind="none"))
        state, _, outs = jax.jit(
            lambda s, b, p, cfg=cfg: eng.scan_protocol(
                cfg, s, b, p, grads_fn, STEPS
            )
        )(eng.init_state(cfg, seed=0), jnp.zeros(N), jnp.zeros(D, jnp.float32))
        ban_step = np.asarray(state.ban_step)
        assert ban_step[cheater] >= 0, (
            f"{spec.name}: single-coordinate cheater never banned"
        )
        assert all(ban_step[i] == -1 for i in range(N) if i != cheater), (
            spec.name
        )
