"""Native (non-interpret) TPU lowering validation for the kernel family.

Interpret mode hides an entire class of kernel bugs — block shapes that
violate the TPU (8, 128) tile minimum, scalar operands that must live in
SMEM, sublane-1 slices of batched outputs. These tests push every kernel
through the REAL Mosaic lowering pipeline:

* on a TPU host (``jax.default_backend() == 'tpu'``): compile AND run
  natively, comparing against interpret mode;
* on a CPU-only host: cross-platform lowering via the jax export API with
  ``platforms=['tpu']`` — runs the full Mosaic pass (this is what caught
  the original (1, 1)-blocked tau operands), no TPU needed;
* skipped only when neither a TPU nor the export API exists.

CI exercises this file under ``REPRO_PALLAS_COMPILE=1`` (see
.github/workflows/ci.yml); the env-flag wiring itself is covered by the
subprocess test at the bottom.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import centered_clip as _k

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _export_fn():
    """The cross-platform export entry point, wherever this jax hides it."""
    exp = getattr(jax, "export", None)
    if exp is not None and hasattr(exp, "export"):
        return exp.export
    try:
        from jax._src.export import _export

        return _export.export
    except ImportError:
        return None


def _on_tpu():
    return jax.default_backend() == "tpu"


def _validate(fn, *args):
    """Native-compile fn on TPU, else Mosaic-lower it via export."""
    jitted = jax.jit(fn)
    if _on_tpu():
        return jax.tree.map(np.asarray, jitted(*args))
    exporter = _export_fn()
    if exporter is None:
        pytest.skip("no TPU and no cross-platform export API in this jax")
    module = exporter(jitted, platforms=["tpu"])(*args).mlir_module()
    assert "tpu_custom_call" in module  # the Mosaic kernel made it through
    return None


N, D, PARTS, ITERS = 8, 384, 4, 5


def _stack(key, shape):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32)


def test_centered_clip_lowers_natively():
    xs = _stack(0, (N, D))
    taus = jnp.full((ITERS,), 1.0, jnp.float32)
    out = _validate(
        lambda x: _k.centered_clip_pallas(x, taus, interpret=False), xs
    )
    if out is not None:
        ref = _k.centered_clip_pallas(xs, taus, interpret=True)
        np.testing.assert_allclose(out, np.asarray(ref), atol=1e-5)


def test_butterfly_clip_lowers_natively():
    parts = _stack(1, (PARTS, N, D))
    taus = jnp.full((ITERS,), 1.0, jnp.float32)
    out = _validate(
        lambda p: _k.butterfly_clip_pallas(p, taus, interpret=False), parts
    )
    if out is not None:
        ref = _k.butterfly_clip_pallas(parts, taus, interpret=True)
        np.testing.assert_allclose(out, np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("warm", [False, True])
def test_fused_butterfly_lowers_natively(warm):
    parts = _stack(2, (PARTS, N, D))
    z = _stack(3, (PARTS, D))
    v0 = _stack(4, (PARTS, D)) if warm else None
    taus = jnp.full((ITERS,), 1.0, jnp.float32)

    def fn(p, zz):
        return _k.butterfly_clip_fused_pallas(
            p, taus, zz, v0=v0, interpret=False
        )

    out = _validate(fn, parts, z)
    if out is not None:
        ref = _k.butterfly_clip_fused_pallas(
            parts, taus, z, v0=v0, interpret=True
        )
        for got, want in zip(out, ref):
            np.testing.assert_allclose(got, np.asarray(want), atol=1e-4)


def test_fused_single_lowers_natively():
    xs = _stack(5, (N, D))
    z = _stack(6, (D,))
    taus = jnp.full((ITERS,), 1.0, jnp.float32)
    _validate(
        lambda x, zz: _k.centered_clip_fused_pallas(
            x, taus, zz, interpret=False
        ),
        xs, z,
    )


def test_verify_tables_batched_lowers_natively():
    parts = _stack(7, (PARTS, N, D))
    agg = _stack(8, (PARTS, D))
    z = _stack(9, (PARTS, D))
    _validate(
        lambda p, a, zz: _k.verify_tables_batched_pallas(
            p, a, zz, 1.0, interpret=False
        ),
        parts, agg, z,
    )


def test_verify_tables_lowers_natively():
    """The single (unbatched) verification kernel — its SMEM tau operand
    is exactly the (1, 1)-block class the Mosaic pass rejects."""
    xs = _stack(20, (N, D))
    v = _stack(21, (D,))
    z = _stack(22, (D,))
    out = _validate(
        lambda x, vv, zz: _k.verify_tables_pallas(
            x, vv, zz, 1.0, interpret=False
        ),
        xs, v, z,
    )
    if out is not None:
        ref = _k.verify_tables_pallas(xs, v, z, 1.0, interpret=True)
        for got, want in zip(out, ref):
            np.testing.assert_allclose(got, np.asarray(want), atol=1e-5)


def test_digest_tables_batched_lowers_natively():
    """The generalized verification wrapper's standalone digest pass
    (s_i = <z, x_i - v>, ||x_i - v||, no clip weight) through the real
    Mosaic pipeline."""
    parts = _stack(16, (PARTS, N, D))
    agg = _stack(17, (PARTS, D))
    z = _stack(18, (PARTS, D))
    out = _validate(
        lambda p, a, zz: _k.digest_tables_batched_pallas(
            p, a, zz, interpret=False
        ),
        parts, agg, z,
    )
    if out is not None:
        ref = _k.digest_tables_batched_pallas(parts, agg, z, interpret=True)
        for got, want in zip(out, ref):
            np.testing.assert_allclose(got, np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("tau", [0.0, 1.0])
def test_digest_tables_rows_lowers_natively(tau):
    """The sampled-digest audit kernel: one HBM pass over only the k
    sampled partitions, their ids scalar-prefetched into SMEM to steer the
    grid — the dynamic-index block maps are exactly what interpret mode
    cannot validate. tau=0 is the verified:* digest, tau>0 the
    ButterflyClip clip-weighted variant."""
    k = 2
    parts = _stack(27, (PARTS, N, D))
    agg = _stack(28, (PARTS, D))
    z = _stack(29, (PARTS, D))
    rows = jnp.asarray([3, 1], jnp.int32)

    def fn(p, a, zz, r):
        return _k.digest_tables_rows_pallas(
            p, a, zz, r, tau, interpret=False
        )

    out = _validate(fn, parts, agg, z, rows)
    if out is not None:
        ref = _k.digest_tables_rows_pallas(
            parts, agg, z, rows, tau, interpret=True
        )
        for got, want in zip(out, ref):
            assert got.shape == (k, N)
            np.testing.assert_allclose(got, np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("weighted", [False, True])
def test_mean_digest_fused_lowers_natively(weighted):
    """verified:mean's fused aggregation + digest-epilogue kernel (2 HBM
    passes, two grid phases sharing the aggregate output ref) must lower
    as a unit."""
    parts = _stack(19, (PARTS, N, D))
    z = _stack(20, (PARTS, D))
    w = jnp.ones((N,)).at[1].set(0.0) if weighted else None

    def fn(p, zz):
        return _k.mean_digest_fused_pallas(p, zz, w, interpret=False)

    out = _validate(fn, parts, z)
    if out is not None:
        ref = _k.mean_digest_fused_pallas(parts, z, w, interpret=True)
        for got, want in zip(out, ref):
            np.testing.assert_allclose(got, np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("base", ["mean", "coordinate_median"])
def test_verified_wrapped_spec_dispatch_lowers(base):
    """The verified:* route into the digest kernels: verified_aggregate on
    a wrapped spec with use_pallas=True must reach the fused mean-digest
    kernel (verified:mean) / the standalone digest kernel (the sort-based
    bases) through spec dispatch. Under REPRO_PALLAS_COMPILE=1 this lowers
    natively; in interpret mode it doubles as a spec-vs-jnp equivalence
    check."""
    from repro.core.aggregators import AggregatorSpec, verified_aggregate
    from repro.kernels import ops

    n, d = N, N * D
    g = _stack(21, (n, d))
    z = _stack(22, (n, D))
    spec = AggregatorSpec(f"verified:{base}")

    def fn(gg, zz):
        agg, _parts, s, norms, iters = verified_aggregate(
            spec, gg, zz, use_pallas=True
        )
        return agg, s, norms, iters

    if ops._INTERPRET:
        got = jax.jit(fn)(g, z)
        ref = verified_aggregate(spec, g, z, use_pallas=False)
        want = (ref[0], ref[2], ref[3])
        for a, b in zip(got[:3], want):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-4
            )
    else:
        _validate(fn, g, z)


def test_repro_pallas_compile_env_flag():
    """REPRO_PALLAS_COMPILE=1 must flip the ops layer to interpret=False and
    the resulting jaxpr must still Mosaic-lower (subprocess: the flag is
    read at import)."""
    if _export_fn() is None and not _on_tpu():
        pytest.skip("no TPU and no cross-platform export API in this jax")
    code = """
import jax, jax.numpy as jnp
import repro.kernels.ops as ops
assert ops._INTERPRET is False, "REPRO_PALLAS_COMPILE=1 not honoured"
parts = jnp.ones((4, 8, 384), jnp.float32)
z = jnp.ones((4, 384), jnp.float32)
fn = jax.jit(lambda p, z: ops.butterfly_clip_fused_op(p, 1.0, z, n_iters=3))
if jax.default_backend() == "tpu":
    jax.block_until_ready(fn(parts, z))
else:
    try:
        from jax import export as exp
        exporter = exp.export
    except ImportError:
        from jax._src.export import _export as exp
        exporter = exp.export
    module = exporter(fn, platforms=["tpu"])(parts, z).mlir_module()
    assert "tpu_custom_call" in module
print("PALLAS_COMPILE_OK")
"""
    env = dict(os.environ)
    env["REPRO_PALLAS_COMPILE"] = "1"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-W", "ignore", "-c", code],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert r.returncode == 0, r.stdout[-2000:] + "\n---\n" + r.stderr[-2000:]
    assert "PALLAS_COMPILE_OK" in r.stdout


@pytest.mark.parametrize("codec", ["int8", "bf16"])
def test_fused_dequant_butterfly_lowers_natively(codec):
    """The compressed:butterfly_clip hot path — fused dequantize + clip +
    digest over WIRE payloads (int8/bf16 blocks in HBM, f32 sidecar scales
    in a (1, n, 1) block) — through the real Mosaic pipeline, per wire
    dtype."""
    from repro.core import compression as comp

    x = _stack(23, (PARTS, N, D))
    qs, scales = comp.quantize(x, codec)
    z = _stack(24, (PARTS, D))
    taus = jnp.full((ITERS,), 1.0, jnp.float32)

    def fn(q, s, zz):
        return _k.butterfly_clip_fused_dequant_pallas(
            q, s, taus, zz, interpret=False
        )

    out = _validate(fn, qs, scales, z)
    if out is not None:
        ref = _k.butterfly_clip_fused_dequant_pallas(
            qs, scales, taus, z, interpret=True
        )
        for got, want in zip(out, ref):
            np.testing.assert_allclose(got, np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("codec", ["int8", "bf16"])
def test_mean_digest_fused_dequant_lowers_natively(codec):
    """compressed:verified:mean's fused dequantize + mean + digest kernel
    must lower as a unit for both wire dtypes (the int8 path exercises
    integer-block loads that interpret mode cannot validate)."""
    from repro.core import compression as comp

    x = _stack(25, (PARTS, N, D))
    qs, scales = comp.quantize(x, codec)
    z = _stack(26, (PARTS, D))
    w = jnp.ones((N,)).at[2].set(0.0)

    def fn(q, s, zz):
        return _k.mean_digest_fused_dequant_pallas(q, s, zz, w, interpret=False)

    out = _validate(fn, qs, scales, z)
    if out is not None:
        ref = _k.mean_digest_fused_dequant_pallas(
            qs, scales, z, w, interpret=True
        )
        for got, want in zip(out, ref):
            np.testing.assert_allclose(got, np.asarray(want), atol=1e-4)


def test_adaptive_step_kernel_lowers_natively():
    """The one-pass adaptive clip iteration (cw from carried sq, v update,
    incremental next-sq) through the real Mosaic pipeline."""
    parts = _stack(10, (PARTS, N, D))
    v = _stack(11, (PARTS, 1, D)) * 0.1
    sq = jnp.sum((parts - v) ** 2, axis=-1, keepdims=True)

    def fn(p, vv, ss):
        return _k.adaptive_clip_step_pallas(p, vv, ss, 1.0, interpret=False)

    out = _validate(fn, parts, v, sq)
    if out is not None:
        ref = _k.adaptive_clip_step_pallas(parts, v, sq, 1.0, interpret=True)
        for got, want in zip(out, ref):
            np.testing.assert_allclose(got, np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("adaptive", [False, True])
def test_spec_dispatched_fused_kernels_lower(adaptive):
    """The AggregatorSpec route into the fused kernels: verified_aggregate
    (the engine's aggregation phase) with use_pallas=True must reach the
    fused / adaptive Mosaic kernels through spec dispatch. Under
    REPRO_PALLAS_COMPILE=1 (the CI Mosaic job) this lowers natively; in
    interpret mode it doubles as a spec-vs-jnp equivalence check."""
    from repro.core.aggregators import AggregatorSpec, verified_aggregate
    from repro.kernels import ops

    n, d = 8, 8 * D
    g = _stack(14, (n, d))
    z = _stack(15, (n, D))
    params = (("adaptive_tol", 1e-4 if adaptive else None),
              ("n_iters", ITERS), ("tau", 1.0), ("warm_start", False))
    spec = AggregatorSpec("butterfly_clip", params)

    def fn(gg, zz):
        agg, _parts, s, norms, iters = verified_aggregate(
            spec, gg, zz, use_pallas=True
        )
        return agg, s, norms, iters

    if ops._INTERPRET:
        got = jax.jit(fn)(g, z)
        ref = verified_aggregate(spec, g, z, use_pallas=False)
        want = (ref[0], ref[2], ref[3], ref[4])
        for a, b in zip(got[:3], want[:3]):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-4
            )
    else:
        _validate(fn, g, z)


@pytest.mark.parametrize("warm", [False, True])
def test_adaptive_driver_lowers_natively(warm):
    """The full early-exit driver: lax.while_loop wrapped around the Mosaic
    step kernel must lower as a unit (early-exit kernels cannot merge
    interpreter-only — this is the CI gate for the adaptive family)."""
    parts = _stack(12, (PARTS, N, D))
    v0 = _stack(13, (PARTS, D)) * 0.1 if warm else None

    def fn(p):
        return _k.butterfly_clip_adaptive_pallas(
            p, 1.0, 1e-4, ITERS, v0=v0, interpret=False
        )

    out = _validate(fn, parts)
    if out is not None:
        ref = _k.butterfly_clip_adaptive_pallas(
            parts, 1.0, 1e-4, ITERS, v0=v0, interpret=True
        )
        np.testing.assert_allclose(out[0], np.asarray(ref[0]), atol=1e-4)
        np.testing.assert_array_equal(out[1], np.asarray(ref[1]))
