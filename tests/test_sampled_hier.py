"""Flat-cost verification properties (core.hierarchy + core.engine).

The two axes that shrink Alg. 6's O(n^2) table broadcast — sampled-digest
audits and the hierarchical butterfly-of-butterflies — must not weaken the
protocol's guarantees. The load-bearing properties:

* the sampled digest-column set is coverage-bounded: no column's audit age
  ever exceeds :func:`hierarchy.staleness_bound` (the top-k-by-age rule),
  both for the pure sampler and for the ledger the scanned engine carries;
* a cheater whose corruption lands in an UNSAMPLED column this step is not
  lost — it is banned as soon as its column is drawn, within the staleness
  window;
* honest runs stay honest: sampling and hierarchy produce zero bans and
  zero accusations with no attack, and the sampled aggregate is the full
  aggregate (sampling touches tables only);
* the mode x aggregator attack grid bans exactly the Byzantine set with
  zero honest casualties in every mode combination;
* the analytic table model behind the bench gates: bytes shrink
  monotonically per axis and the composed mode clears the n=1024 <= 10%
  acceptance ceiling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import hierarchy as hier

N, D = 16, 64
BYZ = (3, 11)


def _grads_fn(n=N, d=D):
    """iid noise around a fixed descent direction; the engine's attack
    phase applies the Byzantine corruption itself."""
    mu = jax.random.normal(jax.random.key(7), (d,)) * 0.1

    def grads_fn(params, t, flips):
        key = jax.random.fold_in(jax.random.key(1), t)
        G = mu[None] + jax.random.normal(key, (n, d), jnp.float32)
        return G, G

    return grads_fn


def _run(steps, byz=(), **cfg_kw):
    cfg = eng.EngineConfig(
        n=N, d=D, tau=1.0, clip_iters=10, m_validators=2,
        aggregator="verified:mean", **cfg_kw,
    )
    runner = eng.make_scan_runner(cfg, _grads_fn(), steps)
    state0 = eng.init_state(cfg, seed=0)
    byz_mask = jnp.zeros((N,)).at[jnp.asarray(list(byz), jnp.int32)].set(
        1.0) if byz else jnp.zeros((N,))
    state, _, outs = runner(state0, byz_mask, jnp.zeros(()))
    return cfg, state, outs


# ---------------------------------------------------------------------------
# Sampler coverage: audit age below the CHOOSETARGET-style bound
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_cells,m,k", [(24, 2, 3), (16, 1, 1), (32, 2, 2)])
def test_sampler_age_below_staleness_bound(n_cells, m, k):
    bound = hier.staleness_bound(n_cells, m, k)
    col_checked = jnp.full((n_cells,), -1, jnp.int32)
    key = jax.random.key(42)
    worst = 0
    for t in range(6 * bound):
        idx, mask = hier.sample_audit_cells(
            jax.random.fold_in(key, t), t, col_checked, m, k, n_cells
        )
        ages = t - np.asarray(col_checked)[np.asarray(idx)]
        if t >= bound:  # past warmup every draw must respect the bound
            worst = max(worst, int(ages.max()))
        col_checked = jnp.where(mask, t, col_checked)
        if t == bound - 1:
            # coverage: every column sampled at least once within one bound
            assert (np.asarray(col_checked) >= 0).all()
    assert worst <= bound, f"realized audit age {worst} > bound {bound}"
    k_tot = hier.sampled_k(n_cells, m, k)
    assert int(mask.sum()) == k_tot and idx.shape == (k_tot,)


@pytest.mark.parametrize("groups", [None, 4])
def test_engine_sampled_ledger_bounded(groups):
    """The scanned engine's col_checked ledger obeys the same bound: the
    per-column gap between consecutive broadcasts of outs.sampled_parts
    never exceeds staleness_bound (+1 for the ledger's end-of-step
    update lag)."""
    m, k = 2, 2
    bound = hier.staleness_bound(N, m, k)
    steps = 4 * bound
    cfg, state, outs = _run(steps, audit_k=k, groups=groups)
    samp = np.asarray(outs.sampled_parts)  # (steps, n)
    assert samp.shape == (steps, N)
    assert (samp.sum(axis=1) == hier.sampled_k(N, m, k)).all()
    for c in range(N):
        hits = np.nonzero(samp[:, c])[0]
        assert len(hits) > 0, f"column {c} never sampled in {steps} steps"
        gaps = np.diff(np.concatenate([[-1], hits]))
        assert gaps.max() <= bound + 1, (
            f"column {c} waited {gaps.max()} steps (bound {bound})"
        )
    assert (np.asarray(state.col_checked) >= 0).all()


# ---------------------------------------------------------------------------
# Honest runs stay honest; sampling touches tables only
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "kw",
    [dict(audit_k=2), dict(groups=4), dict(audit_k=2, groups=4)],
    ids=["sampled", "hier", "hier_sampled"],
)
def test_honest_run_no_bans_no_accusations(kw):
    _, state, outs = _run(12, **kw)
    assert (np.asarray(state.ban_step) == -1).all()
    assert not np.asarray(outs.accuse_mat).any()
    assert not np.asarray(outs.sys_accuse).any()
    assert np.asarray(outs.checksum_violations).sum() == 0


def test_sampling_does_not_change_the_aggregate():
    """audit_k shrinks the digest broadcast, not the aggregation: the
    honest g_hat stream must match the full-table run exactly."""
    _, _, full = _run(8)
    _, _, sampled = _run(8, audit_k=1)
    np.testing.assert_array_equal(
        np.asarray(full.g_hat), np.asarray(sampled.g_hat)
    )


def test_hier_mean_matches_flat_mean():
    """Two-level weighted mean == flat mean for the linear spec (equal
    weights), so the hierarchical honest aggregate matches flat to float
    tolerance."""
    _, _, flat = _run(6)
    _, _, h = _run(6, groups=4)
    np.testing.assert_allclose(
        np.asarray(flat.g_hat), np.asarray(h.g_hat), atol=1e-4
    )


# ---------------------------------------------------------------------------
# The sampled window: an unsampled cheater is caught, within the bound
# ---------------------------------------------------------------------------
def test_unsampled_cheating_aggregator_banned_within_window():
    """A lying aggregator (corrupts its partition, misreports its digest
    row to cancel the checksum) under audit_k=1/m=1 sampling: the corrupted
    column is invisible every step it goes unsampled, but the age-priority
    draw reaches it within staleness_bound — the ban lands inside the
    window, never silently lost. The validator peer-audit (full recompute)
    runs concurrently, so the effective bound is the max of the two
    coverage windows."""
    m, k = 1, 1
    liar = min(BYZ)
    col_bound = hier.staleness_bound(N, m, k)
    audit_bound = int(np.ceil(N / m)) + 2
    bound = max(col_bound, audit_bound)
    cfg = eng.EngineConfig(
        n=N, d=D, tau=1.0, clip_iters=10, m_validators=m,
        attack="none", aggregator_attack=True, aggregator_scale=5.0,
        misreport_s=True, start_step=0, audit_k=k,
    )
    runner = eng.make_scan_runner(cfg, _grads_fn(), bound + 4)
    state0 = eng.init_state(cfg, seed=0)
    byz_mask = jnp.zeros((N,)).at[liar].set(1.0)
    state, _, outs = runner(state0, byz_mask, jnp.zeros(()))
    ban_step = np.asarray(state.ban_step)
    assert ban_step[liar] >= 0, "lying aggregator never banned"
    assert ban_step[liar] <= bound, (
        f"banned at step {ban_step[liar]} > staleness window {bound}"
    )
    honest = [i for i in range(N) if i != liar]
    assert (ban_step[honest] == -1).all()


# ---------------------------------------------------------------------------
# Mode x aggregator attack grid
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("agg", ["verified:mean", "butterfly_clip"])
@pytest.mark.parametrize(
    "kw",
    [dict(), dict(audit_k=2), dict(groups=4), dict(audit_k=2, groups=4)],
    ids=["full", "sampled", "hier", "hier_sampled"],
)
def test_mode_grid_bans_exactly_the_byzantine(kw, agg):
    """sign_flip attackers across every mode combination: all Byzantine
    banned within the validator-audit coverage window, zero honest bans,
    no honest peer ever peer-accused, and full quiescence once the
    attackers are gone. (While an attacker is still active, the iterative
    flagship's V2 checksum — exact only at the clip fixed point — may
    transiently flag an honest-OWNED partition; the recompute exonerates
    it, which is the protocol working, so system accusations are only
    required to vanish post-ban.)"""
    m = 2
    steps = int(np.ceil(N / m)) + 6
    # clip_iters=60: the flagship's V2 identity holds at the converged
    # fixed point; an under-converged residual would flag partitions
    # spuriously (and be exonerated — noisy, but not the property here)
    cfg = eng.EngineConfig(
        n=N, d=D, tau=1.0, clip_iters=60, m_validators=m,
        attack="sign_flip", lam=100.0, start_step=0, aggregator=agg, **kw,
    )
    runner = eng.make_scan_runner(cfg, _grads_fn(), steps)
    state0 = eng.init_state(cfg, seed=0)
    byz_mask = jnp.zeros((N,)).at[jnp.asarray(BYZ)].set(1.0)
    state, _, outs = runner(state0, byz_mask, jnp.zeros(()))
    ban_step = np.asarray(state.ban_step)
    banned = set(np.nonzero(ban_step >= 0)[0].tolist())
    assert banned == set(BYZ), f"banned {sorted(banned)} != {sorted(BYZ)}"
    honest = np.asarray([i for i in range(N) if i not in BYZ])
    accuse = np.asarray(outs.accuse_mat)  # (steps, accuser, target)
    assert not accuse[:, :, honest].any(), "an honest peer was accused"
    post = int(ban_step[list(BYZ)].max()) + 1
    assert post < steps  # bans land inside the coverage window
    assert not accuse[post:].any()
    assert not np.asarray(outs.sys_accuse)[post:].any()
    if agg == "verified:mean":
        # the exact linear checksum never flags anyone but under attack
        assert not np.asarray(outs.sys_accuse)[:, honest].any()


# ---------------------------------------------------------------------------
# The analytic table model behind the bench gates
# ---------------------------------------------------------------------------
def test_table_model_shrinks_and_clears_acceptance_ceiling():
    full = hier.table_scalars(1024)
    sampled = hier.table_scalars(1024, m_validators=2, audit_k=2)
    h = hier.table_scalars(1024, groups=32)
    both = hier.table_scalars(1024, m_validators=2, audit_k=2, groups=32)
    assert both <= h <= full and both <= sampled <= full
    # the PR acceptance gate (mirrored in benchmarks/check_regression.py)
    assert both <= 0.10 * full
    assert sampled <= 0.10 * full and h <= 0.10 * full
    # sampling caps at the column count: a huge budget = full tables
    assert hier.table_scalars(16, m_validators=8, audit_k=8) == \
        hier.table_scalars(16)
    with pytest.raises(ValueError):
        hier.group_shape(16, 3)  # must divide n


def test_sampled_k_and_bound_consistency():
    assert hier.sampled_k(16, 2, 2) == 4
    assert hier.sampled_k(16, 8, 8) == 16  # capped
    assert hier.staleness_bound(16, 2, 2) == int(np.ceil(16 / 4)) + 2
