"""Integration: the full BTARD-SGD trainer vs attacks and vs PS baselines —
the controlled §4.1-style experiment in miniature, plus BTARD-Clipped-SGD
(Alg. 9) and the Sybil gate (App. F)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
from repro.core.sybil import SybilGate
from repro.data import classification_batch, peer_seed
from repro.optim import sgd

DIM, CLASSES = 16, 4


def _setup():
    def batch_fn(peer, step, flipped):
        return classification_batch(
            peer_seed(0, step, peer), 16, DIM, CLASSES, flip_labels=flipped
        )

    def loss_fn(params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        return -jnp.mean(
            jnp.take_along_axis(
                jax.nn.log_softmax(logits), batch["y"][:, None], axis=1
            )
        )

    params0 = {
        "w": jnp.zeros((DIM, CLASSES)),
        "b": jnp.zeros((CLASSES,)),
    }
    eval_batch = classification_batch(10**7, 512, DIM, CLASSES)

    def accuracy(params):
        logits = eval_batch["x"] @ params["w"] + params["b"]
        return float((jnp.argmax(logits, 1) == eval_batch["y"]).mean())

    return loss_fn, params0, batch_fn, accuracy


@pytest.mark.parametrize("attack", ["sign_flip", "alie", "ipm_06"])
def test_btard_recovers_under_7_of_16_byzantine(attack):
    loss_fn, params0, batch_fn, accuracy = _setup()
    cfg = TrainerConfig(
        n_peers=16,
        byzantine=tuple(range(9, 16)),
        attack=AttackConfig(kind=attack, start_step=5),
        defense="btard",
        tau=1.0,
        m_validators=2,
        seed=0,
    )
    tr = BTARDTrainer(loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.3, momentum=0.9))
    tr.run(50)
    acc = accuracy(tr.unraveled_params())
    assert set(range(9, 16)) <= tr.banned, (attack, tr.banned)
    assert not (tr.banned - set(range(9, 16)))
    assert acc > 0.85, (attack, acc)


def test_btard_matches_allreduce_without_attack():
    loss_fn, params0, batch_fn, accuracy = _setup()
    accs = {}
    for defense in ["btard", "mean"]:
        cfg = TrainerConfig(
            n_peers=8, byzantine=(), defense=defense, tau=2.0, seed=0
        )
        tr = BTARDTrainer(loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.3, momentum=0.9))
        tr.run(40)
        accs[defense] = accuracy(tr.unraveled_params())
    assert abs(accs["btard"] - accs["mean"]) < 0.08, accs


def test_ps_baselines_fail_where_paper_says():
    """Plain mean breaks under amplified sign flip (Fig. 3 upper rows)."""
    loss_fn, params0, batch_fn, accuracy = _setup()
    cfg = TrainerConfig(
        n_peers=16,
        byzantine=tuple(range(9, 16)),
        attack=AttackConfig(kind="sign_flip", start_step=5),
        defense="mean",
        seed=0,
    )
    tr = BTARDTrainer(loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.3, momentum=0.9))
    tr.run(30)
    assert accuracy(tr.unraveled_params()) < 0.7


def test_btard_clipped_sgd_heavy_tails():
    """Alg. 9: peers clip their own gradients; training still converges."""
    loss_fn, params0, batch_fn, accuracy = _setup()
    cfg = TrainerConfig(
        n_peers=8,
        byzantine=(6, 7),
        attack=AttackConfig(kind="sign_flip", start_step=5),
        defense="btard",
        tau=1.0,
        clip_lambda=5.0,
        m_validators=2,
        seed=0,
    )
    tr = BTARDTrainer(loss_fn, params0, batch_fn, cfg, optimizer=sgd(0.3, momentum=0.9))
    tr.run(40)
    assert {6, 7} <= tr.banned
    assert accuracy(tr.unraveled_params()) > 0.85


def test_sybil_gate_blocks_fake_identities():
    def grad_fn(peer, step, params, flipped=False):
        k = jax.random.key(peer * 31 + step)
        return np.asarray(jax.random.normal(k, (8,)), np.float32)

    gate = SybilGate(grad_fn, probation_steps=5, check_prob=0.9, seed=0)
    gate.request_join(100, 0, dishonest=False)
    gate.request_join(101, 0, dishonest=True)
    for t in range(20):
        admitted, rejected = gate.step(None, t)
    assert 100 in admitted
    assert 101 in rejected and 101 not in admitted
