"""Minimal stand-in for ``hypothesis`` so the property tests still run (with
a deterministic sampler) when the optional dep is missing.

When hypothesis IS installed (see requirements-dev.txt) it is re-exported
unchanged. Otherwise ``given`` expands each strategy into a fixed number of
seeded pseudo-random examples — weaker shrinking/coverage than the real
thing, but the invariants get exercised either way and collection never
fails on the import.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    _DEFAULT_MAX_EXAMPLES = 10

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Accepts (and mostly ignores) the hypothesis knobs; only
        max_examples matters to the fallback sampler."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", None) or getattr(
                    fn, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES
                )
                # deterministic per-test stream so failures reproduce
                rng = random.Random(fn.__name__)
                for i in range(n):
                    drawn = {
                        k: s.example(rng) for k, s in strategy_kwargs.items()
                    }
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:  # noqa: BLE001 - re-raise w/ context
                        raise AssertionError(
                            f"falsifying example ({i + 1}/{n}): {drawn!r}"
                        ) from e

            # hide the drawn parameters from pytest's fixture resolution
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._compat_max_examples = getattr(fn, "_compat_max_examples", None)
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items()
                    if name not in strategy_kwargs
                ]
            )
            return wrapper

        return deco
