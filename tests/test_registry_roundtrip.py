"""Property test: aggregator-spec names are a lossless wire format.

Configs cross process boundaries as canonical strings (CLI flags,
checkpoint metadata, the launch manifest) — ``parse -> canonical ->
parse`` must be the identity for EVERY registered aggregator under any
typed parameter assignment, or two peers can disagree about the protocol
they are running. btard-lint checks one alternate assignment statically
(tools/analysis/contracts.py C1); this property test sweeps the space.
"""
import jax  # noqa: F401  (forces the cpu-pinning conftest import order)

from _hypothesis_compat import given, settings, strategies as st

from repro.core import aggregators as agg_mod

_NAMES = agg_mod.registered_aggregators()


def _value_for(name, default, fval, ival, bval, codec):
    if name == "codec":
        return codec
    if isinstance(default, bool):
        return bval
    if isinstance(default, float):
        return fval
    return ival  # int params and the None-defaulted n_byzantine


@settings(max_examples=25, deadline=None)
@given(
    name=st.sampled_from(_NAMES),
    fval=st.floats(min_value=1e-3, max_value=16.0),
    ival=st.integers(min_value=1, max_value=64),
    bval=st.booleans(),
    codec=st.sampled_from(["int8", "bf16"]),
)
def test_spec_roundtrip_with_nondefault_params(name, fval, ival, bval, codec):
    defn = agg_mod.REGISTRY[name]
    params = {
        k: _value_for(k, v, fval, ival, bval, codec)
        for k, v in defn.defaults
    }
    spec = agg_mod.AggregatorSpec(name, tuple(sorted(params.items())))
    canon = spec.canonical()
    again = agg_mod.AggregatorSpec.parse(canon)
    assert again == spec
    assert again.canonical() == canon
    # param values survive with their types intact, not just their repr
    assert again.param_dict() == params


@settings(max_examples=10, deadline=None)
@given(name=st.sampled_from(_NAMES))
def test_bare_name_roundtrip(name):
    spec = agg_mod.AggregatorSpec.parse(name)
    assert agg_mod.AggregatorSpec.parse(spec.canonical()) == spec
