"""Negative tests for btard-lint (tools/analysis).

Each test plants one deliberate violation of a protocol invariant and
asserts the *intended* check — and only it — reports a finding. This is
what keeps the linter honest: a rule that never fires on a planted bug is
dead weight, and a rule that fires from the wrong layer would bury real
reports under noise.

Planted violations:

1. host callback inside a protocol phase        -> purity (callback)
2. off-chain PRNG seed (constant-folded key)    -> purity (constant key)
3. upcast of a collective's output, no barrier  -> wire_dtype W1
4. widened operand feeding a collective         -> wire_dtype W2
5. scan-carry shape/treedef drift               -> carry_stability
6. coordinatewise flag on a non-bitwise spec    -> coordinatewise
7. kernel with no ref oracle / manifest entry   -> pallas_completeness
8. illegal TPU block specs (VMEM scalar, lane)  -> pallas_block_specs
"""
import typing

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import AbstractMesh, PartitionSpec as P

from tools.analysis import common
from tools.analysis import kernels_check
from tools.analysis.jaxpr_checks import carry_findings_for, purity_findings_for
from tools.analysis.kernels_check import block_spec_findings
from tools.analysis.wire_dtype import wire_findings


def _checks(findings):
    return sorted({f.check for f in findings})


# ---------------------------------------------------------------- purity

def test_planted_host_callback_is_caught():
    def phase(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) * 2.0,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    args = (jax.ShapeDtypeStruct((8,), jnp.float32),)
    findings = purity_findings_for(phase, args, "planted")
    assert _checks(findings) == ["purity"]
    assert any("callback" in f.message for f in findings)
    # and only purity: the carry of the identity-shaped phase is stable
    assert not carry_findings_for(lambda x: (x,), args[0], (), "planted")


def test_planted_constant_prng_seed_is_caught():
    def phase(x):
        noise = jax.random.normal(jax.random.key(0), x.shape)
        return x + noise

    findings = purity_findings_for(
        phase, (jax.ShapeDtypeStruct((8,), jnp.float32),), "planted")
    assert _checks(findings) == ["purity"]
    assert any("constant" in f.message.lower() or "literal" in
               f.message.lower() or "seed" in f.message.lower()
               for f in findings)


def test_clean_phase_has_no_purity_findings():
    def phase(x, key):
        return x + jax.random.normal(key, x.shape)

    findings = purity_findings_for(
        phase,
        (jax.ShapeDtypeStruct((8,), jnp.float32),
         jax.eval_shape(lambda: jax.random.key(3))),
        "clean")
    assert findings == []


# ------------------------------------------------------------ wire dtype

def _gather_harness(body):
    """Trace body(x) under a 1-axis abstract mesh, x one bf16 shard."""
    mesh = AbstractMesh((("peers", 8),))
    fn = shard_map(body, mesh=mesh, in_specs=(P("peers"),),
                   out_specs=P(), check_rep=False)
    return jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((64,), jnp.bfloat16))


def test_planted_unpinned_upcast_is_caught():
    def leaky(x):
        full = jax.lax.all_gather(x, "peers", tiled=True)
        return full.astype(jnp.float32).sum()  # upcast free to hoist

    findings = wire_findings(_gather_harness(leaky), "planted",
                             wire_dtype=jnp.bfloat16)
    assert _checks(findings) == ["wire_dtype"]
    assert any("barrier" in f.message for f in findings)


def test_planted_widened_collective_operand_is_caught():
    def leaky(x):
        return jax.lax.all_gather(  # ships f32: 2x the declared wire
            x.astype(jnp.float32), "peers", tiled=True).sum()

    findings = wire_findings(_gather_harness(leaky), "planted",
                             wire_dtype=jnp.bfloat16)
    assert "wire_dtype" in _checks(findings)


def test_barrier_pinned_upcast_is_clean():
    def pinned(x):
        full = jax.lax.all_gather(x, "peers", tiled=True)
        full = jax.lax.optimization_barrier(full)
        return full.astype(jnp.float32).sum()

    assert wire_findings(_gather_harness(pinned), "clean",
                         wire_dtype=jnp.bfloat16) == []


# ----------------------------------------------------------- scan carry

class _ToyState(typing.NamedTuple):
    step: jax.Array
    acc: jax.Array


_TOY = _ToyState(
    step=jax.ShapeDtypeStruct((), jnp.int32),
    acc=jax.ShapeDtypeStruct((4,), jnp.float32),
)


def test_planted_carry_dtype_drift_is_caught():
    def step(s):
        # acc silently promoted to f64-less world's widest: bf16 -> f32
        # drift planted the other way round: f32 -> bf16
        return _ToyState(s.step + 1, s.acc.astype(jnp.bfloat16)),

    findings = carry_findings_for(step, _TOY, (), "planted")
    assert _checks(findings) == ["carry_stability"]
    assert any("acc" in f.message for f in findings)


def test_planted_carry_treedef_drift_is_caught():
    def step(s):
        return (s.step + 1, s.acc, s.acc),  # extra leaf: treedef drift

    findings = carry_findings_for(step, _TOY, (), "planted")
    assert _checks(findings) == ["carry_stability"]
    assert any("treedef" in f.message for f in findings)


def test_stable_carry_is_clean():
    def step(s):
        return _ToyState(s.step + 1, s.acc * 2.0),

    assert carry_findings_for(step, _TOY, (), "clean") == []


# ------------------------------------------------------ capability flags

def test_planted_noncoordinatewise_flag_is_caught():
    from repro.core import aggregators as agg_mod

    def make(n, d, use_pallas=False):
        def fn(xs, weights, v0, key):
            # global-norm coupling: slices do NOT concat bitwise
            return xs.mean(0) / (1.0 + jnp.linalg.norm(xs)), None

        return fn

    name = "lint_probe_global_norm"
    agg_mod.REGISTRY[name] = agg_mod.AggregatorDef(
        name=name, make=make, defaults=(), coordinatewise=True)
    try:
        from tools.analysis.contracts import check_coordinatewise

        res = check_coordinatewise()
        mine = [f for f in res.findings if f.where == name]
        assert mine and _checks(mine) == ["coordinatewise"]
        assert [f for f in res.findings if f.where != name] == []
    finally:
        del agg_mod.REGISTRY[name]


# ------------------------------------------------------------ kernels

def test_planted_unmapped_kernel_is_caught(monkeypatch):
    from repro.kernels import centered_clip as _k

    monkeypatch.setattr(
        _k, "lint_probe_orphan_pallas", lambda *a: None, raising=False)
    findings = kernels_check.completeness_findings()
    mine = [f for f in findings if f.where == "lint_probe_orphan_pallas"]
    assert mine and _checks(mine) == ["pallas_completeness"]
    assert any("KERNEL_MANIFEST" in f.message for f in mine)
    assert [f for f in findings if f.where != "lint_probe_orphan_pallas"] == []


def test_planted_illegal_block_specs_are_caught():
    def bad_kernel(s_ref, x_ref, o_ref):
        o_ref[...] = x_ref[...] * s_ref[0, 0]

    def call(scale, x):
        return pl.pallas_call(
            bad_kernel,
            grid=(2,),
            in_specs=[
                pl.BlockSpec((1, 1), lambda b: (0, 0)),     # VMEM scalar
                pl.BlockSpec((8, 64), lambda b: (0, b)),    # lane 64
            ],
            out_specs=pl.BlockSpec((8, 64), lambda b: (0, b)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True,
        )(scale, x)

    closed = jax.make_jaxpr(call)(
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((8, 128), jnp.float32),
    )
    findings = block_spec_findings(closed, "planted")
    assert _checks(findings) == ["pallas_block_specs"]
    msgs = " | ".join(f.message for f in findings)
    assert "SMEM" in msgs         # the (1, 1) VMEM scalar
    assert "lane dim 64" in msgs  # the 64-wide lane tiles


# ------------------------------------------------------------- plumbing

def test_cli_registry_is_complete():
    from tools.analysis import check_names

    assert set(check_names()) == {
        "engine_purity", "engine_carry", "wire_dtype",
        "registry_roundtrip", "capability_flags", "coordinatewise",
        "pallas_completeness", "pallas_block_specs",
    }


def test_checkresult_report_shape():
    res = common.CheckResult("probe")
    res.findings.append(common.Finding("probe", "here", "msg"))
    d = res.to_dict()
    assert d["status"] == "fail" and d["findings"][0]["where"] == "here"
    assert not res.ok
