"""Property tests for the fused one-pass-per-iteration ButterflyClip kernel:
the incremental-norm recurrence + verification epilogue must agree with BOTH
kernels/ref.py (expanded recurrence) and the pure-jnp centered_clip +
verification_tables path, over ragged shapes, tau extremes and banned peers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import butterfly as bf
from repro.core.centered_clip import centered_clip
from repro.kernels.ops import (
    butterfly_clip_fused_op,
    centered_clip_fused_op,
    verify_tables_all_op,
)
from repro.kernels.ref import (
    centered_clip_fused_ref,
    centered_clip_ref,
    verify_tables_ref,
)

TAUS = [0.1, 1.0, np.inf]
# d both lane/block-aligned and ragged — padding must be exact
SHAPES = [(4, 128), (8, 512), (16, 1000), (32, 2048), (5, 130), (9, 1025)]


def _mask(n, banned):
    return jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0) if banned else None


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("tau", TAUS)
@pytest.mark.parametrize("banned", [False, True])
def test_fused_matches_ref_and_jnp(shape, tau, banned):
    n, d = shape
    xs = jax.random.normal(jax.random.key(n * d + 1), (n, d)) * 2 + 0.25
    z = jax.random.normal(jax.random.key(3), (d,))
    z = z / jnp.linalg.norm(z)
    w = _mask(n, banned)
    n_iters = 12
    taus = jnp.full((n_iters,), tau, jnp.float32)

    agg, s, norms = centered_clip_fused_op(xs, tau, z, w, n_iters=n_iters)

    # oracle 1: the expanded incremental-norm recurrence
    v_r, s_r, n_r = centered_clip_fused_ref(xs, taus, z, weights=w)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(v_r), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(norms), np.asarray(n_r), atol=1e-5, rtol=1e-5)

    # oracle 2: the plain jnp two-phase path (direct norms every iteration)
    v_j = centered_clip(xs, tau, n_iters=n_iters, weights=w)
    s_j, n_j = verify_tables_ref(xs, v_j, z, tau)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(v_j), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_j), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(norms), np.asarray(n_j), atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 32),
    d=st.integers(2, 2100),
    tau=st.sampled_from([0.1, 0.7, 1.0, 4.0, float("inf")]),
    iters=st.integers(1, 25),
    banned=st.booleans(),
    seed=st.integers(0, 99999),
)
def test_property_fused_recurrence(n, d, tau, iters, banned, seed):
    xs = jax.random.normal(jax.random.key(seed), (n, d)) * 2
    z = jax.random.normal(jax.random.key(seed + 1), (d,))
    z = z / jnp.maximum(jnp.linalg.norm(z), 1e-30)
    w = _mask(n, banned)
    agg, s, norms = centered_clip_fused_op(xs, tau, z, w, n_iters=iters)
    v_r, s_r, n_r = centered_clip_fused_ref(
        xs, jnp.full((iters,), tau, jnp.float32), z, weights=w
    )
    np.testing.assert_allclose(np.asarray(agg), np.asarray(v_r), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(norms), np.asarray(n_r), atol=1e-5, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 16),
    d=st.integers(2, 1500),
    blk=st.sampled_from([128, 256, 512, 1024]),
    seed=st.integers(0, 99999),
)
def test_property_fused_block_size_invariance(n, d, blk, seed):
    """Output must not depend on the VMEM block geometry (padding exactness +
    per-block accumulation order)."""
    xs = jax.random.normal(jax.random.key(seed), (n, d))
    z = jax.random.normal(jax.random.key(seed + 7), (d,))
    z = z / jnp.maximum(jnp.linalg.norm(z), 1e-30)
    a = centered_clip_fused_op(xs, 1.0, z, n_iters=8, block=blk)
    b = centered_clip_fused_op(xs, 1.0, z, n_iters=8, block=2048)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 8, 300), (4, 16, 1025), (6, 6, 128)])
@pytest.mark.parametrize("tau", [0.5, np.inf])
def test_batched_fused_matches_per_partition(shape, tau):
    """The all-partition fused kernel == per-partition fused op == jnp."""
    n_parts, n, d = shape
    parts = jax.random.normal(jax.random.key(n_parts * d), (n_parts, n, d)) * 2
    z = jax.random.normal(jax.random.key(5), (n_parts, d))
    z = z / jnp.linalg.norm(z, axis=1, keepdims=True)
    w = jnp.where(jnp.arange(n) % 4 == 0, 0.0, 1.0)
    agg, s, norms = butterfly_clip_fused_op(parts, tau, z, w, n_iters=10)
    assert s.shape == (n, n_parts) and norms.shape == (n, n_parts)
    taus = jnp.full((10,), tau, jnp.float32)
    for j in range(n_parts):
        v_j = centered_clip_ref(parts[j], taus, w)
        s_j, n_j = verify_tables_ref(parts[j], v_j, z[j], tau)
        np.testing.assert_allclose(np.asarray(agg[j]), np.asarray(v_j), atol=1e-5)
        np.testing.assert_allclose(np.asarray(s[:, j]), np.asarray(s_j), atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(norms[:, j]), np.asarray(n_j), atol=1e-5)


def test_verify_tables_all_op_matches_jnp():
    n, d = 8, 515
    g = jax.random.normal(jax.random.key(2), (n, d))
    agg, parts = bf.butterfly_clip(g, tau=1.0, n_iters=30)
    z = bf.get_random_directions(7, n, parts.shape[-1])
    s_j, n_j = bf.verification_tables(parts, agg, z, 1.0)
    s_k, n_k = bf.verification_tables(parts, agg, z, 1.0, use_pallas=True)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_j), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(n_k), np.asarray(n_j), atol=1e-5, rtol=1e-4)


def test_butterfly_clip_verified_pallas_equals_jnp():
    n, d = 8, 700
    g = jax.random.normal(jax.random.key(11), (n, d))
    z = bf.get_random_directions(3, n, bf.pad_to_parts(d, n) // n)
    a_j, p_j, s_j, n_j = bf.butterfly_clip_verified(g, 1.0, z, n_iters=20)
    a_k, p_k, s_k, n_k = bf.butterfly_clip_verified(
        g, 1.0, z, n_iters=20, use_pallas=True
    )
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_j), atol=1e-5)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_j), atol=0)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_j), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(n_k), np.asarray(n_j), atol=1e-5, rtol=1e-4)


def test_protocol_fused_path_matches_two_call_path():
    """BTARDProtocol(use_pallas=True) must walk the same trajectory and ban
    the same peers as the two-jitted-call path."""
    from repro.core.protocol import AttackConfig, BTARDProtocol

    D = 48
    w_true = np.asarray(jax.random.normal(jax.random.key(9), (D,)))

    def grad_fn(peer, step, params, flipped=False):
        k = jax.random.key((peer * 7919 + step) % 2**31)
        X = jax.random.normal(k, (4, D))
        y = X @ w_true
        if flipped:
            y = -y
        return np.asarray(2 * X.T @ (X @ np.asarray(params) - y) / 4, np.float32)

    def run(use_pallas):
        proto = BTARDProtocol(
            8, D, grad_fn, byzantine={6, 7},
            attack=AttackConfig(kind="sign_flip", start_step=2),
            tau=1.0, clip_iters=12, m_validators=2, seed=0,
            use_pallas=use_pallas,
        )
        params = np.zeros(D, np.float32)
        traj = []
        for t in range(8):
            g, _ = proto.step(params, t)
            params = params - 0.05 * g
            traj.append(params.copy())
        return np.stack(traj), proto.banned

    t_ref, bans_ref = run(False)
    t_fused, bans_fused = run(True)
    np.testing.assert_allclose(t_fused, t_ref, atol=1e-5)
    assert bans_fused == bans_ref


# ---------------------------------------------------------------------------
# Adaptive early-exit family
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(4, 12),
    d=st.sampled_from([128, 256, 384]),
    tau=st.floats(0.5, 30.0),
    banned=st.integers(0, 2),
)
def test_property_adaptive_step_kernel_matches_ref(n, d, tau, banned):
    """One driver iteration (interpret mode) == the expanded-recurrence
    oracle, for random shapes/taus/ban masks and a non-trivial carried v
    (d block-multiple — the while driver pads before invoking the step)."""
    from repro.kernels import centered_clip as _k
    from repro.kernels.ref import adaptive_step_ref

    parts = jax.random.normal(jax.random.key(n * 31 + d), (3, n, d))
    w = jnp.ones((n,)).at[:banned].set(0.0)
    v = 0.3 * jax.random.normal(jax.random.key(d), (3, 1, d))
    sq = jnp.sum((parts - v) ** 2, axis=-1, keepdims=True)
    vn, sqn = _k.adaptive_clip_step_pallas(parts, v, sq, tau, w, block=128)
    vr, sqr = jax.vmap(
        lambda x, vv, ss: adaptive_step_ref(x, vv, ss, tau, w)
    )(parts, v[:, 0], sq[:, :, 0])
    np.testing.assert_allclose(np.asarray(vn[:, 0]), np.asarray(vr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sqn[:, :, 0]), np.asarray(sqr),
                               rtol=1e-3, atol=1e-3)


def test_adaptive_op_tol_zero_equals_fixed_kernel():
    """tol=0 (cap binding) reproduces the FUSED fixed-budget kernel's
    aggregate bitwise (both carry the incremental-norm recurrence; the
    legacy two-phase kernel recomputes norms and differs at the ulp level),
    and the fused adaptive op's epilogue tables equal the standalone batched
    table kernel on the same iterate."""
    from repro.kernels.ops import (
        butterfly_clip_adaptive_op,
        butterfly_clip_fused_adaptive_op,
        butterfly_clip_fused_op,
        verify_tables_all_op,
    )

    parts = jax.random.normal(jax.random.key(21), (4, 8, 384))
    z = jax.random.normal(jax.random.key(22), (4, 384))
    w = jnp.ones((8,)).at[5].set(0.0)
    agg_fixed, _, _ = butterfly_clip_fused_op(parts, 1.0, z, w, n_iters=12)
    agg_adapt, iters = butterfly_clip_adaptive_op(
        parts, 1.0, 0.0, w, max_iters=12
    )
    np.testing.assert_array_equal(np.asarray(agg_adapt), np.asarray(agg_fixed))
    assert np.all(np.asarray(iters) == 12)

    agg2, s2, n2, _ = butterfly_clip_fused_adaptive_op(
        parts, 1.0, z, 0.0, w, max_iters=12
    )
    s_ref, n_ref = verify_tables_all_op(parts, agg2, z, 1.0)
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(n2), np.asarray(n_ref))
