"""BTARD protocol state-machine tests (paper Alg. 4-7 + App. C attack zoo)."""
import jax
import numpy as np
import pytest

from repro.core.protocol import AttackConfig, BTARDProtocol

D = 48


def _grad_fn_factory():
    w_true = np.asarray(jax.random.normal(jax.random.key(9), (D,)))

    def grad_fn(peer, step, params, flipped=False):
        k = jax.random.key((peer * 7919 + step) % 2**31)
        X = jax.random.normal(k, (4, D))
        y = X @ w_true
        if flipped:
            y = -y
        g = 2 * X.T @ (X @ np.asarray(params) - np.asarray(y)) / 4
        return np.asarray(g, np.float32)

    return grad_fn


def _protocol(attack, byz=(5, 6, 7), m=2, **kw):
    return BTARDProtocol(
        n_peers=8,
        d=D,
        grad_fn=_grad_fn_factory(),
        byzantine=set(byz),
        attack=attack,
        tau=1.0,
        m_validators=m,
        seed=0,
        **kw,
    )


def _run(proto, steps=25):
    params = np.zeros(D, np.float32)
    for t in range(steps):
        g, info = proto.step(params, t)
        params = params - 0.05 * g
        if proto.byzantine <= proto.banned:
            break
    return params, proto


@pytest.mark.parametrize(
    "kind", ["sign_flip", "random_direction", "ipm_06", "alie", "label_flip"]
)
def test_attackers_banned_and_no_honest_casualties(kind):
    proto = _protocol(AttackConfig(kind=kind, start_step=2))
    _, proto = _run(proto, steps=40)
    assert proto.byzantine <= proto.banned, (kind, proto.banned)
    honest_banned = proto.banned - proto.byzantine
    assert not honest_banned, (kind, honest_banned)


def test_no_attack_no_bans():
    proto = _protocol(AttackConfig(kind="none"))
    _, proto = _run(proto, steps=10)
    assert proto.banned == set()


def test_false_accusation_bans_the_accuser():
    """Byzantine validators slandering honest peers get banned themselves
    (the Hammurabi rule, Alg. 3)."""
    proto = BTARDProtocol(
        n_peers=8, d=D, grad_fn=_grad_fn_factory(), byzantine={6, 7},
        attack=AttackConfig(kind="none", start_step=0, false_accuse=True),
        tau=1.0, m_validators=3, seed=1,
    )
    params = np.zeros(D, np.float32)
    banned_reasons = []
    for t in range(30):
        g, info = proto.step(params, t)
        banned_reasons += info.banned_now
        if {6, 7} <= proto.banned:
            break
    # eventually the slandering validators ban themselves; honest all alive
    assert proto.banned <= {6, 7}
    assert not any(p not in {6, 7} for p, _ in banned_reasons)


def test_aggregator_attack_detected_via_checksum():
    proto = _protocol(
        AttackConfig(
            kind="none",
            start_step=1,
            aggregator_attack=True,
            aggregator_scale=0.5,
            misreport_s=False,
        ),
        byz=(6, 7),
    )
    params = np.zeros(D, np.float32)
    total_violations = 0
    for t in range(12):
        g, info = proto.step(params, t)
        total_violations += info.checksum_violations
        if {6, 7} <= proto.banned:
            break
    assert total_violations > 0
    assert {6, 7} <= proto.banned


def test_misreported_s_caught_by_validators():
    """Colluders cancel the checksum; validators recompute s and ban both the
    liar and the corrupt aggregator (App. D.5)."""
    proto = _protocol(
        AttackConfig(
            kind="none", start_step=0,
            aggregator_attack=True, aggregator_scale=0.3, misreport_s=True,
        ),
        byz=(6, 7), m=3,
    )
    params = np.zeros(D, np.float32)
    for t in range(40):
        g, info = proto.step(params, t)
        if {6, 7} <= proto.banned:
            break
    assert {6, 7} <= proto.banned
    assert not (proto.banned - {6, 7})


def test_training_converges_with_byzantines_banned():
    proto = _protocol(AttackConfig(kind="sign_flip", start_step=3))
    params = np.zeros(D, np.float32)
    for t in range(60):
        g, _ = proto.step(params, t)
        params = params - 0.05 * g
    # after bans, SGD should reach near the optimum
    final_grad = _grad_fn_factory()(0, 10**6, params)
    assert np.linalg.norm(params) > 1.0  # moved away from init
    assert proto.byzantine <= proto.banned
