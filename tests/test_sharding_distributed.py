"""Distributed-step tests: run in a SUBPROCESS with 8 host devices so the
session's device count stays 1 for every other test."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-W", "ignore", "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=560,
    )
    assert r.returncode == 0, r.stdout[-3000:] + "\n---\n" + r.stderr[-3000:]
    return r.stdout


def test_btard_step_equals_baseline_when_honest():
    out = _run(
        """
        import jax, jax.numpy as jnp
        from repro.launch.steps import make_baseline_train_step, make_btard_train_step
        from repro.models import get_model
        from repro.optim import sgd
        from repro.configs.base import InputShape

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        m = get_model('qwen3-1.7b', reduced=True)
        shape = InputShape('t', 64, 8, 'train')
        opt = sgd(0.05)
        params = m.init_params(jax.random.key(0)); st = opt.init(params)
        toks = jax.random.randint(jax.random.key(1), (8, 65), 0, m.cfg.vocab_size)
        bl, _ = make_baseline_train_step(m, opt, mesh, shape)
        bt, _ = make_btard_train_step(m, opt, mesh, shape, tau=1e9, clip_iters=3)
        p1, _, _ = bl(params, st, {'tokens': toks}, jnp.int32(0))
        byz = jnp.zeros((4,), jnp.float32); w = jnp.ones((4,), jnp.float32)
        p2, _, met, _ = bt(params, st, {'tokens': toks}, jnp.int32(0), jnp.int32(7), byz, w)
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        m = max(jax.tree.leaves(diffs))
        assert m < 5e-3, m
        print('EQUIV OK', m)
        """
    )
    assert "EQUIV OK" in out


def test_device_attack_detected_and_clipped():
    out = _run(
        """
        import jax, jax.numpy as jnp
        from repro.launch.steps import make_btard_train_step
        from repro.models import get_model
        from repro.optim import sgd
        from repro.configs.base import InputShape

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        m = get_model('qwen3-1.7b', reduced=True)
        shape = InputShape('t', 64, 8, 'train')
        opt = sgd(0.05)
        params = m.init_params(jax.random.key(0)); st = opt.init(params)
        toks = jax.random.randint(jax.random.key(1), (8, 65), 0, m.cfg.vocab_size)
        bt, _ = make_btard_train_step(m, opt, mesh, shape, tau=0.05, clip_iters=30,
                                      attack='sign_flip', delta_max=0.2)
        byz = jnp.asarray([0., 0., 0., 1.]); w = jnp.ones((4,), jnp.float32)
        p2, _, met, verif = bt(params, st, {'tokens': toks}, jnp.int32(0), jnp.int32(7), byz, w)
        # honest-majority aggregate stays bounded despite a -100x attacker
        import numpy as np
        norms = np.asarray(verif['norm_table'])
        assert np.isfinite(norms).all()
        # the attacked peer's residual norm dominates every partition
        assert (norms[:, 3] >= norms[:, :3].max(1) - 1e-6).mean() > 0.9
        # and banning it via weights restores the checksum
        w2 = jnp.asarray([1., 1., 1., 0.])
        p3, _, met3, verif3 = bt(params, st, {'tokens': toks}, jnp.int32(0), jnp.int32(7), byz, w2)
        assert float(met3['checksum_max']) < 1e-3
        print('ATTACK OK')
        """
    )
    assert "ATTACK OK" in out


def test_multi_pod_mesh_axes():
    out = _run(
        """
        import jax, jax.numpy as jnp
        from repro.launch.steps import make_btard_train_step
        from repro.models import get_model
        from repro.optim import sgd
        from repro.configs.base import InputShape

        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        m = get_model('qwen3-1.7b', reduced=True)
        shape = InputShape('t', 64, 8, 'train')
        opt = sgd(0.05)
        bt, bargs = make_btard_train_step(m, opt, mesh, shape, tau=2.0, clip_iters=5)
        bt.lower(*bargs).compile()
        params = m.init_params(jax.random.key(0)); st = opt.init(params)
        toks = jax.random.randint(jax.random.key(1), (8, 65), 0, m.cfg.vocab_size)
        byz = jnp.zeros((4,), jnp.float32); w = jnp.ones((4,), jnp.float32)
        p, _, met, _ = bt(params, st, {'tokens': toks}, jnp.int32(0), jnp.int32(3), byz, w)
        assert float(met['checksum_max']) < 1e-3
        print('MULTIPOD OK', float(met['loss']))
        """
    )
    assert "MULTIPOD OK" in out


def test_scan_step_equals_stepwise_and_warm_start_runs():
    out = _run(
        """
        import jax, jax.numpy as jnp
        from repro.launch.steps import make_btard_scan_train_step, make_btard_train_step
        from repro.models import get_model
        from repro.optim import sgd
        from repro.configs.base import InputShape

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        m = get_model('qwen3-1.7b', reduced=True)
        shape = InputShape('t', 64, 8, 'train')
        opt = sgd(0.05)
        params = m.init_params(jax.random.key(0)); st = opt.init(params)
        N = 3
        toks = [jax.random.randint(jax.random.key(i), (8, 65), 0, m.cfg.vocab_size)
                for i in range(N)]
        byz = jnp.zeros((4,), jnp.float32); w = jnp.ones((4,), jnp.float32)

        one, _ = make_btard_train_step(m, opt, mesh, shape, tau=2.0, clip_iters=5)
        p1, s1 = params, st
        for i in range(N):
            p1, s1, met, _ = one(p1, s1, {'tokens': toks[i]}, jnp.int32(i),
                                 jnp.int32(i * 7919 + 13), byz, w)

        scan, _ = make_btard_scan_train_step(m, opt, mesh, shape, n_scan_steps=N,
                                             tau=2.0, clip_iters=5)
        batches = {'tokens': jnp.stack(toks)}
        steps = jnp.arange(N, dtype=jnp.int32)
        seeds = steps * 7919 + 13
        v0 = jax.tree.map(jnp.zeros_like, params)
        p2, s2, mets, verifs, v_last = scan(params, st, batches, steps, seeds, byz, w, v0)
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        mx = max(jax.tree.leaves(diffs))
        assert mx < 5e-3, mx
        assert mets['loss'].shape == (N,)

        # warm start: runs end-to-end and stays checksum-clean when honest
        warm, _ = make_btard_scan_train_step(m, opt, mesh, shape, n_scan_steps=N,
                                             tau=2.0, clip_iters=5, warm_start=True)
        p3, s3, mets3, _, _ = warm(params, st, batches, steps, seeds, byz, w, v0)
        assert float(mets3['checksum_max'].max()) < 1e-3
        print('SCAN EQUIV OK', mx)
        """
    )
    assert "SCAN EQUIV OK" in out


def test_pallas_kernel_inside_distributed_step():
    out = _run(
        """
        import jax, jax.numpy as jnp
        from repro.launch.steps import make_btard_train_step
        from repro.models import get_model
        from repro.optim import sgd
        from repro.configs.base import InputShape

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        m = get_model('qwen3-1.7b', reduced=True)
        shape = InputShape('t', 64, 8, 'train')
        opt = sgd(0.05)
        params = m.init_params(jax.random.key(0)); st = opt.init(params)
        toks = jax.random.randint(jax.random.key(1), (8, 65), 0, m.cfg.vocab_size)
        byz = jnp.zeros((4,), jnp.float32); w = jnp.ones((4,), jnp.float32)
        ref, _ = make_btard_train_step(m, opt, mesh, shape, tau=2.0, clip_iters=6)
        ker, _ = make_btard_train_step(m, opt, mesh, shape, tau=2.0, clip_iters=6, use_pallas=True)
        p1, _, _, _ = ref(params, st, {'tokens': toks}, jnp.int32(0), jnp.int32(7), byz, w)
        p2, _, _, _ = ker(params, st, {'tokens': toks}, jnp.int32(0), jnp.int32(7), byz, w)
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        mx = max(jax.tree.leaves(diffs))
        assert mx < 5e-3, mx
        print('PALLAS DIST OK', mx)
        """
    )
    assert "PALLAS DIST OK" in out
