"""Regression tests for the flat-gradient bug fixes riding the real-model
gauntlet (ISSUE 10 satellites):

* chunked cross-entropy: every chunk (ragged tail included) must stay within
  the LOSS_CHUNK memory bound, and the chunked loss must equal the unchunked
  reference for seq_len % LOSS_CHUNK != 0. Pre-fix, floor-division chunking
  let a chunk grow to 2*LOSS_CHUNK-1 tokens — S=4095 with LOSS_CHUNK=2048
  materialized the FULL (B, S, V) f32 logits the chunking exists to avoid.
* optimizer mixed-precision state: moments are f32 even for bf16 params,
  weight decay and updates skip non-float leaves (pre-fix, an int32 counter
  leaf was decayed toward zero), and the f32 update math for bf16 params is
  bitwise identical to an all-f32 reference run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.model as mm
from repro.configs import get_config, reduce_config
from repro.models.model import Model
from repro.optim import adam, lamb, sgd
from repro.optim.optimizers import apply_updates

ARCH = "qwen3-1.7b"


@pytest.fixture
def small_chunk(monkeypatch):
    """Shrink LOSS_CHUNK so ragged-tail behavior is exercised at S=31."""
    monkeypatch.setattr(mm, "LOSS_CHUNK", 16)


def _model_and_params():
    cfg = reduce_config(get_config(ARCH))
    m = Model(cfg)
    return m, m.init_params(jax.random.key(0))


def _reference_loss(m, params, toks):
    """Unchunked cross-entropy over the full (B, S, V) logits."""
    import repro.models.transformer as tfm
    from repro.models.layers import apply_norm, embed_tokens, logits_out

    cfg = m.cfg
    inputs, targets = toks[:, :-1], toks[:, 1:]
    B, S = inputs.shape
    pos = jnp.arange(S)
    x = embed_tokens(params, cfg, inputs, pos=pos if cfg.learned_pos else None)
    x, _, _ = tfm.stack_apply(
        params, cfg, x, pos=pos, memory=None, cache=None, mode="train"
    )
    x = apply_norm(params["final_norm"], cfg, x)
    emb = {k: params[k] for k in ("embed", "lm_head") if k in params}
    logits = logits_out(emb, cfg, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (lse - tgt).sum() / (B * S)


@pytest.mark.parametrize("seq", [31, 33, 47])
def test_chunk_width_never_exceeds_bound(small_chunk, monkeypatch, seq):
    """No logits chunk may be wider than LOSS_CHUNK — the memory contract
    the chunking documents. Fails pre-fix: floor division gave S=31 a single
    31-wide chunk (and S=4095 the full logits matrix at the real bound)."""
    m, params = _model_and_params()
    widths = []
    orig = mm.logits_out

    def spy(emb_params, cfg, x_sl):
        widths.append(x_sl.shape[1])
        return orig(emb_params, cfg, x_sl)

    monkeypatch.setattr(mm, "logits_out", spy)
    toks = jax.random.randint(jax.random.key(seq), (2, seq + 1), 0, m.cfg.vocab_size)
    loss, _ = m.loss_fn(params, {"tokens": toks})
    assert bool(jnp.isfinite(loss))
    assert widths and max(widths) <= mm.LOSS_CHUNK, (seq, widths)


@pytest.mark.parametrize("seq", [15, 17, 31, 48])
def test_ragged_seq_chunked_loss_matches_unchunked(small_chunk, seq):
    """Chunked loss == unchunked reference for seq_len % LOSS_CHUNK != 0
    (no token dropped, normalization exact)."""
    m, params = _model_and_params()
    toks = jax.random.randint(jax.random.key(seq), (2, seq + 1), 0, m.cfg.vocab_size)
    loss, _ = m.loss_fn(params, {"tokens": toks})
    ref = _reference_loss(m, params, toks)
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------- optimizers

OPTS = [
    ("sgd", lambda: sgd(0.1, momentum=0.9, weight_decay=0.01)),
    ("adam", lambda: adam(0.1, weight_decay=0.01)),
    ("lamb", lambda: lamb(0.1, weight_decay=0.01)),
]


@pytest.mark.parametrize("name,mk", OPTS)
def test_moments_are_f32_for_bf16_params(name, mk):
    params = {"w": jnp.ones((4, 4), jnp.bfloat16), "b": jnp.zeros((4,), jnp.float32)}
    state = mk().init(params)
    for leaf in jax.tree.leaves(state):
        assert leaf.dtype == jnp.float32, (name, leaf.dtype)


@pytest.mark.parametrize("name,mk", OPTS)
def test_weight_decay_skips_integer_leaves(name, mk):
    """An int32 counter leaf must survive optimizer steps bitwise. Fails
    pre-fix: weight decay decayed it (100 -> 97 for sgd/adam in 3 steps) and
    apply_updates round-tripped it through f32 (lossy above 2**24)."""
    params = {
        "w": jnp.ones((3,), jnp.bfloat16),
        "count": jnp.array(100, jnp.int32),
        "big": jnp.array(2**24 + 1, jnp.int32),  # not representable in f32
    }
    grads = jax.tree.map(jnp.zeros_like, params)
    grads["w"] = jnp.full((3,), 0.5, jnp.bfloat16)
    opt = mk()
    st = opt.init(params)
    p = params
    for step in range(3):
        ups, st = opt.update(grads, st, p, step)
        p = apply_updates(p, ups)
    assert int(p["count"]) == 100, (name, int(p["count"]))
    assert int(p["big"]) == 2**24 + 1, (name, int(p["big"]))
    assert p["count"].dtype == jnp.int32
    # float leaves still train
    assert float(p["w"][0]) != 1.0


@pytest.mark.parametrize("name,mk", OPTS)
def test_bf16_update_bitwise_matches_f32_reference(name, mk):
    """The f32 update computed for bf16 params must be bitwise identical to
    an all-f32 run fed the same values: mixed precision changes storage, not
    optimizer math."""
    w0 = (
        jax.random.normal(jax.random.key(0), (16,), jnp.float32)
        .astype(jnp.bfloat16)
        .astype(jnp.float32)
    )
    g0 = (
        jax.random.normal(jax.random.key(1), (16,), jnp.float32)
        .astype(jnp.bfloat16)
        .astype(jnp.float32)
    )
    opt_b, opt_f = mk(), mk()
    pb = {"w": w0.astype(jnp.bfloat16)}
    pf = {"w": pb["w"].astype(jnp.float32)}  # same VALUES, f32 storage
    sb, sf = opt_b.init(pb), opt_f.init(pf)
    for step in range(4):
        ub, sb = opt_b.update({"w": g0}, sb, pb, step)
        uf, sf = opt_f.update({"w": g0}, sf, pf, step)
        assert ub["w"].dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(ub["w"]), np.asarray(uf["w"]))
        for mb, mf in zip(jax.tree.leaves(sb), jax.tree.leaves(sf)):
            np.testing.assert_array_equal(np.asarray(mb), np.asarray(mf))
        pb = apply_updates(pb, ub)
        pf = {"w": pb["w"].astype(jnp.float32)}
