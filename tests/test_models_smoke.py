"""Per-architecture smoke tests (REQUIRED deliverable): a reduced variant of
each assigned family runs one forward/train step on CPU with correct output
shapes and no NaNs, plus the prefill/decode cache-consistency check — and the
real-model gauntlet: each zoo family through one scanned BTARD section
(per-peer ``Model.loss_fn`` gradients, the core.flatten ravel boundary, full
verification on the wire) with a sign-flip Byzantine banned and no honest
peer accused."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs
from repro.models import get_model

B, S = 2, 32
ARCHS = list_archs(include_extra=True)

# one representative per zoo family for the engine-integration gauntlet:
# dense transformer, MoE, SSM (Mamba-2 SSD), RG-LRU hybrid
FAMILY_ARCHS = [
    "albert-large",
    "deepseek-v2-lite-16b",
    "mamba2-2.7b",
    "recurrentgemma-9b",
]


def _btard_run(arch, attack="sign_flip", aggregator="compressed:verified:mean",
               dtype=None, steps=4, peers=4, seq_len=16):
    """One scanned BTARD section on a reduced zoo LM; returns the trainer."""
    from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
    from repro.models.workload import lm_setup
    from repro.optim import sgd

    loss_fn, params0, batch_fn, _ = lm_setup(
        arch, seq_len=seq_len, batch_size=2, dtype=dtype
    )
    tr = BTARDTrainer(
        loss_fn, params0, batch_fn,
        TrainerConfig(
            n_peers=peers, byzantine=(peers - 1,),
            attack=AttackConfig(kind=attack, start_step=0),
            defense="btard", aggregator=aggregator,
            tau=2.0, clip_iters=5, m_validators=1,
        ),
        optimizer=sgd(0.05),
    )
    tr.run_scan(steps)
    return tr


def _assert_byzantine_banned_honest_clean(tr, peers=4):
    """The §4.1 guarantees, restated on real pytree gradients: the attacker
    is banned within 5 steps, and no honest peer is ever accused."""
    byz = {peers - 1}
    assert set(tr.banned) == byz, f"banned {sorted(tr.banned)} != {sorted(byz)}"
    ban_step = min(
        rec["step"] for rec in tr.history if rec["banned_now"]
    )
    assert ban_step <= 5, f"ban landed at step {ban_step} > 5"
    for rec in tr.history:
        assert jnp.isfinite(rec["grad_norm"]), rec["step"]
        honest_accused = set(rec.get("accused_peers", [])) - byz
        assert not honest_accused, (
            f"step {rec['step']}: honest peers accused {sorted(honest_accused)}"
        )


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_scanned_btard_step_per_family(arch):
    """Engine integration per family: finite loss trajectory, the Byzantine
    peer banned, zero honest accusations, and a bitwise ravel/unravel
    round-trip at the trainer's flatten boundary."""
    tr = _btard_run(arch)
    _assert_byzantine_banned_honest_clean(tr)
    # the (n, d) contract: pytree -> flat f32 -> pytree -> flat is bitwise
    flat = tr.boundary.flatten(tr.boundary.unflatten(jnp.asarray(tr.params)))
    assert jnp.array_equal(flat, jnp.asarray(tr.params)), "ravel not bitwise"


@pytest.mark.slow
@pytest.mark.parametrize("attack", ["sign_flip", "random_direction", "alie"])
@pytest.mark.parametrize("arch", ["albert-large", "mamba2-2.7b"])
def test_attack_model_grid(arch, attack):
    """Attack x model smoke grid: every cell bans the attacker fast and
    never accuses an honest peer, on real transformer/SSM gradients."""
    tr = _btard_run(arch, attack=attack)
    _assert_byzantine_banned_honest_clean(tr)


@pytest.mark.slow
def test_bf16_params_through_bf16_wire():
    """Mixed precision composes: bf16 param/activation storage + bf16 wire
    codec, f32 digests over dequantized wire values — bans stay exact and
    zero honest accusations stays structural, not a tolerance."""
    tr = _btard_run(
        "albert-large", dtype="bfloat16",
        aggregator="compressed:verified:mean:codec=bf16",
    )
    _assert_byzantine_banned_honest_clean(tr)
    # bitwise contract on the tree side: bf16 -> f32 widening is exact, so
    # tree -> flat -> tree round-trips bitwise (flat -> tree -> flat does
    # NOT for bf16 leaves — the master f32 row is quantized at the cast)
    tree = tr.boundary.unflatten(jnp.asarray(tr.params))
    tree2 = tr.boundary.unflatten(tr.boundary.flatten(tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(tree2)):
        assert a.dtype == b.dtype and jnp.array_equal(a, b)


def _batch(m, key=1):
    cfg = m.cfg
    batch = {
        "tokens": jax.random.randint(jax.random.key(key), (B, S + 1), 0, cfg.vocab_size)
    }
    if cfg.encoder_len:
        batch["memory_raw"] = (
            jax.random.normal(jax.random.key(key + 1), (B, cfg.encoder_len, cfg.encoder_dim))
            * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    m = get_model(arch, reduced=True)
    params = m.init_params(jax.random.key(0))
    batch = _batch(m)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(m.loss_fn, has_aux=True)
    )(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves), arch
    # one SGD step moves the loss
    params2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - 0.1 * g.astype(jnp.float32)).astype(p.dtype),
        params, grads,
    )
    loss2, _ = jax.jit(m.loss_fn)(params2, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_logits_shape(arch):
    m = get_model(arch, reduced=True)
    params = m.init_params(jax.random.key(0))
    batch = _batch(m)
    batch["tokens"] = batch["tokens"][:, :S]
    cache = m.init_cache(B, S)
    logits, new_cache = jax.jit(m.prefill)(params, batch, cache)
    assert logits.shape == (B, m.cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decoding token S-1 after an (S-1)-prefill must reproduce the full-S
    prefill logits — validates every cache type (KV, MLA latent, SSD state,
    RG-LRU hidden, conv buffers)."""
    m = get_model(arch, reduced=True)
    cfg = m.cfg
    params = m.init_params(jax.random.key(0))
    batch = _batch(m)
    toks = batch["tokens"][:, :S]
    batch_full = dict(batch, tokens=toks)
    logA, _ = jax.jit(m.prefill)(params, batch_full, m.init_cache(B, S + 1))
    batch_part = dict(batch, tokens=toks[:, : S - 1])
    _, cacheB = jax.jit(m.prefill)(params, batch_part, m.init_cache(B, S + 1))
    db = {"token": toks[:, S - 1], "pos": jnp.full((B,), S - 1, jnp.int32)}
    logB, _ = jax.jit(m.decode_step)(params, db, cacheB)
    rel = float(jnp.max(jnp.abs(logA - logB))) / (
        float(jnp.max(jnp.abs(logA))) + 1e-9
    )
    assert rel < 2e-2, (arch, rel)


def test_param_counts_scale_sanely():
    """Full configs should land near their nameplate sizes."""
    expect = {
        "qwen1.5-110b": (95e9, 130e9),
        "gemma3-27b": (24e9, 31e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "deepseek-v2-lite-16b": (13e9, 19e9),
        "dbrx-132b": (115e9, 145e9),
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "chatglm3-6b": (5e9, 7.5e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "whisper-small": (0.15e9, 0.4e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_model(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_below_total():
    m = get_model("deepseek-v2-lite-16b")
    assert m.active_param_count() < 0.35 * m.param_count()
