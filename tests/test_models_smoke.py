"""Per-architecture smoke tests (REQUIRED deliverable): a reduced variant of
each assigned family runs one forward/train step on CPU with correct output
shapes and no NaNs, plus the prefill/decode cache-consistency check."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs
from repro.models import get_model

B, S = 2, 32
ARCHS = list_archs(include_extra=True)


def _batch(m, key=1):
    cfg = m.cfg
    batch = {
        "tokens": jax.random.randint(jax.random.key(key), (B, S + 1), 0, cfg.vocab_size)
    }
    if cfg.encoder_len:
        batch["memory_raw"] = (
            jax.random.normal(jax.random.key(key + 1), (B, cfg.encoder_len, cfg.encoder_dim))
            * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    m = get_model(arch, reduced=True)
    params = m.init_params(jax.random.key(0))
    batch = _batch(m)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(m.loss_fn, has_aux=True)
    )(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves), arch
    # one SGD step moves the loss
    params2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - 0.1 * g.astype(jnp.float32)).astype(p.dtype),
        params, grads,
    )
    loss2, _ = jax.jit(m.loss_fn)(params2, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_logits_shape(arch):
    m = get_model(arch, reduced=True)
    params = m.init_params(jax.random.key(0))
    batch = _batch(m)
    batch["tokens"] = batch["tokens"][:, :S]
    cache = m.init_cache(B, S)
    logits, new_cache = jax.jit(m.prefill)(params, batch, cache)
    assert logits.shape == (B, m.cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decoding token S-1 after an (S-1)-prefill must reproduce the full-S
    prefill logits — validates every cache type (KV, MLA latent, SSD state,
    RG-LRU hidden, conv buffers)."""
    m = get_model(arch, reduced=True)
    cfg = m.cfg
    params = m.init_params(jax.random.key(0))
    batch = _batch(m)
    toks = batch["tokens"][:, :S]
    batch_full = dict(batch, tokens=toks)
    logA, _ = jax.jit(m.prefill)(params, batch_full, m.init_cache(B, S + 1))
    batch_part = dict(batch, tokens=toks[:, : S - 1])
    _, cacheB = jax.jit(m.prefill)(params, batch_part, m.init_cache(B, S + 1))
    db = {"token": toks[:, S - 1], "pos": jnp.full((B,), S - 1, jnp.int32)}
    logB, _ = jax.jit(m.decode_step)(params, db, cacheB)
    rel = float(jnp.max(jnp.abs(logA - logB))) / (
        float(jnp.max(jnp.abs(logA))) + 1e-9
    )
    assert rel < 2e-2, (arch, rel)


def test_param_counts_scale_sanely():
    """Full configs should land near their nameplate sizes."""
    expect = {
        "qwen1.5-110b": (95e9, 130e9),
        "gemma3-27b": (24e9, 31e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "deepseek-v2-lite-16b": (13e9, 19e9),
        "dbrx-132b": (115e9, 145e9),
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "chatglm3-6b": (5e9, 7.5e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "whisper-small": (0.15e9, 0.4e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_model(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_below_total():
    m = get_model("deepseek-v2-lite-16b")
    assert m.active_param_count() < 0.35 * m.param_count()
