"""CenteredClip unit + property tests (paper §2.2 / D.2 invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.centered_clip import (
    centered_clip,
    centered_clip_to_tol,
    clip_residuals,
    tau_schedule,
)
from repro.core.aggregators import geometric_median


def _rand(n, d, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.key(seed), (n, d))


def test_tau_inf_is_mean():
    xs = _rand(8, 32)
    v = centered_clip(xs, np.inf, n_iters=5)
    np.testing.assert_allclose(np.asarray(v), np.asarray(xs.mean(0)), atol=1e-5)


def test_weights_exclude_banned():
    xs = _rand(8, 16)
    w = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    v = centered_clip(xs, np.inf, n_iters=5, weights=w)
    np.testing.assert_allclose(np.asarray(v), np.asarray(xs[:4].mean(0)), atol=1e-5)


def test_fixed_point_residual_zero():
    """At the fixed point, sum_i Delta_i = 0 — the Verification-2 identity."""
    xs = _rand(12, 64, seed=3)
    v, iters = centered_clip_to_tol(xs, tau=1.0, eps=1e-7)
    res = clip_residuals(xs, v, 1.0)
    assert float(jnp.abs(res.sum(0)).max()) < 1e-4


def test_bounded_shift_under_attack():
    """Gradient attacks shift CenteredClip by O(tau * b / (n-b)) — paper
    App. C: 'b Byzantine peers can collectively shift the outputs ... by up
    to tau*b/n'. At the fixed point the attackers' clipped pull is b*tau,
    balanced by the (n-b) honest pulls, so |shift| <~ tau*b/(n-b) plus the
    honest spread — crucially INDEPENDENT of the 1000x attack amplitude."""
    n, b, d, tau = 16, 7, 128, 1.0
    honest = _rand(n - b, d, seed=1, scale=0.1)
    attack = 1000.0 * jnp.ones((b, d))
    xs = jnp.concatenate([honest, attack])
    v, _ = centered_clip_to_tol(xs, tau, eps=1e-7, max_iters=2000)
    shift = float(jnp.linalg.norm(v - honest.mean(0)))
    assert shift <= 2.0 * tau * b / (n - b), shift
    # and the mean would have been catastrophically wrong:
    assert float(jnp.linalg.norm(xs.mean(0) - honest.mean(0))) > 100.0


def test_small_tau_approaches_geometric_median():
    xs = jnp.concatenate([_rand(10, 8, seed=2), 50.0 + _rand(3, 8, seed=4)])
    v, _ = centered_clip_to_tol(xs, tau=0.05, eps=1e-8, max_iters=2000)
    gm = geometric_median(xs, eps=1e-8, max_iters=2000)
    # both should sit near the honest cluster, far from the outliers
    assert float(jnp.linalg.norm(v - gm)) < 2.0


def test_tau_schedule_eq5():
    taus = tau_schedule(delta=0.1, sigma=2.0, n_iters=3)
    # manual eq. (5): B0=0 -> tau0 = 4*sqrt(0.9*(4)/(sqrt(3)*0.1))
    t0 = 4 * np.sqrt(0.9 * 4.0 / (np.sqrt(3) * 0.1))
    assert abs(taus[0] - t0) < 1e-4
    b2 = 5 * 4.0 * 1 * 0 + 6.45 * 0.1 * 0 + 5 * 4.0
    t1 = 4 * np.sqrt(0.9 * (b2 / 3 + 4.0) / (np.sqrt(3) * 0.1))
    assert abs(taus[1] - t1) < 1e-3
    assert np.isinf(tau_schedule(0.0, 1.0, 2)).all()


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(3, 20),
    d=st.integers(1, 64),
    tau=st.floats(0.1, 100.0),
    seed=st.integers(0, 10_000),
)
def test_property_idempotent_on_consensus(n, d, tau, seed):
    """If all peers send the same vector, the aggregate IS that vector.
    (Convergence from v0=0 takes ~||x||/tau steps: each iteration moves by at
    most tau until the point is within the clip radius, then lands exactly.)"""
    x = jax.random.normal(jax.random.key(seed), (d,))
    xs = jnp.broadcast_to(x, (n, d))
    iters = int(float(jnp.linalg.norm(x)) / tau) + 5
    v = centered_clip(xs, tau, n_iters=iters)
    np.testing.assert_allclose(np.asarray(v), np.asarray(x), atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 16),
    d=st.integers(2, 32),
    seed=st.integers(0, 10_000),
    perm_seed=st.integers(0, 10_000),
)
def test_property_permutation_invariant(n, d, seed, perm_seed):
    xs = jax.random.normal(jax.random.key(seed), (n, d))
    perm = jax.random.permutation(jax.random.key(perm_seed), n)
    v1 = centered_clip(xs, 1.0, n_iters=30)
    v2 = centered_clip(xs[perm], 1.0, n_iters=30)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 16),
    d=st.integers(2, 32),
    seed=st.integers(0, 10_000),
)
def test_property_within_convex_hull_bound(n, d, seed):
    """Aggregate norm never exceeds the max input norm (tau=inf mean case
    and clipped case both)."""
    xs = jax.random.normal(jax.random.key(seed), (n, d)) * 3
    for tau in [0.5, 5.0, np.inf]:
        v = centered_clip(xs, tau, n_iters=30)
        assert float(jnp.linalg.norm(v)) <= float(
            jnp.linalg.norm(xs, axis=1).max()
        ) + 1e-3
