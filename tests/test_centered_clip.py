"""CenteredClip unit + property tests (paper §2.2 / D.2 invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.centered_clip import (
    centered_clip,
    centered_clip_to_tol,
    clip_residuals,
    tau_schedule,
)
from repro.core.aggregators import geometric_median


def _rand(n, d, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.key(seed), (n, d))


def test_tau_inf_is_mean():
    xs = _rand(8, 32)
    v = centered_clip(xs, np.inf, n_iters=5)
    np.testing.assert_allclose(np.asarray(v), np.asarray(xs.mean(0)), atol=1e-5)


def test_weights_exclude_banned():
    xs = _rand(8, 16)
    w = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    v = centered_clip(xs, np.inf, n_iters=5, weights=w)
    np.testing.assert_allclose(np.asarray(v), np.asarray(xs[:4].mean(0)), atol=1e-5)


def test_fixed_point_residual_zero():
    """At the fixed point, sum_i Delta_i = 0 — the Verification-2 identity."""
    xs = _rand(12, 64, seed=3)
    v, iters = centered_clip_to_tol(xs, tau=1.0, eps=1e-7)
    res = clip_residuals(xs, v, 1.0)
    assert float(jnp.abs(res.sum(0)).max()) < 1e-4


def test_bounded_shift_under_attack():
    """Gradient attacks shift CenteredClip by O(tau * b / (n-b)) — paper
    App. C: 'b Byzantine peers can collectively shift the outputs ... by up
    to tau*b/n'. At the fixed point the attackers' clipped pull is b*tau,
    balanced by the (n-b) honest pulls, so |shift| <~ tau*b/(n-b) plus the
    honest spread — crucially INDEPENDENT of the 1000x attack amplitude."""
    n, b, d, tau = 16, 7, 128, 1.0
    honest = _rand(n - b, d, seed=1, scale=0.1)
    attack = 1000.0 * jnp.ones((b, d))
    xs = jnp.concatenate([honest, attack])
    v, _ = centered_clip_to_tol(xs, tau, eps=1e-7, max_iters=2000)
    shift = float(jnp.linalg.norm(v - honest.mean(0)))
    assert shift <= 2.0 * tau * b / (n - b), shift
    # and the mean would have been catastrophically wrong:
    assert float(jnp.linalg.norm(xs.mean(0) - honest.mean(0))) > 100.0


def test_small_tau_approaches_geometric_median():
    xs = jnp.concatenate([_rand(10, 8, seed=2), 50.0 + _rand(3, 8, seed=4)])
    v, _ = centered_clip_to_tol(xs, tau=0.05, eps=1e-8, max_iters=2000)
    gm = geometric_median(xs, eps=1e-8, max_iters=2000)
    # both should sit near the honest cluster, far from the outliers
    assert float(jnp.linalg.norm(v - gm)) < 2.0


def test_tau_schedule_eq5():
    taus = tau_schedule(delta=0.1, sigma=2.0, n_iters=3)
    # manual eq. (5): B0=0 -> tau0 = 4*sqrt(0.9*(4)/(sqrt(3)*0.1))
    t0 = 4 * np.sqrt(0.9 * 4.0 / (np.sqrt(3) * 0.1))
    assert abs(taus[0] - t0) < 1e-4
    b2 = 5 * 4.0 * 1 * 0 + 6.45 * 0.1 * 0 + 5 * 4.0
    t1 = 4 * np.sqrt(0.9 * (b2 / 3 + 4.0) / (np.sqrt(3) * 0.1))
    assert abs(taus[1] - t1) < 1e-3
    assert np.isinf(tau_schedule(0.0, 1.0, 2)).all()


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(3, 20),
    d=st.integers(1, 64),
    tau=st.floats(0.1, 100.0),
    seed=st.integers(0, 10_000),
)
def test_property_idempotent_on_consensus(n, d, tau, seed):
    """If all peers send the same vector, the aggregate IS that vector.
    (Convergence from v0=0 takes ~||x||/tau steps: each iteration moves by at
    most tau until the point is within the clip radius, then lands exactly.)"""
    x = jax.random.normal(jax.random.key(seed), (d,))
    xs = jnp.broadcast_to(x, (n, d))
    iters = int(float(jnp.linalg.norm(x)) / tau) + 5
    v = centered_clip(xs, tau, n_iters=iters)
    np.testing.assert_allclose(np.asarray(v), np.asarray(x), atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 16),
    d=st.integers(2, 32),
    seed=st.integers(0, 10_000),
    perm_seed=st.integers(0, 10_000),
)
def test_property_permutation_invariant(n, d, seed, perm_seed):
    xs = jax.random.normal(jax.random.key(seed), (n, d))
    perm = jax.random.permutation(jax.random.key(perm_seed), n)
    v1 = centered_clip(xs, 1.0, n_iters=30)
    v2 = centered_clip(xs[perm], 1.0, n_iters=30)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 16),
    d=st.integers(2, 32),
    seed=st.integers(0, 10_000),
)
def test_property_within_convex_hull_bound(n, d, seed):
    """Aggregate norm never exceeds the max input norm (tau=inf mean case
    and clipped case both)."""
    xs = jax.random.normal(jax.random.key(seed), (n, d)) * 3
    for tau in [0.5, 5.0, np.inf]:
        v = centered_clip(xs, tau, n_iters=30)
        assert float(jnp.linalg.norm(v)) <= float(
            jnp.linalg.norm(xs, axis=1).max()
        ) + 1e-3


# ---------------------------------------------------------------------------
# Adaptive (while_loop) CenteredClip — the early-exit budget
# ---------------------------------------------------------------------------
def test_adaptive_tol_zero_bitwise_equals_fixed():
    """tol=0 runs the full cap through the SHARED update rule — the
    aggregate is bitwise the fixed-budget result (stacked and single)."""
    from repro.core.centered_clip import (
        centered_clip_adaptive,
        centered_clip_adaptive_stacked,
        centered_clip_stacked,
    )

    stacked = jax.random.normal(jax.random.key(5), (6, 10, 48))
    w = jnp.ones((10,)).at[4].set(0.0)
    fixed = centered_clip_stacked(stacked, 1.3, n_iters=17, weights=w)
    adapt, iters = centered_clip_adaptive_stacked(
        stacked, 1.3, 0.0, 17, weights=w
    )
    np.testing.assert_array_equal(np.asarray(adapt), np.asarray(fixed))
    assert np.all(np.asarray(iters) == 17)

    xs = _rand(9, 33, seed=7)
    v_fixed = centered_clip(xs, 0.8, n_iters=11)
    v_adapt, it = centered_clip_adaptive(xs, 0.8, 0.0, 11)
    np.testing.assert_array_equal(np.asarray(v_adapt), np.asarray(v_fixed))


def test_stacked_fixed_equals_vmap_single():
    """The shared stacked update is the SAME computation as
    vmap(centered_clip) — the fixed path's refactor is observationally
    identical."""
    from repro.core.centered_clip import centered_clip_stacked

    stacked = jax.random.normal(jax.random.key(9), (5, 8, 40))
    w = jnp.ones((8,)).at[1].set(0.0)
    vmapped = jax.vmap(
        lambda xs: centered_clip(xs, tau=1.1, n_iters=13, weights=w)
    )(stacked)
    shared = centered_clip_stacked(stacked, 1.1, n_iters=13, weights=w)
    np.testing.assert_array_equal(np.asarray(shared), np.asarray(vmapped))


def test_adaptive_early_exit_same_fixed_point():
    """With a real tolerance the loop exits early (iters << cap) and lands
    within tol of the converged fixed point; warm starting from a nearby
    aggregate cuts the count further (the compounding the engine exploits)."""
    from repro.core.centered_clip import centered_clip_adaptive

    mu = jax.random.normal(jax.random.key(1), (64,)) * 3.0
    xs = mu + _rand(12, 64, seed=2, scale=0.5)
    ref, _ = centered_clip_to_tol(xs, 5.0, eps=1e-8, max_iters=5000)
    v, iters = centered_clip_adaptive(xs, 5.0, 1e-5, 500)
    assert int(iters) < 100
    assert float(jnp.linalg.norm(v - ref)) < 1e-3
    v_w, it_w = centered_clip_adaptive(xs, 5.0, 1e-5, 500, v0=ref)
    assert int(it_w) <= int(iters)
    np.testing.assert_allclose(np.asarray(v_w), np.asarray(ref), atol=1e-3)


def test_adaptive_frozen_partitions_match_independent_runs():
    """Partitions converge at different speeds; the joint while_loop freezes
    finished ones, so per-partition results equal fully independent loops."""
    from repro.core.centered_clip import (
        centered_clip_adaptive,
        centered_clip_adaptive_stacked,
    )

    fast = jnp.broadcast_to(
        jax.random.normal(jax.random.key(3), (48,)), (10, 48)
    ) + 0.01 * _rand(10, 48, seed=4)
    slow = _rand(10, 48, seed=5, scale=10.0)
    stacked = jnp.stack([fast, slow])
    v, iters = centered_clip_adaptive_stacked(stacked, 2.0, 1e-5, 300)
    assert int(iters[0]) < int(iters[1])
    for j in range(2):
        v_j, it_j = centered_clip_adaptive(stacked[j], 2.0, 1e-5, 300)
        np.testing.assert_array_equal(np.asarray(v[j]), np.asarray(v_j))
        assert int(iters[j]) == int(it_j)


def test_adaptive_pallas_driver_matches_jnp():
    """The early-exit kernel driver (one HBM pass per iteration + carried
    recurrence) tracks the jnp while_loop within f32 tolerance, with the
    same iteration counts."""
    from repro.core.centered_clip import centered_clip_adaptive_stacked
    from repro.kernels.ops import butterfly_clip_adaptive_op

    stacked = jax.random.normal(jax.random.key(11), (4, 8, 200))
    w = jnp.ones((8,)).at[3].set(0.0)
    v0 = 0.05 * jax.random.normal(jax.random.key(12), (4, 200))
    agg_k, it_k = butterfly_clip_adaptive_op(
        stacked, 2.0, 1e-6, w, v0=v0, max_iters=200
    )
    agg_j, it_j = centered_clip_adaptive_stacked(
        stacked, 2.0, 1e-6, 200, weights=w, v0=v0
    )
    np.testing.assert_allclose(
        np.asarray(agg_k), np.asarray(agg_j), atol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(it_k), np.asarray(it_j))


def test_adaptive_verified_epilogue_deterministic():
    """The verification tables depend only on (parts, agg, z) — running the
    adaptive aggregation at different caps that reach the same iterate gives
    identical tables (the budget is invisible to the broadcast protocol)."""
    from repro.core import butterfly as bf

    g = _rand(8, 8 * 40, seed=13)
    z = bf.get_random_directions(3, 8, 40)
    agg1, _, s1, n1, it1 = bf.butterfly_clip_verified_adaptive(
        g, 2.0, z, 1e-7, 500
    )
    agg2, _, s2, n2, it2 = bf.butterfly_clip_verified_adaptive(
        g, 2.0, z, 1e-7, 600
    )
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
