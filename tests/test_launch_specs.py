"""Launch-layer consistency: cache/batch specs match the abstract trees for
every (arch x shape), and the restarted BTARD variant converges."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs, shape_applicable
from repro.core import AttackConfig, BTARDTrainer, TrainerConfig
from repro.core.btard_sgd import restarted_btard_sgd
from repro.data import classification_batch, peer_seed
from repro.launch import input_specs as ispecs
from repro.models import Model
from repro.optim import sgd
from repro.sharding import set_mesh


class _FakeMesh:
    """Just enough mesh for spec construction (no devices touched)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", list_archs())
def test_cache_specs_cover_cache_tree(arch):
    cfg = get_config(arch)
    model = Model(cfg)
    mesh = _FakeMesh({"data": 16, "model": 16})
    set_mesh(mesh)
    for shape in INPUT_SHAPES.values():
        if shape.kind == "train" or not shape_applicable(cfg, shape):
            continue
        cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
        specs = ispecs.cache_specs(model, shape, mesh)
        # identical tree structure => every cache leaf has a spec
        s1 = jax.tree.structure(
            jax.tree.map(lambda _: 0, cache_abs)
        )
        s2 = jax.tree.structure(
            jax.tree.map(
                lambda _: 0, specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            )
        )
        assert s1 == s2, (arch, shape.name)
        # spec rank never exceeds leaf rank
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
        flat_abs = jax.tree.leaves(cache_abs)
        for sp, leaf in zip(flat_specs, flat_abs):
            assert len(sp) <= leaf.ndim, (arch, shape.name, sp, leaf.shape)


def test_long500k_cache_is_sequence_sharded():
    cfg = get_config("gemma3-27b")
    model = Model(cfg)
    mesh = _FakeMesh({"data": 16, "model": 16})
    set_mesh(mesh)
    specs = ispecs.cache_specs(model, INPUT_SHAPES["long_500k"], mesh)
    flat = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    # at least the KV leaves shard their sequence dim over 'data'
    assert any("data" in tuple(s) for s in flat)


def test_decode32k_cache_is_batch_sharded():
    cfg = get_config("gemma3-27b")
    model = Model(cfg)
    mesh = _FakeMesh({"data": 16, "model": 16})
    set_mesh(mesh)
    specs = ispecs.cache_specs(model, INPUT_SHAPES["decode_32k"], mesh)
    flat = [
        tuple(s)
        for s in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
    ]
    assert any(s and s[0] == "data" for s in flat)


def test_restarted_btard_sgd_converges():
    set_mesh(None)
    """Alg. 8: halving-radius restarts on the strongly convex problem."""
    DIM, CLASSES = 8, 2

    def batch_fn(peer, step, flipped):
        return classification_batch(peer_seed(0, step, peer), 16, DIM, CLASSES)

    def loss_fn(params, batch):
        logits = batch["x"] @ params["w"]
        return -jnp.mean(
            jnp.take_along_axis(
                jax.nn.log_softmax(logits), batch["y"][:, None], axis=1
            )
        ) + 1e-3 * jnp.sum(params["w"] ** 2)

    def make_trainer(lr, params0):
        cfg = TrainerConfig(
            n_peers=8, byzantine=(7,),
            attack=AttackConfig(kind="sign_flip", start_step=0),
            defense="btard", tau=1.0, m_validators=2,
        )
        p0 = params0 or {"w": jnp.zeros((DIM, CLASSES))}
        return BTARDTrainer(loss_fn, p0, batch_fn, cfg, optimizer=sgd(lr, momentum=0.9))

    params, hist = restarted_btard_sgd(
        make_trainer, n_restarts=3,
        steps_fn=lambda r: 8 * (r + 1),
        lr_fn=lambda r: 0.4 * (0.5**r),
    )
    eval_b = classification_batch(10**7, 512, DIM, CLASSES)
    acc = float((jnp.argmax(eval_b["x"] @ params["w"], 1) == eval_b["y"]).mean())
    assert acc > 0.9, acc
    assert any(h.get("restart") == 2 for h in hist)
