"""Baseline aggregators + attack zoo unit tests (paper §4.1 building blocks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as atk
from repro.core.aggregators import (
    coordinate_median,
    geometric_median,
    krum,
    mean_agg,
    ps_centered_clip,
    trimmed_mean,
)


def _data(b=3, n=10, d=16, scale=100.0):
    honest = jax.random.normal(jax.random.key(0), (n - b, d))
    bad = scale * jnp.ones((b, d))
    return jnp.concatenate([honest, bad]), honest


@pytest.mark.parametrize(
    "agg,kw",
    [
        (coordinate_median, {}),
        (geometric_median, {}),
        (trimmed_mean, {"trim_ratio": 0.3}),
        (krum, {"n_byzantine": 3}),
        (ps_centered_clip, {"tau": 1.0}),
    ],
)
def test_robust_aggregators_resist_large_outliers(agg, kw):
    xs, honest = _data()
    v = agg(xs, **kw)
    assert float(jnp.linalg.norm(v - honest.mean(0))) < 5.0


def test_mean_is_broken_by_one_attacker():
    xs, honest = _data(b=1)
    v = mean_agg(xs)
    assert float(jnp.linalg.norm(v - honest.mean(0))) > 5.0


def test_sign_flip_shapes_and_direction():
    g = jax.random.normal(jax.random.key(1), (8, 32))
    mask = jnp.arange(8) >= 5
    out = atk.sign_flip(g, mask, lam=1000.0)
    np.testing.assert_allclose(np.asarray(out[:5]), np.asarray(g[:5]))
    np.testing.assert_allclose(np.asarray(out[5:]), np.asarray(-1000.0 * g[5:]))


def test_ipm_sends_negative_scaled_honest_mean():
    g = jax.random.normal(jax.random.key(2), (8, 32))
    mask = jnp.arange(8) >= 6
    out = atk.ipm(g, mask, epsilon=0.6)
    mu = g[:6].mean(0)
    np.testing.assert_allclose(np.asarray(out[6]), np.asarray(-0.6 * mu), atol=1e-5)


def test_alie_stays_within_population_spread():
    """ALIE's point is staying inside the honest variance envelope."""
    g = jax.random.normal(jax.random.key(3), (16, 64))
    mask = jnp.arange(16) >= 9
    out = atk.alie(g, mask)
    mu = g[:9].mean(0)
    sd = g[:9].std(0, ddof=1)
    dev = jnp.abs(out[9] - mu) / jnp.maximum(sd, 1e-6)
    assert float(dev.max()) < 4.0  # z_max is small for these (n, b)


def test_random_direction_common_vector():
    g = jax.random.normal(jax.random.key(4), (8, 32))
    mask = jnp.arange(8) >= 5
    out = atk.random_direction(g, mask, key=jax.random.key(0), lam=100.0)
    # all attackers send the SAME vector
    np.testing.assert_allclose(np.asarray(out[5]), np.asarray(out[6]))
    np.testing.assert_allclose(np.asarray(out[6]), np.asarray(out[7]))
    assert float(jnp.linalg.norm(out[5])) > 10 * float(jnp.linalg.norm(g[0]))
