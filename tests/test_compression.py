"""Compressed robust all-reduce (core.compression): quantized butterfly
payloads with EXACT verification.

* registry / combinator / CLI-parse contract for the compressed: wrappers
  (auto-lift through verified:, codec param binding, canonical round trip);
* hypothesis property tests for the wire codecs over ragged shapes, extreme
  magnitudes (denormal territory), and all-zero partitions: determinism
  (same bits in -> same wire bits out, the exact-verification foundation),
  the int8 half-step error bound, bf16 cast equality, and digest equality —
  the tables any validator recomputes from the wire values match the
  owner's bit-for-bit;
* the fused dequantize kernels == kernels/ref.py oracles per partition;
* the adversarial attack x codec engine grid: compressed ButterflyClip and
  compressed verified:mean ban every Byzantine peer within 5 steps under
  every attack, honest runs produce ZERO accusations over 50 steps, and
  the scanned engine matches the stepwise engine exactly;
* one-coordinate cheaters are banned under BOTH codecs, while a
  perturbation BELOW the int8 quantization step is invisible: same wire
  row, same aggregate, no accusation — the wire representation IS the
  protocol-visible contribution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import butterfly as bf
from repro.core import compression as comp
from repro.core import engine as eng
from repro.core import verification as verif
from repro.core.aggregators import AggregatorSpec, registered_aggregators
from repro.core.protocol import AttackConfig

N, D = 8, 48
BYZ = (6, 7)
BAN_WITHIN = 5
GRID_STEPS = 8
HONEST_STEPS = 50

ATTACKS = {
    "sign_flip": dict(kind="sign_flip", lam=1.0),
    "scaled": dict(kind="sign_flip", lam=1000.0),
    "random": dict(kind="random_direction", lam=100.0),
    "colluding": dict(kind="ipm_06"),
}


def _spec(name, codec):
    return AggregatorSpec(name, (("codec", codec),))


def _grid_specs(codec):
    return [
        _spec("compressed:butterfly_clip", codec),
        _spec("compressed:verified:mean", codec),
    ]


def _grads_fn(n=N, d=D):
    w_true = jax.random.normal(jax.random.key(9), (d,))

    def peer_grad(peer, step, params):
        k = jax.random.key((peer * 7919 + step) % (2**31 - 1))
        X = jax.random.normal(k, (4, d))
        return 2 * X.T @ (X @ params - X @ w_true) / 4

    def grads_fn(params, t, flips):
        G = jax.vmap(lambda i: peer_grad(i, t, params))(jnp.arange(n))
        return G, G

    return grads_fn


def _cfg(spec, attack_kw, m_validators=3):
    # clip_iters=200 runs CenteredClip to its fixed point so the V2
    # checksum is honest-clean (as in tests/test_verification_grid.py);
    # the wrapped mean declares no n_iters and ignores it.
    return eng.config_from_attack(
        N, D, AttackConfig(start_step=0, **attack_kw),
        tau=1.0, clip_iters=200, m_validators=m_validators, aggregator=spec,
    )


def _run_stepwise(cfg, byz_mask, steps, grads_fn=None):
    grads_fn = grads_fn or _grads_fn()
    step_fn = eng.jit_protocol_step(cfg)
    state = eng.init_state(cfg, seed=0)
    flips = jnp.zeros((N,), bool)
    params = jnp.zeros(D, jnp.float32)
    outs = []
    for _ in range(steps):
        G, H = grads_fn(params, state.step, flips)
        state, out = step_fn(state, byz_mask, G, H)
        outs.append(out)
    return state, outs


def _run_scan(cfg, byz_mask, steps, grads_fn=None):
    grads_fn = grads_fn or _grads_fn()
    return jax.jit(
        lambda s, b, p: eng.scan_protocol(cfg, s, b, p, grads_fn, steps)
    )(eng.init_state(cfg, seed=0), byz_mask, jnp.zeros(D, jnp.float32))


# ---------------------------------------------------------------------------
# Registry / combinator / parse contract
# ---------------------------------------------------------------------------
def test_compressed_combinator_and_registry():
    names = set(registered_aggregators())
    assert {"compressed:butterfly_clip", "compressed:verified:mean",
            "compressed:verified:trimmed_mean",
            "compressed:verified:coordinate_median"} <= names
    # every compressed wrapper stays verifiable and declares a codec
    for name in names:
        if name.startswith("compressed:"):
            spec = AggregatorSpec(name)
            assert spec.verifiable
            assert comp.codec_of(spec) == comp.DEFAULT_CODEC

    # combinator: verifiable specs wrap directly, params preserved
    w = comp.compressed(
        AggregatorSpec("butterfly_clip", (("n_iters", 7),)), codec="bf16"
    )
    assert w.name == "compressed:butterfly_clip"
    assert w.get("n_iters") == 7 and comp.codec_of(w) == "bf16"
    assert comp.inner_spec(w) == AggregatorSpec(
        "butterfly_clip", (("n_iters", 7),)
    )
    # non-verifiable coordinatewise specs lift through verified: first
    assert comp.compressed("mean").name == "compressed:verified:mean"
    # already-compressed: unchanged unless the codec is overridden
    assert comp.compressed(w) == w
    assert comp.codec_of(comp.compressed(w, codec="int8")) == "int8"
    # full-vector specs rejected, like verified:
    for name in ("krum", "geometric_median", "centered_clip"):
        with pytest.raises(ValueError, match="not coordinatewise"):
            comp.compressed(name)
    with pytest.raises(ValueError, match="unknown wire codec"):
        comp.compressed("butterfly_clip", codec="fp4")
    with pytest.raises(ValueError, match="unknown wire codec"):
        comp.codec_of(_spec("compressed:butterfly_clip", "fp4"))

    # CLI parse: codec binds to the wrapper, other params to the inner spec
    s = AggregatorSpec.parse("compressed:butterfly_clip:n_iters=20,codec=bf16")
    assert s.name == "compressed:butterfly_clip"
    assert s.get("n_iters") == 20 and comp.codec_of(s) == "bf16"
    assert AggregatorSpec.parse(s.canonical()) == s
    s2 = AggregatorSpec.parse("compressed:verified:mean")
    assert s2.name == "compressed:verified:mean"
    s3 = AggregatorSpec.parse("compressed:mean")  # auto-lift
    assert s3.name == "compressed:verified:mean"
    s4 = AggregatorSpec.parse(
        "compressed:verified:trimmed_mean:trim_ratio=0.3"
    )
    assert s4.get("trim_ratio") == 0.3


# ---------------------------------------------------------------------------
# Codec properties (hypothesis): determinism, bounds, digest equality
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    n_parts=st.integers(1, 6),
    n=st.integers(2, 12),
    d=st.integers(2, 700),
    expo=st.integers(-40, 10),
    zero_rows=st.booleans(),
    seed=st.integers(0, 99999),
)
def test_property_codec_roundtrip(n_parts, n, d, expo, zero_rows, seed):
    """Wire-codec invariants over ragged shapes, magnitudes down to f32
    denormal territory (1e-40), and all-zero partitions: quantize is
    deterministic, all-zero payloads are exact, int8 error is bounded by
    half a quantization step, bf16 is a pure dtype cast."""
    x = jax.random.normal(
        jax.random.key(seed), (n_parts, n, d), jnp.float32
    ) * jnp.float32(10.0 ** expo)
    if zero_rows:
        x = x.at[0].set(0.0)  # whole-partition zeros (padding looks like this)

    for codec in comp.CODECS:
        q, scales = comp.quantize(x, codec)
        q2, scales2 = comp.quantize(x, codec)  # determinism — bitwise
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
        np.testing.assert_array_equal(np.asarray(scales), np.asarray(scales2))
        rt = np.asarray(comp.roundtrip(x, codec))
        xs = np.asarray(x)
        if zero_rows:
            assert not rt[0].any()  # all-zero payloads round-trip exactly
        if codec == "bf16":
            np.testing.assert_array_equal(
                rt, np.asarray(xs.astype(jnp.bfloat16), np.float32)
            )
        else:
            assert q.dtype == jnp.int8
            sc = np.asarray(scales)[..., None]
            amax = np.abs(xs).max(axis=-1, keepdims=True)
            # half a quantization step, plus slack for denormal flushing
            # (a flushed scale leaves at most |x| <= amax of error)
            atol = 0.5 * sc + amax * 1e-5 + 1e-37
            assert (np.abs(xs - rt) <= atol).all()


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(2, 12),
    d=st.integers(2, 500),
    expo=st.integers(-6, 6),
    seed=st.integers(0, 99999),
)
def test_property_wire_digest_equality(n, d, expo, seed):
    """The exact-verification contract: digests recomputed from the wire
    values by ANY party equal the owner's bit-for-bit. compressed
    spec_tables == inner spec_tables over the same wire parts (one code
    path — the dispatch only strips the wrapper), and compressed_aggregate
    returns exactly the wire_grads projection as its parts."""
    g = jax.random.normal(jax.random.key(seed), (n, d), jnp.float32)
    g = g * jnp.float32(10.0 ** expo)
    part = bf.pad_to_parts(d, n) // n
    z = bf.get_random_directions(seed + 1, n, part)
    for codec in comp.CODECS:
        spec = _spec("compressed:verified:mean", codec)
        agg, parts, s, norms, _ = verif.spec_aggregate(spec, g, z=z)
        # parts ARE the wire projection (peer payload boundaries fixed by
        # the butterfly layout)
        want_parts = bf.split_parts(comp.wire_grads(g, codec, n), n)
        np.testing.assert_array_equal(
            np.asarray(parts), np.asarray(want_parts)
        )
        # a validator's standalone recompute over those wire parts:
        # identical digests, whether or not it strips the wrapper itself
        s_c, n_c = verif.spec_tables(spec, parts, agg, z)
        s_i, n_i = verif.spec_tables(comp.inner_spec(spec), parts, agg, z)
        np.testing.assert_array_equal(np.asarray(s_c), np.asarray(s_i))
        np.testing.assert_array_equal(np.asarray(n_c), np.asarray(n_i))
        np.testing.assert_allclose(
            np.asarray(s), np.asarray(s_c), atol=1e-5 * 10.0 ** expo
        )
        np.testing.assert_allclose(
            np.asarray(norms), np.asarray(n_c), atol=1e-5 * 10.0 ** expo
        )


@settings(max_examples=6, deadline=None)
@given(
    n_parts=st.integers(1, 5),
    n=st.integers(2, 10),
    d=st.integers(2, 600),
    codec=st.sampled_from(comp.CODECS),
    banned=st.booleans(),
    seed=st.integers(0, 99999),
)
def test_property_fused_dequant_kernels_match_ref(
    n_parts, n, d, codec, banned, seed
):
    """The fused dequantize+clip+digest and dequantize+mean+digest kernels
    == the kernels/ref.py oracles per partition, over ragged shapes and
    both wire dtypes (wire-dtype zero padding must be exact)."""
    from repro.kernels.ops import (
        butterfly_clip_fused_dequant_op,
        mean_digest_fused_dequant_op,
    )
    from repro.kernels.ref import (
        centered_clip_fused_dequant_ref,
        mean_digest_fused_dequant_ref,
    )

    x = jax.random.normal(jax.random.key(seed), (n_parts, n, d)) * 2
    qs, scales = comp.quantize(x, codec)
    z = jax.random.normal(jax.random.key(seed + 2), (n_parts, d))
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=1, keepdims=True), 1e-30)
    w = jnp.where(jnp.arange(n) % 3 == 0, 0.0, 1.0) if banned else None

    n_iters = 5
    agg, s, norms = butterfly_clip_fused_dequant_op(
        qs, scales, 1.0, z, w, n_iters=n_iters
    )
    taus = jnp.full((n_iters,), 1.0, jnp.float32)
    for j in range(n_parts):
        v_r, s_r, n_r = centered_clip_fused_dequant_ref(
            qs[j], scales[j], taus, z[j], weights=w
        )
        np.testing.assert_allclose(np.asarray(agg[j]), np.asarray(v_r),
                                   atol=2e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(s[:, j]), np.asarray(s_r),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(norms[:, j]), np.asarray(n_r),
                                   atol=1e-4, rtol=1e-4)

    agg, s, norms = mean_digest_fused_dequant_op(qs, scales, z, w)
    for j in range(n_parts):
        v_r, s_r, n_r = mean_digest_fused_dequant_ref(
            qs[j], scales[j], z[j], w
        )
        np.testing.assert_allclose(np.asarray(agg[j]), np.asarray(v_r),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(s[:, j]), np.asarray(s_r),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(norms[:, j]), np.asarray(n_r),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# The adversarial attack x codec engine grid
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("codec", comp.CODECS)
@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_grid_bans_byzantine_and_scan_equals_stepwise(attack, codec):
    """Every compressed spec bans every Byzantine peer within BAN_WITHIN
    steps under every attack and codec, never bans an honest peer, and the
    stepwise and scanned engines agree exactly on bans/accusations."""
    byz_mask = jnp.asarray([1.0 if i in BYZ else 0.0 for i in range(N)])
    for spec in _grid_specs(codec):
        cfg = _cfg(spec, ATTACKS[attack])
        state_sw, step_outs = _run_stepwise(cfg, byz_mask, GRID_STEPS)
        state_sc, _, outs = _run_scan(cfg, byz_mask, GRID_STEPS)

        banned_sw = np.stack([np.asarray(o.banned_now) for o in step_outs])
        accuse_sw = np.stack([np.asarray(o.accuse_mat) for o in step_outs])
        np.testing.assert_array_equal(np.asarray(outs.banned_now), banned_sw)
        np.testing.assert_array_equal(np.asarray(outs.accuse_mat), accuse_sw)
        np.testing.assert_array_equal(
            np.asarray(state_sc.ban_step), np.asarray(state_sw.ban_step)
        )

        ban_step = np.asarray(state_sc.ban_step)
        label = f"{spec.canonical()} under {attack}"
        for i in BYZ:
            assert 0 <= ban_step[i] < BAN_WITHIN, (
                f"{label}: byz peer {i} ban_step={ban_step[i]}"
            )
        for i in range(N):
            if i not in BYZ:
                assert ban_step[i] == -1, f"{label}: honest peer {i} banned"


@pytest.mark.slow
@pytest.mark.parametrize("codec", comp.CODECS)
def test_honest_runs_have_zero_accusations(codec):
    """50 honest steps per codec, both engines: not a single peer or system
    accusation — rounding error can never slander anyone because every
    digest is computed over the dequantized wire values."""
    byz_mask = jnp.zeros((N,), jnp.float32)
    for spec in _grid_specs(codec):
        cfg = _cfg(spec, dict(kind="none"))
        state_sc, _, outs = _run_scan(cfg, byz_mask, HONEST_STEPS)
        label = spec.canonical()
        assert not np.asarray(outs.accuse_mat).any(), label
        assert not np.asarray(outs.sys_accuse).any(), label
        assert not np.asarray(outs.banned_now).any(), label
        assert not (np.asarray(state_sc.ban_step) >= 0).any(), label

        state_sw, step_outs = _run_stepwise(cfg, byz_mask, HONEST_STEPS)
        assert not any(np.asarray(o.accuse_mat).any() for o in step_outs)
        assert not any(np.asarray(o.sys_accuse).any() for o in step_outs)
        assert not (np.asarray(state_sw.ban_step) >= 0).any()


@pytest.mark.parametrize("codec", comp.CODECS)
def test_engine_bans_single_coordinate_cheater(codec):
    """A cheater perturbing ONE coordinate by more than the quantization
    step changes its wire row, so its recomputed digests mismatch and the
    audit bans it — under both codecs."""
    cheater = 2
    STEPS = 12  # >= worst-case audit latency at m_validators=3

    def grads_fn(params, t, flips):
        base = _grads_fn()
        G, H = base(params, t, flips)
        G = G.at[cheater, 5].add(0.5)  # far above the int8 step here
        return G, H

    for spec in _grid_specs(codec):
        cfg = _cfg(spec, dict(kind="none"))
        state, _, outs = _run_scan(
            cfg, jnp.zeros(N), STEPS, grads_fn=grads_fn
        )
        ban_step = np.asarray(state.ban_step)
        assert ban_step[cheater] >= 0, (
            f"{spec.canonical()}: single-coordinate cheater never banned"
        )
        assert all(
            ban_step[i] == -1 for i in range(N) if i != cheater
        ), spec.canonical()


def test_subquantization_cheat_is_invisible_and_harmless():
    """A perturbation BELOW the int8 quantization step never reaches the
    wire: the cheater's wire row is bit-identical to honest, so it is
    neither banned nor accused — correctly, because its perturbation also
    never entered the aggregate (identical g_hat). The wire representation
    IS the protocol-visible contribution."""
    cheater, coord, STEPS = 2, 5, 12
    base = _grads_fn()

    # freeze the gradient matrix so the wire-equality precondition holds
    # at EVERY step the validator rotation audits (with evolving params a
    # fixed delta can drift across a rounding boundary mid-run, which is a
    # different — banned — cheater)
    G0, _ = base(jnp.zeros(D, jnp.float32), 0, None)
    part = bf.pad_to_parts(D, N) // N
    row = bf.split_parts(G0, N)[cheater, coord // part]
    delta = float(np.abs(np.asarray(row)).max()) / 127.0 * 1e-3
    Gp = G0.at[cheater, coord].add(delta)

    # precondition: the perturbed gradient projects to the SAME wire bits
    np.testing.assert_array_equal(
        np.asarray(comp.wire_grads(Gp, "int8", N)),
        np.asarray(comp.wire_grads(G0, "int8", N)),
    )
    assert delta > 0

    def grads_fn(params, t, flips):
        return Gp, G0

    def grads_fn_h(params, t, flips):
        return G0, G0

    spec = _spec("compressed:butterfly_clip", "int8")
    cfg = _cfg(spec, dict(kind="none"))
    state, _, outs = _run_scan(cfg, jnp.zeros(N), STEPS, grads_fn=grads_fn)
    state_h, _, outs_h = _run_scan(
        cfg, jnp.zeros(N), STEPS, grads_fn=grads_fn_h
    )
    assert not np.asarray(outs.accuse_mat).any()
    assert not np.asarray(outs.sys_accuse).any()
    assert not (np.asarray(state.ban_step) >= 0).any()
    np.testing.assert_array_equal(
        np.asarray(outs.g_hat), np.asarray(outs_h.g_hat)
    )


# ---------------------------------------------------------------------------
# Wire-vs-raw commitment semantics
# ---------------------------------------------------------------------------
def test_compressed_aggregate_equals_inner_over_wire():
    """compressed_aggregate == the inner spec applied to the wire-projected
    gradients, for both the jnp and (interpret-mode) Pallas paths — the
    wrapper changes the wire representation, never the aggregation
    contract."""
    g = jax.random.normal(jax.random.key(11), (N, D + 3), jnp.float32) * 3
    n_parts = N
    part = bf.pad_to_parts(D + 3, n_parts) // n_parts
    z = bf.get_random_directions(5, n_parts, part)
    for codec in comp.CODECS:
        for inner_name in ("butterfly_clip", "verified:mean"):
            spec = comp.compressed(
                AggregatorSpec(inner_name).with_defaults(
                    tau=1.0, n_iters=30, adaptive_tol=None, warm_start=False
                ),
                codec=codec,
            )
            wire = comp.wire_grads(g, codec, n_parts)
            for use_pallas in (False, True):
                agg, parts, s, norms, _ = verif.spec_aggregate(
                    spec, g, z=z, use_pallas=use_pallas
                )
                agg_i, parts_i, s_i, n_i, _ = verif.spec_aggregate(
                    comp.inner_spec(spec), wire, z=z, use_pallas=False
                )
                np.testing.assert_array_equal(
                    np.asarray(parts), np.asarray(parts_i)
                )
                np.testing.assert_allclose(
                    np.asarray(agg), np.asarray(agg_i), atol=3e-5
                )
                np.testing.assert_allclose(
                    np.asarray(s), np.asarray(s_i), atol=1e-4
                )
                np.testing.assert_allclose(
                    np.asarray(norms), np.asarray(n_i), atol=1e-4
                )
