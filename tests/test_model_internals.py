"""Model-internal invariants: attention path equivalences, SSD vs naive
recurrence, RG-LRU vs step recurrence, MoE dispatch exactness."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.attention import (
    KV_BLOCK,
    _blocked_attention,
    _dense_attention,
    _windowed_attention,
    causal_attention,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import ssd_chunked


def _qkv(B=2, S=64, K=2, G=2, D=16, T=None, seed=0):
    T = T or S
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, D))
    k = jax.random.normal(ks[1], (B, T, K, D))
    v = jax.random.normal(ks[2], (B, T, K, D))
    return q, k, v


def test_blocked_attention_matches_dense():
    q, k, v = _qkv(S=64)
    pos = jnp.arange(64)
    msk = (pos[None, :] <= pos[:, None])[None, None, None]
    dense = _dense_attention(q, k, v, msk)
    blocked = _blocked_attention(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(blocked), atol=2e-5)


def test_windowed_attention_matches_masked_dense():
    S, w = 256, 32
    q, k, v = _qkv(S=S)
    pos = jnp.arange(S)
    msk = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - w)
    dense = _dense_attention(q, k, v, msk[None, None, None])
    windowed = _windowed_attention(q, k, v, w)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(windowed), atol=2e-5)


def test_ssd_chunked_matches_naive_recurrence():
    B, S, H, P, N, Q = 1, 48, 2, 4, 8, 8
    ks = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = -dt * 0.5
    Bm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, S, N)) * 0.5

    y, final = ssd_chunked(x, a_log, dt, Bm, Cm, Q)

    # naive: S_t = a_t S_{t-1} + dt_t B_t x_t ; y_t = C_t . S_t
    state = np.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        a = np.exp(np.asarray(a_log[:, t]))  # (B,H)
        inc = np.einsum("bn,bhp->bhnp", np.asarray(Bm[:, t]),
                        np.asarray(dt[:, t])[..., None] * np.asarray(x[:, t]))
        state = state * a[..., None, None] + inc
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(Cm[:, t]), state))
    y_ref = np.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), state, atol=1e-3, rtol=1e-3)


def test_moe_dispatch_exact_vs_dense_computation():
    """With ample capacity, scatter-dispatch == explicit per-token expert mix."""
    cfg = ModelConfig(
        name="t", family="moe", d_model=16, n_heads=2, n_kv_heads=2, head_dim=8,
        d_ff=0, vocab_size=16, pattern=(LayerSpec("attn_full", "moe"),),
        n_repeats=1, n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=8.0,
        dtype="float32",
    )
    p = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 6, 16))
    y, aux = moe_apply(p, cfg, x)

    # dense reference
    xt = x.reshape(-1, 16)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for j in range(2):
            e = int(top_e[t, j])
            h = np.asarray(xt[t]) @ np.asarray(p["experts_wi"][e])
            g = jax.nn.silu(np.asarray(xt[t]) @ np.asarray(p["experts_wg"][e]))
            out = (np.asarray(g) * h) @ np.asarray(p["experts_wdown"][e])
            ref[t] += float(top_p[t, j]) * out
    np.testing.assert_allclose(
        np.asarray(y).reshape(-1, 16), ref, atol=1e-4, rtol=1e-4
    )
    assert float(aux) > 0


def test_causal_attention_switches_paths_consistently():
    """The dense/blocked path switch must be numerically invisible."""
    q, k, v = _qkv(S=KV_BLOCK + 32, seed=5)
    full = causal_attention(q, k, v)
    pos = jnp.arange(q.shape[1])
    blocked = _blocked_attention(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked), atol=2e-5)
