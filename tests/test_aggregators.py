"""AggregatorSpec API: registry contract, the attack x aggregator grid
through the engine (stepwise == scanned), the weighted trimmed-mean / Krum
fixes, and the deprecation shims onto equivalent specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as agg_mod
from repro.core import butterfly as bf
from repro.core import engine as eng
from repro.core.aggregators import (
    AggregatorSpec,
    krum,
    registered_aggregators,
    resolve_spec,
    trimmed_mean,
    verified_aggregate,
)
from repro.core.protocol import AttackConfig

N, D, STEPS = 8, 48, 8
BYZ = (5, 6, 7)

SPECS = [
    AggregatorSpec("butterfly_clip"),
    AggregatorSpec("mean"),
    AggregatorSpec("coordinate_median"),
    AggregatorSpec("trimmed_mean", (("trim_ratio", 0.25),)),
    AggregatorSpec("geometric_median"),
    AggregatorSpec("krum", (("n_byzantine", 3),)),
    AggregatorSpec("centered_clip"),
]


# ---------------------------------------------------------------------------
# Spec / registry contract
# ---------------------------------------------------------------------------
def test_registry_covers_all_paper_baselines():
    names = set(registered_aggregators())
    assert {"mean", "coordinate_median", "trimmed_mean", "geometric_median",
            "krum", "centered_clip", "butterfly_clip"} <= names
    # the verifiable set: the flagship plus exactly one verified:<base>
    # wrapper per coordinatewise baseline (core.verification), each also
    # available with quantized wire payloads (core.compression)
    verifiable = {
        "butterfly_clip", "verified:mean", "verified:trimmed_mean",
        "verified:coordinate_median",
    }
    assert {n for n in names if AggregatorSpec(n).verifiable} == (
        verifiable | {f"compressed:{n}" for n in verifiable}
    )


def test_spec_parse_and_canonical_roundtrip():
    spec = AggregatorSpec.parse("krum:n_byzantine=3")
    assert spec.name == "krum" and spec.get("n_byzantine") == 3
    spec2 = AggregatorSpec.parse(spec.canonical())
    assert spec2 == spec
    multi = AggregatorSpec.parse(
        "butterfly_clip:warm_start=true,adaptive_tol=1e-4"
    )
    assert multi.get("warm_start") is True
    assert multi.get("adaptive_tol") == pytest.approx(1e-4)


def test_spec_rejects_unknown_names_and_params():
    with pytest.raises(ValueError, match="unknown aggregator"):
        AggregatorSpec.parse("medoid")
    with pytest.raises(ValueError, match="no param"):
        AggregatorSpec.parse("mean:tau=1.0")
    with pytest.raises(ValueError, match="no param"):
        AggregatorSpec("krum", (("trim_ratio", 0.1),)).param_dict()


def test_with_defaults_fills_only_declared_unset_params():
    spec = AggregatorSpec("butterfly_clip", (("tau", 3.0),))
    out = spec.with_defaults(tau=1.0, n_iters=25, trim_ratio=0.4)
    assert out.get("tau") == 3.0  # explicit param wins
    assert out.get("n_iters") == 25  # filled
    assert "trim_ratio" not in dict(out.params)  # undeclared: ignored
    # mean declares nothing — engine knobs fall away silently
    assert AggregatorSpec("mean").with_defaults(tau=1.0).params == ()


def test_uniform_signature_across_registry():
    xs = jax.random.normal(jax.random.key(0), (N, D))
    w = jnp.ones((N,)).at[-1].set(0.0)
    for spec in SPECS:
        v, info = agg_mod.aggregate(
            spec, xs, weights=w, v0=jnp.zeros((D,)), key=jax.random.key(1)
        )
        assert v.shape == (D,), spec.name
        assert np.isfinite(np.asarray(v)).all(), spec.name
        assert np.asarray(info.iters).dtype == np.int32, spec.name


# ---------------------------------------------------------------------------
# Satellite fixes: weighted trimmed mean / Krum distance masking
# ---------------------------------------------------------------------------
def test_trimmed_mean_banned_rows_never_enter_trim_window():
    """3 banned rows at +1000 with trim_ratio=0.2: the old code trimmed
    k=int(10*0.2)=2 rows per end over ALL rows, so one banned row survived
    into the mean. The fix trims over the active block only."""
    n, d = 10, 6
    honest = jax.random.normal(jax.random.key(0), (n - 3, d))
    xs = jnp.concatenate([honest, 1000.0 * jnp.ones((3, d))])
    w = jnp.concatenate([jnp.ones((n - 3,)), jnp.zeros((3,))])
    v = trimmed_mean(xs, trim_ratio=0.2, weights=w)
    # reference: numpy trimmed mean over the 7 active rows, k = floor(7*.2)=1
    ref = np.sort(np.asarray(honest), axis=0)[1:-1].mean(0)
    np.testing.assert_allclose(np.asarray(v), ref, rtol=1e-5, atol=1e-5)


def test_trimmed_mean_unweighted_matches_legacy():
    xs = jax.random.normal(jax.random.key(1), (9, 5))
    got = trimmed_mean(xs, trim_ratio=0.25)
    k = int(9 * 0.25)
    ref = np.sort(np.asarray(xs), axis=0)[k : 9 - k].mean(0)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-6)
    # all-active weights == no weights (same window, same mean)
    got_w = trimmed_mean(xs, trim_ratio=0.25, weights=jnp.ones((9,)))
    np.testing.assert_allclose(np.asarray(got_w), ref, rtol=1e-5, atol=1e-6)


def test_krum_banned_rows_are_not_neighbours():
    """An active attacker surrounded by BANNED clones must not win: the old
    code masked only the final scores, so the clones still served as
    zero-distance nearest neighbours and deflated the attacker's score."""
    n, d = 8, 4
    honest = 0.1 * jax.random.normal(jax.random.key(2), (4, d))
    attacker = 5.0 * jnp.ones((1, d))
    clones = attacker + 1e-3 * jax.random.normal(jax.random.key(3), (3, d))
    xs = jnp.concatenate([honest, attacker, clones])
    w = jnp.concatenate([jnp.ones((5,)), jnp.zeros((3,))])  # clones banned
    v = krum(xs, n_byzantine=3, weights=w)
    assert float(jnp.linalg.norm(v)) < 1.0, np.asarray(v)
    # sanity: without masking the pairwise matrix the attacker would win
    # (its k=3 nearest neighbours are its three zero-distance banned clones)
    d2 = jnp.sum((xs[:, None, :] - xs[None, :, :]) ** 2, -1) + jnp.eye(n) * 1e30
    k = max(1, n - 3 - 2)
    scores = jnp.sort(d2, 1)[:, :k].sum(1)
    old_pick = int(jnp.argmin(jnp.where(w > 0, scores, jnp.inf)))
    assert old_pick == 4  # the attacker — the bug this fix removes


def test_krum_banned_rows_never_selected():
    xs = jnp.concatenate([
        0.1 * jax.random.normal(jax.random.key(4), (6, 3)),
        100.0 * jnp.ones((2, 3)),
    ])
    w = jnp.ones((8,)).at[6:].set(0.0)
    v = krum(xs, n_byzantine=2, weights=w)
    assert float(jnp.linalg.norm(v)) < 2.0


# ---------------------------------------------------------------------------
# The attack x aggregator grid: stepwise == scanned, degradation contract
# ---------------------------------------------------------------------------
def _grads_fn():
    w_true = jax.random.normal(jax.random.key(9), (D,))

    def peer_grad(peer, step, params):
        k = jax.random.key((peer * 7919 + step) % (2**31 - 1))
        X = jax.random.normal(k, (4, D))
        return 2 * X.T @ (X @ params - X @ w_true) / 4

    def grads_fn(params, t, flips):
        G = jax.vmap(lambda i: peer_grad(i, t, params))(jnp.arange(N))
        return G, G

    return grads_fn


@pytest.mark.parametrize("attack", ["sign_flip", "alie", "ipm_06"])
@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_grid_scan_equals_stepwise(spec, attack):
    """Every registered aggregator, under every collusion attack, in BOTH
    engine entry points: N jit_protocol_step calls == one scan_protocol —
    identical bans/accusations, f32-tolerance aggregates. Non-verifiable
    specs must produce ZERO accusations and bans on both paths."""
    cfg = eng.config_from_attack(
        N, D, AttackConfig(kind=attack, start_step=2, lam=100.0),
        tau=1.0, clip_iters=20, m_validators=2, aggregator=spec,
    )
    grads_fn = _grads_fn()
    byz_mask = jnp.asarray([1.0 if i in BYZ else 0.0 for i in range(N)])
    params = jnp.zeros(D, jnp.float32)

    # stepwise: N jitted single steps
    step_fn = eng.jit_protocol_step(cfg)
    state = eng.init_state(cfg, seed=0)
    flips = jnp.zeros((N,), bool)
    step_outs = []
    for _ in range(STEPS):
        G, H = grads_fn(params, state.step, flips)
        state, out = step_fn(state, byz_mask, G, H)
        step_outs.append(out)

    # scanned: one lax.scan (params fixed — no update_fn — matching above)
    state_s, _, outs = jax.jit(
        lambda s, b, p: eng.scan_protocol(cfg, s, b, p, grads_fn, STEPS)
    )(eng.init_state(cfg, seed=0), byz_mask, params)

    banned_step = np.stack([np.asarray(o.banned_now) for o in step_outs])
    accuse_step = np.stack([np.asarray(o.accuse_mat) for o in step_outs])
    np.testing.assert_array_equal(np.asarray(outs.banned_now), banned_step)
    np.testing.assert_array_equal(np.asarray(outs.accuse_mat), accuse_step)
    g_step = np.stack([np.asarray(o.g_hat) for o in step_outs])
    scale = np.abs(g_step).max(axis=1, keepdims=True) + 1.0
    np.testing.assert_allclose(
        np.asarray(outs.g_hat) / scale, g_step / scale, atol=2e-5
    )

    if not spec.verifiable:
        assert not accuse_step.any(), spec.name
        assert not np.asarray(outs.sys_accuse).any(), spec.name
        assert not banned_step.any(), spec.name
        assert not (np.asarray(state_s.ban_step) >= 0).any(), spec.name
    elif attack == "sign_flip":
        # the flagship's detection arm still fires where PR 2 proved it does
        assert banned_step.any(), "butterfly_clip stopped banning sign_flip"


def test_grid_non_verifiable_robust_specs_survive_sign_flip():
    """The Fig. 3 story in miniature: under amplified sign flip the robust
    baselines keep a bounded aggregate while plain mean is dragged to the
    attack scale (they just never BAN anyone — detection is butterfly-only)."""
    grads_fn = _grads_fn()
    byz_mask = jnp.asarray([1.0 if i in BYZ else 0.0 for i in range(N)])
    norms = {}
    for name in ("mean", "krum", "geometric_median", "centered_clip"):
        spec = AggregatorSpec(name)
        if name == "krum":
            spec = spec.override(n_byzantine=len(BYZ))
        cfg = eng.config_from_attack(
            N, D, AttackConfig(kind="sign_flip", start_step=0, lam=1000.0),
            tau=1.0, clip_iters=20, m_validators=2, aggregator=spec,
        )
        _, _, outs = jax.jit(
            lambda s, b, p, cfg=cfg: eng.scan_protocol(
                cfg, s, b, p, grads_fn, 4
            )
        )(eng.init_state(cfg, seed=0), byz_mask, jnp.zeros(D, jnp.float32))
        norms[name] = float(np.linalg.norm(np.asarray(outs.g_hat[-1])))
    assert norms["mean"] > 50 * max(
        norms["krum"], norms["geometric_median"], norms["centered_clip"]
    ), norms


# ---------------------------------------------------------------------------
# Deprecation shims resolve to equivalent specs
# ---------------------------------------------------------------------------
def test_butterfly_clip_verified_shim_warns_and_matches_spec_path():
    g = jax.random.normal(jax.random.key(5), (N, 40))
    z = bf.get_random_directions(7, N, 5)
    with pytest.warns(DeprecationWarning, match="AggregatorSpec"):
        a1, p1, s1, n1 = bf.butterfly_clip_verified(g, 1.0, z, n_iters=7)
    spec = AggregatorSpec(
        "butterfly_clip", (("n_iters", 7), ("tau", 1.0)),
    ).with_defaults(adaptive_tol=None, warm_start=False)
    a2, p2, s2, n2, iters = verified_aggregate(spec, g, z)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
    assert int(iters) == 7


def test_butterfly_stage_shim_warns_and_matches_aggregation_stage():
    from repro.launch import steps as lsteps

    mesh = jax.make_mesh((1,), ("peers",))
    g = jax.random.normal(jax.random.key(6), (24,))
    w = jnp.ones((1,))

    def run(fn):
        return lsteps._shard_map(
            fn, mesh=mesh, in_specs=(lsteps.P("peers"), lsteps.P()),
            out_specs=(lsteps.P(), {
                "checksum": lsteps.P("peers"), "votes": lsteps.P("peers"),
                "clip_iters": lsteps.P("peers"),
                "s_table": lsteps.P(None, None),
                "norm_table": lsteps.P(None, None),
                "audit_target": lsteps.P("peers"),
                "audit_grad_mismatch": lsteps.P("peers"),
                "audit_agg_mismatch": lsteps.P("peers"),
            }),
            axis_names={"peers"},
        )(g[None, :], w)

    with pytest.warns(DeprecationWarning, match="aggregation_stage"):
        full_old, verif_old = run(
            lambda gv, ww: lsteps.butterfly_stage(
                gv[0], "peers", 1, 2.0, 6, ww, 13
            )
        )
    spec = AggregatorSpec("butterfly_clip", (("n_iters", 6), ("tau", 2.0)))
    full_new, verif_new = run(
        lambda gv, ww: lsteps.aggregation_stage(
            gv[0], "peers", 1, spec.with_defaults(
                adaptive_tol=None, warm_start=False
            ), ww, 13,
        )
    )
    np.testing.assert_array_equal(np.asarray(full_old), np.asarray(full_new))
    np.testing.assert_array_equal(
        np.asarray(verif_old["s_table"]), np.asarray(verif_new["s_table"])
    )


def test_krum_launch_keeps_full_vector_semantics():
    """Krum is not coordinate-decomposable: on a model-sharded mesh the
    launch stage must join the shards before scoring so ONE peer wins
    globally — per-shard application can elect different winners per shard
    and emit a composite gradient no peer proposed (this scenario is
    constructed so it would). Subprocess: fake devices need XLA_FLAGS
    before jax import."""
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch import steps as lsteps
from repro.core.aggregators import AggregatorSpec, krum

mesh = jax.make_mesh((4, 2), ("peers", "model"))
n, d = 4, 8
# rows ~ [0, .1, .2, .3]; peer 0 is an outlier in shard A only, peer 3 in
# shard B only -> per-shard krum picks DIFFERENT winners (1 then 0) while
# full-vector krum picks peer 1 everywhere
G = np.tile(np.asarray([0.0, 0.1, 0.2, 0.3])[:, None], (1, d)).astype(np.float32)
G[0, : d // 2] = 50.0
G[3, d // 2 :] = 100.0
G = jnp.asarray(G)
w = jnp.ones((n,))
spec = AggregatorSpec("krum", (("n_byzantine", 1),))

def f(gv, ww):
    out, _ = lsteps.aggregation_stage(
        gv.reshape(-1), ("peers",), n, spec, ww, 3, gather_axes=("model",)
    )
    return out

agg = lsteps._shard_map(
    f, mesh=mesh, in_specs=(P("peers", "model"), P()), out_specs=P("model"),
    axis_names={"peers", "model"},
)(G, w)
want = krum(G, n_byzantine=1, weights=w)
np.testing.assert_array_equal(np.asarray(agg), np.asarray(want))
print("KRUM_JOIN_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert r.returncode == 0, r.stdout[-2000:] + "\n---\n" + r.stderr[-2000:]
    assert "KRUM_JOIN_OK" in r.stdout


def test_cli_clip_flag_shims_resolve_to_spec():
    from repro.launch.train import resolve_cli_aggregator

    with pytest.warns(DeprecationWarning, match="--warm-start-clip"):
        spec = resolve_cli_aggregator("butterfly_clip", True, None, 0)
    assert spec.get("warm_start") is True
    with pytest.warns(DeprecationWarning, match="--adaptive-clip"):
        spec = resolve_cli_aggregator("butterfly_clip", False, 1e-4, 0)
    assert spec.get("adaptive_tol") == pytest.approx(1e-4)
    # explicit spec params beat legacy knobs downstream (with_defaults)
    spec = resolve_cli_aggregator(
        "butterfly_clip:adaptive_tol=1e-2", False, None, 0
    ).with_defaults(tau=1.0, n_iters=60, adaptive_tol=None, warm_start=False)
    assert spec.get("adaptive_tol") == pytest.approx(1e-2)
    # krum inherits n_byzantine from the --byzantine list
    assert resolve_cli_aggregator("krum", False, None, 5).get(
        "n_byzantine"
    ) == 5
    # the flags are ignored (with a warning) for specs that can't use them
    with pytest.warns(UserWarning, match="ignored"):
        spec = resolve_cli_aggregator("mean", True, None, 0)
    assert spec.params == ()


def test_engine_default_spec_matches_legacy_knobs():
    """EngineConfig.aggregator=None resolves the legacy tau/clip_iters/
    warm_start/adaptive_tol knobs into the flagship spec — the pre-spec
    configuration surface keeps meaning exactly what it meant."""
    cfg = eng.EngineConfig(n=N, d=D, tau=2.5, clip_iters=11, warm_start=True,
                           adaptive_tol=1e-3)
    spec = cfg.agg_spec()
    assert spec.name == "butterfly_clip" and spec.verifiable
    assert spec.get("tau") == 2.5
    assert spec.get("n_iters") == 11
    assert spec.get("warm_start") is True
    assert spec.get("adaptive_tol") == pytest.approx(1e-3)
    assert resolve_spec(None).name == "butterfly_clip"
