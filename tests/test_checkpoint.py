"""Checkpoint format guarantees (repro.checkpoint).

The scan-resume bitwise property needs the restored state to be the SAME
BITS, so the msgpack codec is held to exact-dtype round-trips (bf16 wire
buffers, int8 codec state, the uint32 PRNG key chain), a format-version
gate that rejects a stale layout with a clear error instead of a
downstream shape crash, and writable restored arrays. The integration
property: a ProtocolState checkpointed mid-run and restored continues the
scan bitwise-identically to the uninterrupted run.
"""
import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.checkpoint.checkpoint import FORMAT_VERSION
from repro.core import engine as eng
from repro.core.protocol import AttackConfig

N, D = 6, 24


def test_dtype_fidelity_exact_bits(tmp_path):
    """Every protocol-relevant dtype round-trips through its own byte
    width: restored arrays have the same dtype AND the same bits."""
    try:
        import ml_dtypes  # noqa: F401

        bf16 = jnp.bfloat16
    except ImportError:  # pragma: no cover
        bf16 = jnp.float32
    tree = {
        "f32": np.linspace(-1, 1, 7, dtype=np.float32),
        "bf16": jnp.asarray([1.5, -2.25, 3e-8, 65504.0], bf16),
        "int8": np.asarray([-128, -1, 0, 127], np.int8),
        "i32": np.asarray([-(2**31), 2**31 - 1], np.int32),
        # the MPRNG chain: raw uint32 key data, NOT a float detour
        "key": np.asarray(jax.random.PRNGKey(7)),
        "bool": np.asarray([True, False, True]),
    }
    path = str(tmp_path / "ck.msgpack")
    save_checkpoint(path, tree, step=5, meta={"tag": "x"})
    restored, step, meta = load_checkpoint(path, tree)
    assert step == 5 and meta == {"tag": "x"}
    for k, ref in tree.items():
        got = np.asarray(restored[k])
        ref = np.asarray(ref)
        assert got.dtype == ref.dtype, (k, got.dtype, ref.dtype)
        assert got.tobytes() == ref.tobytes(), k
    assert np.asarray(restored["key"]).dtype == np.uint32


def test_restored_arrays_are_writable(tmp_path):
    path = str(tmp_path / "ck.msgpack")
    save_checkpoint(path, {"a": np.arange(4, dtype=np.float32)})
    flat, _, _ = load_checkpoint(path)
    flat["a"][0] = 99.0  # frombuffer views would raise here
    assert flat["a"][0] == 99.0


def test_format_version_mismatch_rejected_clearly(tmp_path):
    """A checkpoint from another layout generation (including the
    unversioned v1 seed format) must be refused with an error that names
    the version, not fail later with a shape/index crash."""
    path = str(tmp_path / "old.msgpack")
    save_checkpoint(path, {"a": np.zeros(2, np.float32)}, step=3)
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    for stale in ({"format_version": FORMAT_VERSION + 1}, {}):
        payload.pop("format_version", None)
        payload.update(stale)
        with open(path, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        with pytest.raises(ValueError, match="format_version"):
            load_checkpoint(path)


def test_missing_array_named_in_error(tmp_path):
    path = str(tmp_path / "ck.msgpack")
    save_checkpoint(path, {"a": np.zeros(2, np.float32)})
    with pytest.raises(KeyError, match="b"):
        load_checkpoint(path, {"a": np.zeros(2, np.float32),
                               "b": np.zeros(2, np.float32)})


def test_atomic_save_preserves_previous_on_reload(tmp_path):
    """os.replace semantics: after any completed save the file is a whole
    checkpoint (the tmp file never becomes the destination partially)."""
    path = str(tmp_path / "ck.msgpack")
    save_checkpoint(path, {"a": np.zeros(3, np.float32)}, step=1)
    save_checkpoint(path, {"a": np.ones(3, np.float32)}, step=2)
    flat, step, _ = load_checkpoint(path)
    assert step == 2 and np.all(flat["a"] == 1.0)
    assert not (tmp_path / "ck.msgpack.tmp").exists()


def test_protocol_state_roundtrip_resumes_scan_bitwise(tmp_path):
    """The engine-level crash drill: run 8 rounds; separately run 4, save
    the FULL ProtocolState (delay ring buffer in bf16, elastic ledgers,
    PRNG key), restore, run 4 more — bans, ledgers and aggregates match
    the uninterrupted run bitwise."""
    cfg = eng.config_from_attack(
        N, D, AttackConfig(kind="delayed_gradient", start_step=0, delay=3),
        tau=1.0, clip_iters=30, m_validators=2, aggregator="verified:mean",
        n_events=2, probation_steps=2,
    )
    byz = jnp.asarray([0, 0, 0, 0, 0, 1], jnp.float32)
    events = [(2, "leave", 5), (4, "join", 5)]

    w_true = jax.random.normal(jax.random.key(9), (D,))

    def grads_fn(params, t, flips):
        def peer_grad(i):
            k = jax.random.key((i * 7919) % (2**31 - 1))
            X = jax.random.normal(k, (4, D))
            return 2 * X.T @ (X @ params - X @ w_true) / 4

        G = jax.vmap(lambda i: peer_grad(i))(jnp.arange(N))
        return G, G

    params = jnp.zeros(D, jnp.float32)
    run = lambda st, k: eng.scan_protocol(cfg, st, byz, params, grads_fn, k)

    state0 = eng.init_state(cfg, seed=0, events=events)
    full_state, _, full_outs = run(state0, 8)

    half_state, _, _ = run(eng.init_state(cfg, seed=0, events=events), 4)
    path = str(tmp_path / "state.msgpack")
    save_checkpoint(path, half_state, step=4)
    restored, step, _ = load_checkpoint(path, half_state)
    assert step == 4
    # the restore is bit-exact, dtypes included (bf16 ring buffer!)
    for ref, got in zip(jax.tree.leaves(half_state),
                        jax.tree.leaves(restored)):
        assert np.asarray(got).dtype == np.asarray(ref).dtype
        assert np.asarray(got).tobytes() == np.asarray(ref).tobytes()
    resumed_state, _, resumed_outs = run(restored, 4)

    np.testing.assert_array_equal(
        np.asarray(resumed_outs.g_hat), np.asarray(full_outs.g_hat)[4:]
    )
    np.testing.assert_array_equal(
        np.asarray(resumed_outs.lifecycle),
        np.asarray(full_outs.lifecycle)[4:],
    )
    for f in ("ban_step", "ban_reason", "id_ban_step", "id_accused",
              "probation_clean", "slot_identity", "col_checked"):
        np.testing.assert_array_equal(
            np.asarray(getattr(resumed_state, f)),
            np.asarray(getattr(full_state, f)), err_msg=f,
        )
