"""Elastic membership inside the scan engine (core.engine + core.sybil).

The load-bearing properties of the slot-lifecycle machinery:

* fixed-mode neutrality: giving a config elastic CAPACITY (n_events > 0)
  without scheduling any events changes NOTHING — every output is bitwise
  identical to the fixed-peer-set engine;
* any join/leave/ban interleaving produces the same lifecycle/active
  masks, ban ledgers and identity ledgers whether the rounds run stepwise
  or under one ``lax.scan`` (hypothesis property — the schedule is drawn
  at random, invalid events must no-op identically in both engines);
* a joining peer is held in probation at weight ZERO: until promotion the
  aggregate is bitwise the aggregate of the run where the slot stayed
  vacant, and a clean probation window flips the slot active;
* the rejoin-under-new-key adversary: a banned Byzantine peer that leaves
  and rejoins with a fresh identity is re-vetted in probation, caught by
  the public-seed spot-check, and re-banned (BAN_SYBIL) WITHOUT its
  gradient ever entering the aggregate; a same-key rejoin lands directly
  in BANNED at admission (identity ledger lookup);
* churn never launders history: identity ban entries survive leave/rejoin
  and the column-staleness ledger (col_checked) is monotone through
  membership events.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import engine as eng
from repro.core import sybil
from repro.core.attacks import rejoin_under_new_key
from repro.core.protocol import AttackConfig

N, D = 6, 24
STEPS = 12


def _grads_fn(n=N, d=D):
    w_true = jax.random.normal(jax.random.key(9), (d,))

    def peer_grad(peer, step, params):
        k = jax.random.key((peer * 7919 + step) % (2**31 - 1))
        X = jax.random.normal(k, (4, d))
        return 2 * X.T @ (X @ params - X @ w_true) / 4

    def grads_fn(params, t, flips):
        G = jax.vmap(lambda i: peer_grad(i, t, params))(jnp.arange(n))
        return G, G

    return grads_fn


def _cfg(attack_kw=None, **kw):
    kw.setdefault("tau", 1.0)
    kw.setdefault("clip_iters", 30)
    kw.setdefault("m_validators", 2)
    kw.setdefault("aggregator", "verified:mean")
    att = AttackConfig(start_step=0, **(attack_kw or dict(kind="none")))
    return eng.config_from_attack(N, D, att, **kw)


def _run_stepwise(cfg, byz_mask, steps, events=None, vacant=()):
    step_fn = eng.jit_protocol_step(cfg)
    grads_fn = _grads_fn()
    state = eng.init_state(cfg, seed=0, events=events, vacant=vacant)
    params = jnp.zeros(D, jnp.float32)
    flips = jnp.zeros((N,), bool)
    outs, states = [], []
    for _ in range(steps):
        G, H = grads_fn(params, state.step, flips)
        state, out = step_fn(state, byz_mask, G, H)
        outs.append(out)
        states.append(state)
    return state, outs, states


def _run_scan(cfg, byz_mask, steps, events=None, vacant=()):
    grads_fn = _grads_fn()
    return jax.jit(
        lambda s, b, p: eng.scan_protocol(cfg, s, b, p, grads_fn, steps)
    )(
        eng.init_state(cfg, seed=0, events=events, vacant=vacant),
        byz_mask,
        jnp.zeros(D, jnp.float32),
    )


def _stack(outs, field):
    return np.stack([np.asarray(getattr(o, field)) for o in outs])


# ---------------------------------------------------------------------------
# Fixed-mode neutrality
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("attack_kw", [dict(kind="none"),
                                       dict(kind="sign_flip", lam=1.0)])
def test_elastic_capacity_without_events_is_bitwise_neutral(attack_kw):
    """n_events > 0 with an inert schedule must not perturb a single bit:
    every existing config keeps its exact trajectory when the membership
    machinery is compiled in but idle."""
    byz = jnp.asarray([0, 0, 0, 0, 0, 1], jnp.float32)
    state_fix, _, outs_fix = _run_scan(_cfg(attack_kw), byz, STEPS)
    state_el, _, outs_el = _run_scan(
        _cfg(attack_kw, n_events=4, probation_steps=2), byz, STEPS
    )
    np.testing.assert_array_equal(
        np.asarray(outs_el.g_hat), np.asarray(outs_fix.g_hat)
    )
    for f in ("banned_now", "ban_reason_now", "accuse_mat", "sys_accuse",
              "n_active", "validators", "lifecycle"):
        np.testing.assert_array_equal(
            np.asarray(getattr(outs_el, f)), np.asarray(getattr(outs_fix, f))
        )
    np.testing.assert_array_equal(
        np.asarray(state_el.ban_step), np.asarray(state_fix.ban_step)
    )


# ---------------------------------------------------------------------------
# Probation: weight zero until a clean window promotes
# ---------------------------------------------------------------------------
def test_join_is_weight_zero_until_clean_window_promotes():
    """A fresh honest joiner never touches the aggregate during probation
    (bitwise vs the slot staying vacant), then flips ACTIVE exactly after
    probation_steps clean spot-checks."""
    probation = 3
    join_step = 2
    cfg = _cfg(n_events=2, probation_steps=probation)
    byz = jnp.zeros((N,), jnp.float32)
    ev = [(join_step, "join", 2)]
    _, _, outs_join = _run_scan(cfg, byz, STEPS, events=ev, vacant=(2,))
    _, _, outs_vac = _run_scan(cfg, byz, STEPS, events=None, vacant=(2,))

    life = np.asarray(outs_join.lifecycle)  # post-step lifecycle per step
    # probation window: joined at join_step, clean checks at join_step ..
    # join_step+probation-1, so the promote lands at that last step's end
    promote_step = join_step + probation - 1
    for t in range(join_step, promote_step):
        assert life[t, 2] == eng.SLOT_PROBATION, life[:, 2]
    assert life[promote_step, 2] == eng.SLOT_ACTIVE, life[:, 2]
    # never in the aggregate before promotion: bitwise equal to the run
    # where the slot simply stays vacant
    np.testing.assert_array_equal(
        np.asarray(outs_join.g_hat)[: promote_step + 1],
        np.asarray(outs_vac.g_hat)[: promote_step + 1],
    )
    # ... and after promotion it IS a member (the aggregate moves)
    assert np.any(
        np.asarray(outs_join.g_hat)[promote_step + 1 :]
        != np.asarray(outs_vac.g_hat)[promote_step + 1 :]
    )
    assert np.asarray(outs_join.n_active)[-1] == N - 1 + 1


# ---------------------------------------------------------------------------
# The rejoin adversary (ISSUE acceptance)
# ---------------------------------------------------------------------------
def test_rejoin_under_new_key_rebanned_without_entering_aggregate():
    """Banned Byzantine slot leaves, rejoins under a FRESH identity while
    still attacking: the probation spot-check catches it (BAN_SYBIL), both
    identities end on the identity ban ledger, and the aggregate is
    bitwise the aggregate of the run where it never came back."""
    byz_slot = 5
    byz = jnp.asarray([1.0 if i == byz_slot else 0.0 for i in range(N)])
    leave, rejoin = 6, 8
    cfg = _cfg(dict(kind="sign_flip", lam=1.0), n_events=2,
               probation_steps=3)
    ev_back = [(leave, "leave", byz_slot), (rejoin, "join", byz_slot)]
    ev_gone = [(leave, "leave", byz_slot)]
    st_back, _, outs_back = _run_scan(cfg, byz, STEPS, events=ev_back)
    cfg_gone = _cfg(dict(kind="sign_flip", lam=1.0), n_events=2,
                    probation_steps=3)
    _, _, outs_gone = _run_scan(cfg_gone, byz, STEPS, events=ev_gone)

    life = np.asarray(outs_back.lifecycle)
    # banned while active (the verification arm), well before it leaves
    assert eng.SLOT_BANNED in life[:leave, byz_slot]
    # after the rejoin the slot is NEVER active again: probation -> banned
    assert not np.any(life[rejoin:, byz_slot] == eng.SLOT_ACTIVE)
    assert life[-1, byz_slot] == eng.SLOT_BANNED
    # the sybil gate is the arm that caught it
    reasons = np.asarray(outs_back.ban_reason_now)[rejoin:, byz_slot]
    banned_rows = np.asarray(outs_back.banned_now)[rejoin:, byz_slot]
    assert banned_rows.any()
    assert reasons[banned_rows.argmax()] == eng.BAN_SYBIL
    # both keys are on the identity ledger: the original identity and the
    # fresh one minted at rejoin
    id_ban = np.asarray(st_back.id_ban_step)
    assert id_ban[byz_slot] >= 0 and id_ban[N] >= 0
    # "never entered the aggregate" is bitwise, not approximate
    np.testing.assert_array_equal(
        np.asarray(outs_back.g_hat), np.asarray(outs_gone.g_hat)
    )
    # no honest peer was accused or banned anywhere in this drama
    honest = [i for i in range(N) if i != byz_slot]
    assert not np.asarray(outs_back.banned_now)[:, honest].any()
    assert not np.asarray(outs_back.accuse_mat)[:, :, honest].any()


def test_same_key_rejoin_lands_directly_banned():
    """Rejoining with the banned IDENTITY (not a fresh key) is refused at
    admission: the identity ledger restores BANNED + the original ban step
    and reason into the slot."""
    byz_slot = 5
    byz = jnp.asarray([1.0 if i == byz_slot else 0.0 for i in range(N)])
    leave, rejoin = 6, 8
    cfg = _cfg(dict(kind="sign_flip", lam=1.0), n_events=2)
    # explicit identity == the slot's original (banned) identity
    ev = [(leave, "leave", byz_slot), (rejoin, "join", byz_slot, byz_slot)]
    st, _, outs = _run_scan(cfg, byz, STEPS, events=ev)
    life = np.asarray(outs.lifecycle)
    assert not np.any(life[rejoin:, byz_slot] == eng.SLOT_PROBATION)
    assert np.all(life[rejoin:, byz_slot] == eng.SLOT_BANNED)
    # the restored slot ledger carries the ORIGINAL ban step
    orig_ban = int(np.asarray(st.id_ban_step)[byz_slot])
    assert 0 <= orig_ban < leave
    assert int(np.asarray(st.ban_step)[byz_slot]) == orig_ban


def test_churn_never_resets_identity_ledger_or_col_checked():
    """Through every leave/rejoin the identity ban entry is immutable once
    written, and col_checked (column audit staleness, a property of the
    topology not the occupant) is monotone non-decreasing."""
    byz_slot = 5
    byz = jnp.asarray([1.0 if i == byz_slot else 0.0 for i in range(N)])
    cfg = _cfg(dict(kind="sign_flip", lam=1.0), n_events=4, audit_k=2,
               m_validators=1)
    ev = [(5, "leave", byz_slot), (7, "join", byz_slot)]
    _, _, states = _run_stepwise(cfg, byz, STEPS, events=ev)
    prev_col = np.full((N,), -1)
    ban_entry = None
    for st in states:
        col = np.asarray(st.col_checked)
        assert np.all(col >= prev_col), (col, prev_col)
        prev_col = col
        id_ban = int(np.asarray(st.id_ban_step)[byz_slot])
        if ban_entry is None and id_ban >= 0:
            ban_entry = id_ban
        if ban_entry is not None:
            assert id_ban == ban_entry  # written once, never moves


# ---------------------------------------------------------------------------
# Hypothesis property: any interleaving, stepwise == scan
# ---------------------------------------------------------------------------
def _random_schedule(seed, n_events):
    """A (possibly nonsensical) interleaving — invalid rows (leave of a
    vacant slot, join onto an occupied one) must no-op identically in both
    engines, so the draw is unconstrained."""
    rng = np.random.RandomState(seed)
    return [
        (int(rng.randint(0, STEPS)),
         "join" if rng.rand() < 0.5 else "leave",
         int(rng.randint(0, N)))
        for _ in range(int(rng.randint(1, n_events + 1)))
    ]


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       attacked=st.booleans())
def test_any_interleaving_scan_equals_stepwise(seed, attacked):
    """For ANY join/leave schedule (with bans landing mid-flight when the
    attack is on), the scanned engine and the stepwise engine agree on the
    lifecycle/active masks, the slot and identity ban ledgers, and the
    aggregates."""
    n_events = 4
    att = dict(kind="sign_flip", lam=1.0) if attacked else dict(kind="none")
    cfg = _cfg(att, n_events=n_events, probation_steps=2)
    byz = jnp.asarray([0, 0, 0, 0, 0, 1], jnp.float32)
    ev = _random_schedule(seed, n_events)
    vacant = (0,) if seed % 2 else ()

    st_sw, outs_sw, _ = _run_stepwise(cfg, byz, STEPS, events=ev,
                                      vacant=vacant)
    st_sc, _, outs_sc = _run_scan(cfg, byz, STEPS, events=ev, vacant=vacant)

    for f in ("lifecycle", "banned_now", "ban_reason_now", "n_active",
              "validators", "sampled_parts"):
        np.testing.assert_array_equal(
            np.asarray(getattr(outs_sc, f)), _stack(outs_sw, f), err_msg=f
        )
    for f in ("ban_step", "ban_reason", "lifecycle", "slot_identity",
              "probation_clean", "id_ban_step", "id_ban_reason",
              "id_accused", "active", "col_checked"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_sc, f)), np.asarray(getattr(st_sw, f)),
            err_msg=f,
        )
    np.testing.assert_array_equal(
        np.asarray(outs_sc.g_hat), _stack(outs_sw, "g_hat")
    )


# ---------------------------------------------------------------------------
# The acceptance churn grid:
# {join, leave, rejoin-banned-identity} x {butterfly_clip, verified:mean}
# x {stepwise, scan}
# ---------------------------------------------------------------------------
BAN_WITHIN = 5  # acceptance: banned <= 5 steps after (re)activation

CHURN_CASES = {
    # an honest peer joins a vacant slot mid-attack
    "join": dict(events=[(3, "join", 0)], vacant=(0,)),
    # the attacker leaves after being banned; capacity is reclaimed
    "leave": dict(events=[(6, "leave", 5)], vacant=()),
    # the banned attacker rejoins its slot under a fresh key
    "rejoin": dict(events=rejoin_under_new_key(5, 6, 8), vacant=()),
}


@pytest.mark.slow
@pytest.mark.parametrize("agg", ["butterfly_clip", "verified:mean"])
@pytest.mark.parametrize("case", sorted(CHURN_CASES))
def test_churn_grid_bans_fast_no_slander_scan_equals_stepwise(case, agg):
    """Every churn pattern x both verifiable aggregators: the Byzantine
    slot is banned within BAN_WITHIN steps of every activation (initial
    AND rejoin), honest peers collect zero accusations, and the stepwise
    and scanned engines agree on the ban ledgers bitwise."""
    kw = CHURN_CASES[case]
    byz_slot = 5
    byz = jnp.asarray([1.0 if i == byz_slot else 0.0 for i in range(N)])
    # clip_iters=200 runs the flagship's CenteredClip to its fixed point so
    # the V2 checksum is honest-clean (same rationale as the PR 5 grid)
    cfg = _cfg(dict(kind="sign_flip", lam=1.0), n_events=2,
               probation_steps=3, clip_iters=200, aggregator=agg)
    st_sw, outs_sw, _ = _run_stepwise(cfg, byz, STEPS, **kw)
    st_sc, _, outs_sc = _run_scan(cfg, byz, STEPS, **kw)

    # scan == stepwise: ban + identity ledgers bitwise
    for f in ("ban_step", "ban_reason", "lifecycle", "slot_identity",
              "id_ban_step", "id_ban_reason", "id_accused"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_sc, f)), np.asarray(getattr(st_sw, f)),
            err_msg=f"{case}/{agg}: {f}",
        )
    np.testing.assert_array_equal(
        np.asarray(outs_sc.banned_now), _stack(outs_sw, "banned_now")
    )

    # banned <= BAN_WITHIN steps after every activation window's start
    life = np.asarray(outs_sc.lifecycle)
    banned_now = np.asarray(outs_sc.banned_now)
    assert banned_now[:BAN_WITHIN, byz_slot].any(), f"{case}/{agg}"
    if case == "leave":
        # the slot vacates (capacity reclaimed) but the ban survives on
        # the IDENTITY ledger
        assert life[-1, byz_slot] == eng.SLOT_VACANT, f"{case}/{agg}"
        assert np.asarray(st_sc.id_ban_step)[byz_slot] >= 0
    else:
        assert life[-1, byz_slot] == eng.SLOT_BANNED, f"{case}/{agg}"
    if case == "rejoin":
        # the rejoined key is caught within the window too, from probation
        assert banned_now[8 : 8 + BAN_WITHIN, byz_slot].any()
        assert not np.any(life[8:, byz_slot] == eng.SLOT_ACTIVE)

    # zero honest accusations / bans, in any direction
    honest = [i for i in range(N) if i != byz_slot]
    assert not np.asarray(outs_sc.banned_now)[:, honest].any()
    assert not np.asarray(outs_sc.accuse_mat)[:, :, honest].any()
    assert not np.asarray(outs_sc.sys_accuse)[:, honest].any()


# ---------------------------------------------------------------------------
# Host mirror (launch path): same lifecycle rules, checkpoint round-trip
# ---------------------------------------------------------------------------
def test_host_membership_mirrors_engine_lifecycle():
    mem = sybil.HostMembership(4, probation_steps=2,
                               events=sybil.parse_churn("leave@2:1,join@4:1"))
    mem.ban_slots({1}, 0)
    for s in range(2):
        mem.apply_events(s)
    assert mem.lifecycle[1] == sybil.SLOT_BANNED
    mem.apply_events(2)  # leave: slot vacates, identity ledger keeps the ban
    assert mem.lifecycle[1] == sybil.SLOT_VACANT
    assert 1 in mem.banned_identities
    mem.apply_events(3)
    mem.apply_events(4)  # fresh identity joins into probation
    assert mem.lifecycle[1] == sybil.SLOT_PROBATION
    assert mem.weights()[1] == 0.0
    # a dirty probe re-bans; the fresh identity joins the ledger too
    mem.observe_probe(np.asarray([0.0, 1.0, 0.0, 0.0]), 4)
    assert mem.lifecycle[1] == sybil.SLOT_BANNED
    assert set(mem.banned_identities) >= {1, 4}
    # checkpoint round-trip restores the full ledger
    clone = sybil.HostMembership(4, probation_steps=2)
    clone.restore_tree(mem.to_tree())
    assert list(clone.lifecycle) == list(mem.lifecycle)
    assert clone.banned_identities == mem.banned_identities
    assert clone.next_identity == mem.next_identity
