"""ProtocolState engine properties (core.engine).

The acceptance bar for the scan engine: a jitted ``lax.scan`` over N >= 8
protocol steps must produce IDENTICAL ban sets / accusations and
f32-tolerance-identical aggregates to N legacy ``BTARDProtocol.step`` calls,
across attack types — plus the warm-start CenteredClip property (same fixed
point, fewer iterations).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import attacks as attacks_mod
from repro.core.centered_clip import centered_clip, centered_clip_to_tol
from repro.core.protocol import AttackConfig, BTARDProtocol

N, D, STEPS = 8, 48, 12
BYZ = (5, 6, 7)


def _make_grads(n=N, d=D):
    """Pure per-step gradient matrices for a public-seed linear problem —
    the same function drives the host wrapper AND the scanned engine."""
    w_true = jax.random.normal(jax.random.key(9), (d,))

    def peer_grad(peer, step, params, flipped):
        k = jax.random.key((peer * 7919 + step) % (2**31 - 1))
        X = jax.random.normal(k, (4, d))
        y = X @ w_true
        y = jnp.where(flipped, -y, y)
        return 2 * X.T @ (X @ params - y) / 4

    def grads_fn(params, t, flips):
        idx = jnp.arange(n)
        G = jax.vmap(lambda i, f: peer_grad(i, t, params, f))(idx, flips)
        H = jax.vmap(lambda i: peer_grad(i, t, params, False))(idx)
        return G, H

    return peer_grad, grads_fn


def _run_wrapper(attack, steps=STEPS, **kw):
    peer_grad, grads_fn = _make_grads()
    jitted = jax.jit(grads_fn)

    def host_grad(i, t, params, flipped=False):
        flips = jnp.zeros((N,), bool).at[i].set(bool(flipped))
        G, H = jitted(jnp.asarray(params, jnp.float32), t, flips)
        return np.asarray(G[i])

    proto = BTARDProtocol(
        n_peers=N, d=D, grad_fn=host_grad, byzantine=set(BYZ),
        attack=attack, tau=1.0, m_validators=2, seed=0, **kw,
    )
    params = np.zeros(D, np.float32)
    g_hats, banned_per_step, accusations = [], [], []
    for t in range(steps):
        g, info = proto.step(params, t)
        params = params - 0.05 * g
        g_hats.append(g)
        banned_per_step.append(sorted(p for p, _ in info.banned_now))
        accusations.append(
            sorted((a, b) for a, b, _, _ in info.accusations if a is not None)
        )
    return proto, np.stack(g_hats), banned_per_step, accusations


def _run_scan(attack, steps=STEPS, **kw):
    _, grads_fn = _make_grads()
    cfg = eng.config_from_attack(
        N, D, attack, tau=1.0, clip_iters=60, m_validators=2, **kw
    )
    state = eng.init_state(cfg, seed=0)
    byz_mask = jnp.asarray([1.0 if i in BYZ else 0.0 for i in range(N)])

    def update(p, g, t):
        return p - 0.05 * g

    runner = jax.jit(
        lambda s, b, p: eng.scan_protocol(
            cfg, s, b, p, grads_fn, steps, update
        )
    )
    state, params, outs = runner(state, byz_mask, jnp.zeros(D, jnp.float32))
    return state, outs


@pytest.mark.parametrize(
    "kind", ["sign_flip", "ipm_06", "alie", "random_direction", "label_flip"]
)
def test_scan_bitmatches_legacy_stepwise(kind):
    """lax.scan over 12 steps == 12 wrapper step() calls: same bans (per
    step), same accusation pairs, aggregates within f32 tolerance."""
    attack = AttackConfig(kind=kind, start_step=2, lam=100.0)
    proto, g_wrap, bans_wrap, acc_wrap = _run_wrapper(attack)
    state, outs = _run_scan(attack)

    banned_scan = {
        int(i) for i in np.nonzero(np.asarray(state.ban_step) >= 0)[0]
    }
    assert banned_scan == proto.banned, (kind, banned_scan, proto.banned)
    assert banned_scan, f"{kind}: attack never triggered a ban in {STEPS} steps"
    assert banned_scan <= set(BYZ)

    banned_now = np.asarray(outs.banned_now)
    for t in range(STEPS):
        assert sorted(np.nonzero(banned_now[t])[0].tolist()) == bans_wrap[t], t
    acc_scan = np.asarray(outs.accuse_mat)
    for t in range(STEPS):
        pairs = sorted((int(v), int(u)) for v, u in zip(*np.nonzero(acc_scan[t])))
        assert pairs == acc_wrap[t], (kind, t)

    g_scan = np.asarray(outs.g_hat)
    scale = np.abs(g_wrap).max(axis=1, keepdims=True) + 1.0
    np.testing.assert_allclose(g_scan / scale, g_wrap / scale, atol=2e-5)


def test_scan_delayed_gradient_ring_buffer():
    """The delay ring buffer in ProtocolState reproduces the wrapper's
    host-side history exactly (delayed rows = honest grads from t - D)."""
    attack = AttackConfig(kind="delayed_gradient", start_step=3, delay=3)
    proto, g_wrap, bans_wrap, _ = _run_wrapper(attack)
    state, outs = _run_scan(attack)
    banned_scan = {
        int(i) for i in np.nonzero(np.asarray(state.ban_step) >= 0)[0]
    }
    assert banned_scan == proto.banned
    scale = np.abs(g_wrap).max(axis=1, keepdims=True) + 1.0
    np.testing.assert_allclose(
        np.asarray(outs.g_hat) / scale, g_wrap / scale, atol=2e-5
    )


def test_scan_no_attack_no_bans_and_stable():
    state, outs = _run_scan(AttackConfig(kind="none"))
    assert not np.any(np.asarray(state.ban_step) >= 0)
    assert np.all(np.isfinite(np.asarray(outs.g_hat)))
    assert np.all(np.asarray(outs.n_active) == N)


def test_attack_registry_matches_named_fns():
    """apply_attack(index) == the named attack on identical inputs (the
    lax.switch registry is a pure re-indexing of the host dict)."""
    G = jax.random.normal(jax.random.key(0), (N, D))
    byz = jnp.zeros((N,), bool).at[jnp.asarray(BYZ)].set(True)
    key = jax.random.key(7)
    for kind in attacks_mod.ATTACK_NAMES:
        if kind == "delayed_gradient":
            delayed = jax.random.normal(jax.random.key(1), (N, D))
        else:
            delayed = None
        got = attacks_mod.apply_attack(
            attacks_mod.attack_index(kind), G, byz,
            key=key, lam=50.0, delayed=delayed,
        )
        want = attacks_mod.GRADIENT_ATTACKS[kind](
            G, byz, key=key, lam=50.0,
            **({"delayed": delayed} if delayed is not None else {}),
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5, err_msg=kind)


def test_attack_registry_traced_index_dispatch():
    """The attack index stays traced through jit — one compiled program
    serves every attack (the composability the registry exists for)."""
    G = jax.random.normal(jax.random.key(0), (N, D))
    byz = jnp.zeros((N,), bool).at[jnp.asarray(BYZ)].set(True)

    @jax.jit
    def run(idx):
        return attacks_mod.apply_attack(idx, G, byz, key=jax.random.key(3))

    flip = run(jnp.int32(attacks_mod.attack_index("sign_flip")))
    none = run(jnp.int32(attacks_mod.attack_index("none")))
    np.testing.assert_allclose(np.asarray(none), np.asarray(G), atol=0)
    assert np.abs(np.asarray(flip)[list(BYZ)]).max() > np.abs(np.asarray(G)).max()


# ---------------------------------------------------------------------------
# Warm-start CenteredClip
# ---------------------------------------------------------------------------
def _drifting_problem(d=512, n=16, b=3):
    mu = jax.random.normal(jax.random.key(1), (d,))
    mu = mu / jnp.linalg.norm(mu) * 20.0
    honest = mu + jax.random.normal(jax.random.key(2), (n - b, d))
    attack = jnp.broadcast_to(-10.0 * mu, (b, d))
    xs0 = jnp.concatenate([honest, attack])
    drift = 0.05 * jax.random.normal(jax.random.key(3), (n, d))
    return xs0, xs0 + drift


def test_warm_start_same_fixed_point_fewer_iters():
    """v0 = last step's aggregate reaches the SAME fixed point in strictly
    fewer iterations (the fixed point is unique for tau > 0; warm starting
    only changes the trajectory). This is the Fig. 9 argument for cutting
    clip_iters below the default 60."""
    xs0, xs1 = _drifting_problem()
    tau = 5.0
    v_prev, _ = centered_clip_to_tol(xs0, tau, eps=1e-7, max_iters=3000)
    v_cold, it_cold = centered_clip_to_tol(xs1, tau, eps=1e-6, max_iters=3000)
    v_warm, it_warm = centered_clip_to_tol(
        xs1, tau, eps=1e-6, max_iters=3000, v0=v_prev
    )
    np.testing.assert_allclose(
        np.asarray(v_warm), np.asarray(v_cold), atol=1e-3
    )
    assert int(it_warm) < int(it_cold), (int(it_warm), int(it_cold))


def test_warm_start_fixed_budget_beats_cold():
    """At a fixed small iteration budget, warm start lands closer to the
    converged fixed point than a cold start — the basis for running the
    protocol at clip_iters well below 60."""
    xs0, xs1 = _drifting_problem()
    tau = 5.0
    v_prev, _ = centered_clip_to_tol(xs0, tau, eps=1e-7, max_iters=3000)
    ref, _ = centered_clip_to_tol(xs1, tau, eps=1e-8, max_iters=5000)
    budget = 8
    err_cold = jnp.linalg.norm(centered_clip(xs1, tau, n_iters=budget) - ref)
    err_warm = jnp.linalg.norm(
        centered_clip(xs1, tau, n_iters=budget, v0=v_prev) - ref
    )
    assert float(err_warm) < 0.1 * float(err_cold), (
        float(err_warm), float(err_cold),
    )


def test_engine_warm_start_cuts_iteration_budget():
    """Slow-drift regime (fixed per-peer datasets, small lr — the realistic
    large-model setting the ROADMAP's warm-start item targets): at a fixed
    15-iteration budget, warm-started steps track the converged (400-iter)
    aggregates several times closer than cold-started ones."""
    w_true = jax.random.normal(jax.random.key(9), (D,))

    def peer_grad(peer, params):
        k = jax.random.key(peer * 7919 + 17)
        X = jax.random.normal(k, (4, D))
        return 2 * X.T @ (X @ params - X @ w_true) / 4

    def grads_fn(params, t, flips):
        G = jax.vmap(lambda i: peer_grad(i, params))(jnp.arange(N))
        return G, G

    byz_mask = jnp.zeros((N,), jnp.float32)

    def run(iters, warm):
        cfg = eng.config_from_attack(
            N, D, AttackConfig(kind="none"), tau=1.0, clip_iters=iters,
            m_validators=0, warm_start=warm,
        )
        st = eng.init_state(cfg, seed=0)
        runner = jax.jit(
            lambda s, b, p: eng.scan_protocol(
                cfg, s, b, p, grads_fn, STEPS, lambda p, g, t: p - 0.02 * g
            )
        )
        _, _, outs = runner(st, byz_mask, jnp.zeros(D, jnp.float32))
        return np.asarray(outs.g_hat)

    ref = run(400, False)
    # step 0 is cold for both by definition; judge the warm steps
    err_cold = np.abs(run(15, False) - ref).max(axis=1)[1:].mean()
    err_warm = np.abs(run(15, True) - ref).max(axis=1)[1:].mean()
    assert err_warm < 0.3 * err_cold, (err_warm, err_cold)


def test_engine_pallas_path_matches_jnp():
    """One jitted engine step with use_pallas=True equals the jnp path."""
    attack = AttackConfig(kind="sign_flip", start_step=0, lam=10.0)
    _, grads_fn = _make_grads()
    byz_mask = jnp.asarray([1.0 if i in BYZ else 0.0 for i in range(N)])
    outs = {}
    for pallas in (False, True):
        cfg = eng.config_from_attack(
            N, D, attack, tau=1.0, clip_iters=10, m_validators=2,
            use_pallas=pallas,
        )
        state = eng.init_state(cfg, seed=0)
        G, H = grads_fn(jnp.zeros(D), jnp.asarray(0), jnp.zeros((N,), bool))
        _, out = eng.jit_protocol_step(cfg)(state, byz_mask, G, H)
        outs[pallas] = np.asarray(out.g_hat)
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-4)


# ---------------------------------------------------------------------------
# Adaptive CenteredClip budget (engine-side early exit)
# ---------------------------------------------------------------------------
def test_engine_adaptive_tol_zero_reproduces_fixed_exactly():
    """adaptive_tol=0.0 runs the full cap through the shared update rule:
    aggregates BITWISE equal, bans/accusations identical — the fixed path is
    a special case of the adaptive one."""
    attack = AttackConfig(kind="sign_flip", start_step=2, lam=100.0)
    _, outs_fixed = _run_scan(attack)
    _, outs_adapt = _run_scan(attack, adaptive_tol=0.0)
    np.testing.assert_array_equal(
        np.asarray(outs_adapt.g_hat), np.asarray(outs_fixed.g_hat)
    )
    np.testing.assert_array_equal(
        np.asarray(outs_adapt.banned_now), np.asarray(outs_fixed.banned_now)
    )
    np.testing.assert_array_equal(
        np.asarray(outs_adapt.accuse_mat), np.asarray(outs_fixed.accuse_mat)
    )
    assert np.all(np.asarray(outs_adapt.clip_iters_used) == 60)


@pytest.mark.parametrize("kind", ["sign_flip", "ipm_06", "label_flip"])
def test_engine_adaptive_matches_legacy_wrapper(kind):
    """The acceptance property: a scanned adaptive+warm run produces the
    SAME bans/accusations as the host-pipeline fixed-iter wrapper and
    f32-tolerance aggregates — in the regime where the clip CONVERGES
    within the cap (tau comparable to the gradient scale; the early exit
    then lands on the unique fixed point the fixed budget also reaches).
    With the cap binding instead (unconverged), only the cold path is
    bitwise comparable — covered by the tol=0 test above."""
    tau = 25.0
    attack = AttackConfig(kind=kind, start_step=2, lam=100.0)

    peer_grad, grads_fn = _make_grads()
    jitted = jax.jit(grads_fn)

    def host_grad(i, t, params, flipped=False):
        flips = jnp.zeros((N,), bool).at[i].set(bool(flipped))
        G, _ = jitted(jnp.asarray(params, jnp.float32), t, flips)
        return np.asarray(G[i])

    proto = BTARDProtocol(
        n_peers=N, d=D, grad_fn=host_grad, byzantine=set(BYZ),
        attack=attack, tau=tau, m_validators=2, seed=0,
    )
    params = np.zeros(D, np.float32)
    g_hats, bans_wrap, acc_wrap = [], [], []
    for t in range(STEPS):
        g, info = proto.step(params, t)
        params = params - 0.05 * g
        g_hats.append(g)
        bans_wrap.append(sorted(p for p, _ in info.banned_now))
        acc_wrap.append(
            sorted((a, b) for a, b, _, _ in info.accusations if a is not None)
        )
    g_wrap = np.stack(g_hats)

    cfg = eng.config_from_attack(
        N, D, attack, tau=tau, clip_iters=60, m_validators=2,
        adaptive_tol=1e-6, warm_start=True,
    )
    state = eng.init_state(cfg, seed=0)
    byz_mask = jnp.asarray([1.0 if i in BYZ else 0.0 for i in range(N)])
    runner = jax.jit(
        lambda s, b, p: eng.scan_protocol(
            cfg, s, b, p, grads_fn, STEPS, lambda p, g, t: p - 0.05 * g
        )
    )
    state, _, outs = runner(state, byz_mask, jnp.zeros(D, jnp.float32))

    banned_scan = {
        int(i) for i in np.nonzero(np.asarray(state.ban_step) >= 0)[0]
    }
    assert banned_scan == proto.banned, (kind, banned_scan, proto.banned)
    assert banned_scan, f"{kind}: attack never triggered a ban"
    banned_now = np.asarray(outs.banned_now)
    for t in range(STEPS):
        assert sorted(np.nonzero(banned_now[t])[0].tolist()) == bans_wrap[t], t
    acc_scan = np.asarray(outs.accuse_mat)
    for t in range(STEPS):
        pairs = sorted(
            (int(v), int(u)) for v, u in zip(*np.nonzero(acc_scan[t]))
        )
        assert pairs == acc_wrap[t], (kind, t)
    used = np.asarray(outs.clip_iters_used)
    assert used.max() < 60, used  # the early exit actually triggered
    scale = np.abs(g_wrap).max(axis=1, keepdims=True) + 1.0
    np.testing.assert_allclose(
        np.asarray(outs.g_hat) / scale, g_wrap / scale, atol=2e-4
    )


def test_engine_adaptive_reports_budget_and_early_exits():
    """clip_iters_used surfaces the real per-step budget; in the no-attack
    slow-drift regime with warm start it early-exits far below the cap."""
    w_true = jax.random.normal(jax.random.key(9), (D,))

    def peer_grad(peer, params):
        k = jax.random.key(peer * 7919 + 17)
        X = jax.random.normal(k, (4, D))
        return 2 * X.T @ (X @ params - X @ w_true) / 4

    def grads_fn(params, t, flips):
        G = jax.vmap(lambda i: peer_grad(i, params))(jnp.arange(N))
        return G, G

    cfg = eng.config_from_attack(
        N, D, AttackConfig(kind="none"), tau=100.0, clip_iters=60,
        m_validators=0, warm_start=True, adaptive_tol=1e-5,
    )
    st = eng.init_state(cfg, seed=0)
    runner = jax.jit(
        lambda s, b, p: eng.scan_protocol(
            cfg, s, b, p, grads_fn, STEPS, lambda p, g, t: p - 0.02 * g
        )
    )
    _, _, outs = runner(st, jnp.zeros((N,), jnp.float32),
                        jnp.zeros(D, jnp.float32))
    used = np.asarray(outs.clip_iters_used)
    assert used.shape == (STEPS,)
    assert used.max() <= 60
    # warm-started steps after the first need only a handful of iterations
    assert used[1:].mean() < 15, used
