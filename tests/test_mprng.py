"""MPRNG commit/reveal protocol tests (paper App. A.2)."""
import numpy as np

from repro.core.mprng import AbortingPeer, LyingPeer, MPRNGPeer, run_mprng


def test_honest_consensus_and_determinism():
    rng = np.random.default_rng(0)
    peers = [MPRNGPeer(i) for i in range(8)]
    v1, banned, rounds = run_mprng(peers, rng)
    assert banned == [] and rounds == 1
    rng2 = np.random.default_rng(0)
    v2, _, _ = run_mprng([MPRNGPeer(i) for i in range(8)], rng2)
    assert v1 == v2  # same randomness -> same output (recomputable by all)


def test_lying_peer_banned():
    rng = np.random.default_rng(1)
    peers = [MPRNGPeer(i) for i in range(7)] + [LyingPeer(7)]
    v, banned, rounds = run_mprng(peers, rng)
    assert banned == [7]
    assert rounds >= 2  # restart happened


def test_aborting_attacker_banned_and_bias_removed():
    """The abort-bias attack: attacker aborts when it dislikes the result.
    The protocol bans it and re-rolls WITHOUT it, so the final output cannot
    be biased by aborts (paper App. A.2 last paragraph)."""
    outs = []
    for seed in range(40):
        rng = np.random.default_rng(seed)
        peers = [MPRNGPeer(i) for i in range(7)] + [AbortingPeer(7)]
        v, banned, _ = run_mprng(peers, rng)
        # attacker either revealed honestly (liked the outcome) or is banned
        outs.append(v % 2)
    # if the abort-bias worked, all outputs would be even; they must not be
    assert 0 < sum(outs) < 40, sum(outs)


def test_output_bits_roughly_uniform():
    rng = np.random.default_rng(2)
    vals = [run_mprng([MPRNGPeer(i) for i in range(4)], rng)[0] % 2 for _ in range(200)]
    frac = sum(vals) / len(vals)
    assert 0.35 < frac < 0.65, frac
