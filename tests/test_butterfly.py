"""ButterflyClip + verification-table tests (paper Alg. 2/6)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import butterfly as bf
from repro.core.centered_clip import centered_clip


def test_split_merge_roundtrip():
    g = jax.random.normal(jax.random.key(0), (5, 103))
    parts = bf.split_parts(g, 5)
    for i in range(5):
        np.testing.assert_allclose(
            np.asarray(bf.merge_parts(parts[i], 103)), np.asarray(g[i])
        )


def test_butterfly_equals_per_partition_clip():
    n, d, tau = 8, 200, 1.0
    g = jax.random.normal(jax.random.key(1), (n, d))
    agg, parts = bf.butterfly_clip(g, tau, n_iters=40)
    for j in range(n):
        ref = centered_clip(parts[:, j], tau, n_iters=40)
        np.testing.assert_allclose(np.asarray(agg[j]), np.asarray(ref), atol=1e-5)


def test_checksum_zero_for_honest_aggregation():
    n, d = 8, 512
    g = jax.random.normal(jax.random.key(2), (n, d))
    agg, parts = bf.butterfly_clip(g, tau=1.0, n_iters=200)
    z = bf.get_random_directions(7, n, parts.shape[-1])
    s, norms = bf.verification_tables(parts, agg, z, 1.0)
    sums, violated = bf.checksum_violations(s, None, tol=1e-3)
    assert not bool(violated.any()), np.asarray(sums)


def test_checksum_catches_corrupted_partition():
    """A malicious aggregator shifting its partition breaks sum_i s_i^j = 0
    with probability 1 (paper eq. (10))."""
    n, d = 8, 512
    g = jax.random.normal(jax.random.key(3), (n, d))
    agg, parts = bf.butterfly_clip(g, tau=1.0, n_iters=200)
    agg = agg.at[3].add(0.05 * jax.random.normal(jax.random.key(4), agg[3].shape))
    z = bf.get_random_directions(7, n, parts.shape[-1])
    s, norms = bf.verification_tables(parts, agg, z, 1.0)
    sums, violated = bf.checksum_violations(s, None, tol=1e-3)
    assert bool(violated[3])
    assert not bool(violated[jnp.arange(n) != 3].any())


def test_delta_max_votes_flag_outlier_partition():
    n, d = 8, 512
    g = jax.random.normal(jax.random.key(5), (n, d)) * 0.1
    agg, parts = bf.butterfly_clip(g, tau=10.0, n_iters=50)
    agg = agg.at[2].add(100.0)  # grossly corrupted partition
    z = bf.get_random_directions(1, n, parts.shape[-1])
    _, norms = bf.verification_tables(parts, agg, z, 10.0)
    votes, trig = bf.delta_max_votes(norms, None, delta_max=5.0)
    assert bool(trig[2]) and not bool(trig[jnp.arange(n) != 2].any())


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 12), d=st.integers(2, 300), seed=st.integers(0, 9999))
def test_property_butterfly_mean_matches_allreduce(n, d, seed):
    """tau=inf butterfly == plain all-reduce mean for any (n, d)."""
    g = jax.random.normal(jax.random.key(seed), (n, d))
    agg, _ = bf.butterfly_clip(g, np.inf, n_iters=3)
    got = bf.merge_parts(agg, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(g.mean(0)), atol=1e-4)
