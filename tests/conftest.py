import os

# Smoke tests and benches must see ONE device (the 512-device override lives
# exclusively in launch/dryrun.py and the subprocess sharding tests).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)


@pytest.fixture(autouse=True)
def _reset_sharding_state():
    """Tests may register (fake) meshes / seq-parallel flags; never leak."""
    yield
    from repro.sharding import set_mesh
    from repro.sharding.specs import set_manual_axes, set_seq_parallel

    set_mesh(None)
    set_manual_axes(())
    set_seq_parallel(False)
