"""Pallas kernel sweeps vs the pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.ops import butterfly_clip_op, centered_clip_op, verify_tables_op
from repro.kernels.ref import centered_clip_ref, verify_tables_ref

SHAPES = [(4, 128), (8, 257), (16, 1000), (32, 2048), (7, 999), (3, 130)]
DTYPES = ["float32", "bfloat16"]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_centered_clip_kernel_sweep(shape, dtype):
    n, d = shape
    xs = (jax.random.normal(jax.random.key(n * d), (n, d)) * 2 + 0.5).astype(dtype)
    tau = 1.0
    taus = jnp.full((12,), tau, jnp.float32)
    got = centered_clip_op(xs, tau, n_iters=12)
    want = centered_clip_ref(xs, taus)
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_verify_tables_kernel_sweep(shape, dtype):
    n, d = shape
    xs = (jax.random.normal(jax.random.key(d), (n, d)) * 3).astype(dtype)
    v = jax.random.normal(jax.random.key(1), (d,)).astype(dtype)
    z = jax.random.normal(jax.random.key(2), (d,))
    z = (z / jnp.linalg.norm(z)).astype(dtype)
    s_k, n_k = verify_tables_op(xs, v, z, 0.7)
    s_r, n_r = verify_tables_ref(xs, v, z, 0.7)
    tol = 1e-4 if dtype == "float32" else 1e-1
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(n_k), np.asarray(n_r), atol=tol, rtol=tol)


def test_kernel_weights_mask():
    xs = jax.random.normal(jax.random.key(0), (8, 300))
    w = jnp.array([1, 0, 1, 0, 1, 1, 1, 0], jnp.float32)
    got = centered_clip_op(xs, 2.0, w, n_iters=10)
    want = centered_clip_ref(xs, jnp.full((10,), 2.0), w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_kernel_tau_inf_mean():
    xs = jax.random.normal(jax.random.key(0), (6, 500))
    got = centered_clip_op(xs, np.inf, n_iters=3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(xs.mean(0)), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 24),
    d=st.integers(2, 1500),
    tau=st.floats(0.2, 50.0),
    iters=st.integers(1, 20),
    seed=st.integers(0, 99999),
)
def test_property_kernel_matches_ref(n, d, tau, iters, seed):
    xs = jax.random.normal(jax.random.key(seed), (n, d)) * 2
    got = centered_clip_op(xs, tau, n_iters=iters)
    want = centered_clip_ref(xs, jnp.full((iters,), tau, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("shape", [(8, 8, 300), (4, 16, 1025), (3, 6, 128)])
def test_butterfly_batched_kernel_matches_per_partition_ref(shape):
    """The all-partition ButterflyClip kernel == per-partition oracle."""
    n_parts, n, d = shape
    parts = jax.random.normal(jax.random.key(n_parts * d), (n_parts, n, d)) * 2
    w = jnp.where(jnp.arange(n) % 4 == 0, 0.0, 1.0)
    got = butterfly_clip_op(parts, 1.0, w, n_iters=10)
    taus = jnp.full((10,), 1.0, jnp.float32)
    want = jnp.stack([centered_clip_ref(parts[j], taus, w) for j in range(n_parts)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 16),
    d=st.integers(2, 2000),
    blk=st.sampled_from([128, 256, 512, 1024]),
    seed=st.integers(0, 99999),
)
def test_property_block_size_invariance(n, d, blk, seed):
    """Kernel output must not depend on the VMEM block geometry."""
    xs = jax.random.normal(jax.random.key(seed), (n, d))
    a = centered_clip_op(xs, 1.0, n_iters=8, block=blk)
    b = centered_clip_op(xs, 1.0, n_iters=8, block=2048)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
