"""Device-resident data pipeline properties.

BTARD's verification model requires PUBLIC batches: any peer (or validator)
recomputing xi_i^t gets the same bits on ANY execution path. These tests pin
that down for the new in-scan generator:

* ``device_batch`` traced under jit/scan (with concrete OR traced step/peer)
  is bitwise identical to the host ``batch()`` for the same
  (global_seed, step, peer) — property-tested over the seed space including
  step*peer products far past int32 (the overflow hazard the ``peer_key``
  fold-in chain removes);
* the launch-layer device-resident scan step consumes exactly the host
  pipeline's batches (subprocess, 8 host devices): identical params out.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from tests._hypothesis_compat import given, settings, strategies as st

from repro.data import TokenPipeline, peer_key

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@settings(max_examples=20, deadline=None)
@given(
    global_seed=st.integers(0, 2**31 - 2),
    step=st.integers(0, 2**31 - 2),
    peer=st.integers(0, 2**20),
)
def test_device_batch_bitwise_matches_host(global_seed, step, peer):
    """jit(device_batch)(traced step, traced peer) == host batch(step, peer)
    bit for bit — including (step, peer) whose product overflows int32 (the
    legacy affine peer_seed hazard)."""
    pipe = TokenPipeline(257, 8, 2, global_seed=global_seed)
    host = pipe.batch(step, peer)
    dev = jax.jit(lambda s, p: pipe.device_batch(s, p))(
        jnp.int32(step), jnp.int32(peer)
    )
    np.testing.assert_array_equal(
        np.asarray(host["tokens"]), np.asarray(dev["tokens"])
    )


def test_device_batch_in_scan_matches_host():
    """The generator INSIDE a lax.scan body (the device-resident loop's data
    phase) emits the host pipeline's exact tokens step by step."""
    pipe = TokenPipeline(512, 12, 4)
    steps = jnp.arange(5, dtype=jnp.int32)

    @jax.jit
    def gen(steps):
        def body(c, s):
            return c, pipe.device_batch(s)["tokens"]

        return jax.lax.scan(body, 0, steps)[1]

    got = np.asarray(gen(steps))
    want = np.stack([np.asarray(pipe.batch(s)["tokens"]) for s in range(5)])
    np.testing.assert_array_equal(got, want)


def test_device_batch_extras_traceable_and_close():
    """Modality extras generate under jit with a process-stable stream tag
    (crc32, not the PYTHONHASHSEED-randomized hash()). Float extras agree
    with the host path to 1 ulp (XLA may fuse the normal*scale chain
    differently across programs); the verification-critical integer tokens
    are exact (above)."""
    pipe = TokenPipeline(64, 8, 2)
    ex = {"memory_raw": ((4, 6), jnp.float32)}
    host = pipe.batch(3, 1, extras=ex)
    dev = jax.jit(lambda s, p: pipe.device_batch(s, p, extras=ex))(
        jnp.int32(3), jnp.int32(1)
    )
    np.testing.assert_array_equal(
        np.asarray(host["tokens"]), np.asarray(dev["tokens"])
    )
    np.testing.assert_allclose(
        np.asarray(host["memory_raw"]), np.asarray(dev["memory_raw"]),
        rtol=1e-6, atol=1e-9,
    )


@settings(max_examples=6, deadline=None)
@given(
    vocab=st.sampled_from([2**16, 151_936, 262_144]),
    seq=st.sampled_from([31, 129, 2049]),
)
def test_device_batch_bitwise_at_zoo_shapes(vocab, seq):
    """The in-scan == host bitwise property at REAL vocab sizes (>= 2^16)
    and zoo sequence lengths: token ids stay int32, in [0, V), and the
    affine transition a*x+c never wraps int32 (audited in TokenPipeline)."""
    pipe = TokenPipeline(vocab, seq, 2, global_seed=3)
    host = pipe.batch(7, 5)
    dev = jax.jit(lambda s, p: pipe.device_batch(s, p))(jnp.int32(7), jnp.int32(5))
    tok = np.asarray(host["tokens"])
    assert tok.dtype == np.int32
    assert tok.min() >= 0 and tok.max() < vocab
    np.testing.assert_array_equal(tok, np.asarray(dev["tokens"]))


def test_affine_overflow_guard():
    """Parameterizations whose transition a*x+c would wrap int32 must raise
    loudly at construction — pre-fix they silently wrapped (tokens stayed in
    [0, V) so nothing downstream noticed the process was not the documented
    bigram). Defaults stay exact for every zoo vocab."""
    import pytest

    with pytest.raises(ValueError, match="overflows int32"):
        TokenPipeline(2**30, 8, 2, a=2**20 + 5)
    # defaults at the largest zoo-ish vocab are fine
    TokenPipeline(262_144, 8, 2)
    # a, c are canonicalized mod V
    p = TokenPipeline(257, 8, 2, a=257 + 5, c=257 + 7)
    assert (p.a, p.c) == (5, 7)


def test_peer_key_injective_and_overflow_free():
    """Distinct (step, peer) -> distinct keys, including coordinates whose
    affine combination wraps int32."""
    pairs = [(0, 0), (0, 1), (1, 0), (2**30, 10**6), (10**6, 2**30),
             (2**31 - 2, 2**20)]
    keys = {
        tuple(np.asarray(jax.random.key_data(peer_key(0, s, p))).tolist())
        for s, p in pairs
    }
    assert len(keys) == len(pairs)


def test_launch_scan_device_data_equals_host_batches():
    """make_btard_scan_train_step(pipeline=...) == the host-batch mode on
    identical inputs: same params out (the in-scan data phase is invisible
    to training), adaptive+warm variant runs checksum-clean."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = """
    import jax, jax.numpy as jnp
    from repro.launch.steps import make_btard_scan_train_step
    from repro.models import get_model
    from repro.optim import sgd
    from repro.configs.base import InputShape
    from repro.data import TokenPipeline

    mesh = jax.make_mesh((4, 2), ('data', 'model'))
    m = get_model('qwen3-1.7b', reduced=True)
    shape = InputShape('t', 16, 8, 'train')
    opt = sgd(0.05)
    params = m.init_params(jax.random.key(0)); st = opt.init(params)
    pipe = TokenPipeline(m.cfg.vocab_size, 16, 8)
    N = 3
    byz = jnp.zeros((4,), jnp.float32); w = jnp.ones((4,), jnp.float32)
    v0 = jax.tree.map(jnp.zeros_like, params)
    steps = jnp.arange(N, dtype=jnp.int32); seeds = steps * 7919 + 13

    host_fn, _ = make_btard_scan_train_step(
        m, opt, mesh, shape, n_scan_steps=N, tau=2.0, clip_iters=5)
    dev_fn, _ = make_btard_scan_train_step(
        m, opt, mesh, shape, n_scan_steps=N, tau=2.0, clip_iters=5,
        pipeline=pipe)
    batches = jax.tree.map(lambda *ls: jnp.stack(ls),
                           *[pipe.batch(s) for s in range(N)])
    p1, _, met1, _, _ = host_fn(params, st, batches, steps, seeds, byz, w, v0)
    p2, _, met2, _, _ = dev_fn(params, st, steps, seeds, byz, w, v0)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    mx = max(jax.tree.leaves(diffs))
    assert mx == 0.0, f'device-data params diverged from host-batch: {mx}'

    # adaptive early exit + warm start on the device-resident path
    ad_fn, _ = make_btard_scan_train_step(
        m, opt, mesh, shape, n_scan_steps=N, tau=2.0, clip_iters=20,
        warm_start=True, adaptive_tol=1e-4, pipeline=pipe)
    _, _, met3, _, _ = ad_fn(params, st, steps, seeds, byz, w, v0)
    assert float(met3['checksum_max'].max()) < 1e-3
    assert met3['clip_iters_max'].shape == (N,)
    print('DEVICE DATA OK', mx)
    """
    r = subprocess.run(
        [sys.executable, "-W", "ignore", "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert r.returncode == 0, r.stdout[-3000:] + "\n---\n" + r.stderr[-3000:]
    assert "DEVICE DATA OK" in r.stdout
