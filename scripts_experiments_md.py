"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from results/dryrun."""
import glob
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.bench_roofline import analyze_record

recs = [json.load(open(f)) for f in sorted(glob.glob("results/dryrun/*.json"))]

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9), r["mesh"], r["step"]))

# --- Dry-run table (both meshes, compile proof + memory) ---
print("<!-- DRYRUN_TABLE -->")
print("| arch | shape | mesh | step | compile | args/dev | temp/dev | HLO GFLOPs/dev | coll GB/dev |")
print("|---|---|---|---|---|---|---|---|---|")
for r in recs:
    fl = r.get("flops_corrected", r["flops"])
    cl = r.get("collective_bytes_corrected", r["collective_bytes"].get("total", 0))
    print(
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} | "
        f"{r['compile_s']}s | {r.get('argument_size_in_bytes',0)/1e9:.1f} GB | "
        f"{r.get('temp_size_in_bytes',0)/1e9:.1f} GB | {fl/1e9:.0f} | {cl/1e9:.2f} |"
    )

print()
print("<!-- ROOFLINE_TABLE -->")
print("| arch | shape | step | compute s | memory s | collective s | dominant | useful ratio |")
print("|---|---|---|---|---|---|---|---|")
for r in recs:
    if r["mesh"] != "16x16" or "probe_error" in r and False:
        continue
    if r["mesh"] != "16x16":
        continue
    terms, dom, mf, ratio = analyze_record(r)
    print(
        f"| {r['arch']} | {r['shape']} | {r['step']} | "
        f"{terms['compute']:.3e} | {terms['memory']:.3e} | {terms['collective']:.3e} | "
        f"**{dom}** | {ratio:.2f} |"
    )
